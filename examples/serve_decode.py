"""Serving example: batched greedy decode with KV/SSM caches across three
architecture families (dense GQA, attention-free SSM, MLA+MoE).

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.train.serve import generate


def main():
    for arch in ["llama3.2-1b", "mamba2-1.3b", "deepseek-v2-lite-16b"]:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        B, prompt_len, max_new = 4, 8, 24
        prompt = jax.random.randint(jax.random.key(1), (B, prompt_len), 0,
                                    cfg.vocab_size)
        t0 = time.perf_counter()
        out = generate(model, params, prompt, max_new=max_new,
                       seq_len=prompt_len + max_new)
        dt = time.perf_counter() - t0
        print(f"{arch:24s} batch={B} generated {max_new} tokens each "
              f"in {dt:5.2f}s ({B * max_new / dt:6.1f} tok/s)  "
              f"sample={out[0, prompt_len:prompt_len + 8].tolist()}")


if __name__ == "__main__":
    main()
