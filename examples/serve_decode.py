"""Serving example: the continuous-batching engine across three
architecture families (dense GQA, attention-free SSM, MLA+MoE), reporting
prefill and decode throughput separately.

    PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import Engine, SamplingParams


def main():
    for arch in ["llama3.2-1b", "mamba2-1.3b", "deepseek-v2-lite-16b"]:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))

        rng = np.random.RandomState(0)
        n_req, slots = 8, 4
        lens = np.maximum(1, rng.poisson(12, n_req))
        news = np.maximum(1, rng.poisson(16, n_req))
        prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
                   for n in lens]

        eng = Engine(model, params, max_slots=slots,
                     max_seq=int((lens + news).max()), prefill_chunk=16)
        rids = [eng.submit(p, int(m), SamplingParams())
                for p, m in zip(prompts, news)]
        results = eng.run()
        st = eng.stats
        lat = st.token_latency_percentiles()
        print(f"{arch:24s} {n_req} reqs on {slots} slots | "
              f"prefill {st.prefill_tokens:3d} tok @ "
              f"{st.prefill_tok_s():7.1f} tok/s | "
              f"decode {st.decoded_tokens:3d} tok @ "
              f"{st.decode_tok_s():7.1f} tok/s | "
              f"p50/p99 {lat[50] * 1e3:5.1f}/{lat[99] * 1e3:5.1f} ms | "
              f"sample={results[rids[0]][:6]}")


if __name__ == "__main__":
    main()
