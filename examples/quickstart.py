"""Quickstart: train a tiny llama-style LM with the paper's BSP + ASA
exchange on the host devices, then greedy-decode from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.synthetic import LMTokenSource
from repro.models import build_model
from repro.optim import sgd_momentum, warmup_cosine
from repro.train.loop import train
from repro.train.serve import generate


def main():
    cfg = get_smoke_config("llama3.2-1b").with_overrides(vocab_size=256)
    model = build_model(cfg)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    jax.set_mesh(mesh)

    src = LMTokenSource(cfg.vocab_size, seq_len=64)
    batches = (src.batch(16, i) for i in range(100))
    opt = sgd_momentum(weight_decay=0.0)

    state, report = train(model, opt, warmup_cosine(0.02, 10, 100), mesh,
                          batches, exchanger="asa", num_steps=100,
                          log_every=20)
    print(f"\ntrained {report.steps} steps "
          f"({report.examples_per_s:.0f} examples/s); "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")

    prompt = jnp.ones((2, 4), jnp.int32)
    out = generate(model, state["params"], prompt, max_new=12, seq_len=16)
    print("greedy sample:", out[0].tolist())


if __name__ == "__main__":
    main()
