"""Paper-faithful experiment: AlexNet trained with BSP + configurable
exchange strategy and the Alg-1 parallel loader, on synthetic ImageNet-like
batch files. Reproduces the paper's training-loop structure end to end
(reduced image size by default — pass --full for 227x227 AlexNet).

    PYTHONPATH=src python examples/train_alexnet_bsp.py \
        --exchanger asa16 --steps 30
"""
import argparse
import tempfile

import numpy as np
import jax

from repro.configs import get_config, get_smoke_config
from repro.data.prefetch import ParallelLoader
from repro.data.synthetic import ImageSource, materialize_batch_files
from repro.models import build_model, count_params
from repro.optim import sgd_momentum, step_decay
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--exchanger", default="asa",
                    help="ar | asa | asa16 | asa8 | ring | hier")
    ap.add_argument("--scheme", default="subgd", choices=["subgd", "awagd"])
    ap.add_argument("--full", action="store_true",
                    help="full 227x227 AlexNet (slow on CPU)")
    args = ap.parse_args()

    cfg = get_config("alexnet") if args.full else get_smoke_config("alexnet")
    model = build_model(cfg)
    n = count_params(jax.eval_shape(model.init, jax.random.key(0)))
    print(f"AlexNet ({'full' if args.full else 'reduced'}): {n:,} params, "
          f"exchanger={args.exchanger}, scheme={args.scheme}")

    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    jax.set_mesh(mesh)

    with tempfile.TemporaryDirectory() as td:
        src = ImageSource(cfg.image_size, cfg.num_classes)
        files = materialize_batch_files(src, td, min(args.steps, 32),
                                        args.batch)
        mean = np.zeros((cfg.image_size, cfg.image_size, 3), np.float32)
        loader = ParallelLoader(files, image_mean=mean,
                                crop=cfg.image_size - 8, depth=2,
                                epochs=args.steps // len(files) + 1)
        # the paper's AlexNet LR policy: /10 every "20 epochs"
        lr = step_decay(0.01, steps_per_drop=max(args.steps // 3, 1))
        opt = sgd_momentum(momentum=0.9, weight_decay=5e-4)
        state, report = train(model, opt, lr, mesh, loader,
                              exchanger=args.exchanger, scheme=args.scheme,
                              num_steps=args.steps, log_every=5)
        loader.stop()
    print(f"\n{report.steps} steps, {report.examples_per_s:.1f} images/s, "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
