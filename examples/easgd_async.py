"""EASGD (paper §4): elastic-averaging training with a center replica,
sweeping the averaging period tau — reproducing the paper's observation that
larger tau behaves like a larger effective batch (slower initial
convergence, less communication).

    PYTHONPATH=src python examples/easgd_async.py --steps 60
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import init_easgd_state, make_easgd_step
from repro.data.synthetic import LMTokenSource
from repro.models import build_model
from repro.optim import constant, sgd_momentum


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--alpha", type=float, default=0.5)
    args = ap.parse_args()

    cfg = get_smoke_config("llama3.2-1b").with_overrides(vocab_size=256)
    model = build_model(cfg)
    k = len(jax.devices())
    mesh = jax.make_mesh((k,), ("data",))
    jax.set_mesh(mesh)
    src = LMTokenSource(cfg.vocab_size, 64)
    opt = sgd_momentum(weight_decay=0.0)

    for tau in (1, 2, 4):
        step = jax.jit(make_easgd_step(model, constant(0.02), mesh,
                                       alpha=args.alpha, tau=tau))
        state = init_easgd_state(model, opt, jax.random.key(0), k)
        losses = []
        for i in range(args.steps):
            state, m = step(state, src.batch(8 * k, i), jax.random.key(i))
            losses.append(float(m["loss"]))
        print(f"tau={tau}: loss {losses[0]:.3f} -> "
              f"{np.mean(losses[-5:]):.3f}  "
              f"(comm every {tau} steps, alpha={args.alpha})")


if __name__ == "__main__":
    main()
