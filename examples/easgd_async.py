"""Async training through the unified engine (paper §4): EASGD and ASGD.

Sweeps the averaging period tau — reproducing the paper's observation that
larger tau behaves like a larger effective batch (slower initial
convergence, less communication) — with the elastic center exchange
routed through the shared exchanger layer at fp16 wire (``asa16``). The
sync/async switch is one field on the TrainPlan; the loop, checkpointing
and metrics are identical to the BSP examples.

    PYTHONPATH=src python examples/easgd_async.py --steps 60
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.synthetic import LMTokenSource
from repro.models import build_model
from repro.optim import constant, sgd_momentum
from repro.train.engine import TrainPlan
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--exchanger", default="asa16",
                    help="wire format of the center exchange")
    args = ap.parse_args()

    cfg = get_smoke_config("llama3.2-1b").with_overrides(vocab_size=256)
    model = build_model(cfg)
    k = len(jax.devices())
    mesh = jax.make_mesh((k,), ("data",))
    jax.set_mesh(mesh)
    src = LMTokenSource(cfg.vocab_size, 64)
    opt = sgd_momentum(weight_decay=0.0)
    batches = lambda: (src.batch(8 * k, i) for i in range(args.steps))

    for tau in (1, 2, 4):
        plan = TrainPlan(algo="easgd", exchanger=args.exchanger,
                         alpha=args.alpha, tau=tau)
        _, report = train(model, opt, constant(0.02), mesh, batches(),
                          plan=plan, num_steps=args.steps, log_every=0,
                          print_fn=lambda *_: None)
        print(f"easgd tau={tau}: loss {report.losses[0]:.3f} -> "
              f"{np.mean(report.losses[-5:]):.3f}  "
              f"(center exchange every {tau} steps at "
              f"{args.exchanger}, alpha={args.alpha})")

    # asgd: the alpha=1 point — center applies summed worker deltas, so
    # the lr scales down by k
    plan = TrainPlan(algo="asgd", exchanger=args.exchanger, tau=2)
    _, report = train(model, opt, constant(0.02 / k), mesh, batches(),
                      plan=plan, num_steps=args.steps, log_every=0,
                      print_fn=lambda *_: None)
    print(f"asgd  tau=2: loss {report.losses[0]:.3f} -> "
          f"{np.mean(report.losses[-5:]):.3f}  "
          f"(workers re-fetch the center each sync)")


if __name__ == "__main__":
    main()
