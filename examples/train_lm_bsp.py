"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps with BSP data parallelism, the ASA exchanger, the parallel
data loader (paper Alg 1), LR schedule, and checkpointing.

    PYTHONPATH=src python examples/train_lm_bsp.py [--steps 300]

Note: pure CPU — a ~100M model at seq 256 runs a few steps/minute; lower
--steps for a quick pass.
"""
import argparse
import tempfile

import jax

from repro.configs import get_config
from repro.data.prefetch import ParallelLoader
from repro.data.synthetic import LMTokenSource, materialize_batch_files
from repro.models import build_model, count_params
from repro.optim import sgd_momentum, warmup_cosine
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    # ~100M llama-family config (derived from llama3.2-1b)
    cfg = get_config("llama3.2-1b").with_overrides(
        num_layers=6, d_model=768, d_ff=2048, vocab_size=32768,
        attention=get_config("llama3.2-1b").attention.__class__(
            num_heads=12, num_kv_heads=4, head_dim=64),
        tie_embeddings=True, scan_layers=True, remat=False)
    model = build_model(cfg)
    print(f"model: {cfg.name}-100M derivative, "
          f"{count_params(jax.eval_shape(model.init, jax.random.key(0))):,}"
          " params")

    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    jax.set_mesh(mesh)

    with tempfile.TemporaryDirectory() as td:
        # paper layout: batch files on disk + Alg 1 background loader
        src = LMTokenSource(cfg.vocab_size, args.seq)
        files = materialize_batch_files(src, td, min(args.steps, 64),
                                        args.batch)
        epochs = args.steps // len(files) + 1
        loader = ParallelLoader(files, depth=2, epochs=epochs)

        opt = sgd_momentum(weight_decay=1e-4)
        lr = warmup_cosine(0.01, 20, args.steps)
        state, report = train(model, opt, lr, mesh, loader,
                              exchanger="asa", num_steps=args.steps,
                              log_every=10, ckpt_path=args.ckpt)
        loader.stop()
    print(f"\n{report.steps} steps, {report.examples_per_s:.1f} ex/s, "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
