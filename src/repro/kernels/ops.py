"""Jitted public wrappers over the Pallas kernels.

Execution mode is auto-selected per backend (compiled on TPU, Pallas
interpreter elsewhere) — see ``repro.kernels.default_interpret`` for the
``REPRO_PALLAS_INTERPRET`` / legacy ``REPRO_PALLAS_COMPILED`` overrides.
The wrappers match the exchanger/optimizer plug-in contracts.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import chunk_sum as _cs
from repro.kernels import fused_rs_update as _fru
from repro.kernels import fused_sgd as _fs
from repro.kernels import quantize as _q


def chunk_sum(chunks, block_n: int = _cs.DEFAULT_BLOCK_N):
    """Exchanger ``sum_fn`` plug-in: (k, ...) -> (...) fp32.

    Flattens trailing dims to the kernel's (k, n) contract."""
    k = chunks.shape[0]
    flat = chunks.reshape(k, -1)
    out = _cs.chunk_sum(flat, block_n=block_n)
    return out.reshape(chunks.shape[1:])


def quant_fp16(x):
    return _q.quant_fp16(x.reshape(-1)).reshape(x.shape)


def dequant_fp16(x):
    return _q.dequant_fp16(x.reshape(-1)).reshape(x.shape)


def quant_int8(x, block_n: int = _q.DEFAULT_BLOCK_N):
    return _q.quant_int8(x.reshape(-1), block_n=block_n)


def dequant_int8(q, scales, block_n: int = _q.DEFAULT_BLOCK_N):
    return _q.dequant_int8(q, scales, block_n=block_n)


def fused_sgd(p, g, m, lr, momentum=0.9, nesterov=False):
    """Optimizer plug-in: nd-arrays, fp32 out, original shape preserved."""
    shape = p.shape
    po, mo = _fs.fused_sgd(p.reshape(-1), g.reshape(-1), m.reshape(-1), lr,
                           momentum=float(momentum), nesterov=bool(nesterov))
    return po.reshape(shape), mo.reshape(shape)


def fused_rs_update(recv, p, m, lr, *, wd_mask=None, scale=1.0,
                    momentum=0.9, nesterov=False, weight_decay=0.0,
                    scales=None):
    """RS->update fusion plug-in (``Optimizer.rs_fused_update``): un-summed
    (k, n) alltoall receives + flat shard (p, m) -> (p', m') fp32.

    ``scale`` is the mean divisor folded into the summation (1/k, or
    1/(k*microbatches) when accumulating); ``scales`` are the per-chunk
    int8 dequant scales for the ``asa8`` wire format."""
    mask = (jnp.zeros_like(p, jnp.float32) if wd_mask is None
            else wd_mask.astype(jnp.float32))
    return _fru.fused_rs_update(
        recv, p.reshape(-1), m.reshape(-1), mask.reshape(-1), lr,
        momentum=float(momentum), nesterov=bool(nesterov),
        scale=float(scale), weight_decay=float(weight_decay),
        scales=scales)
