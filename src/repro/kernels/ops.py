"""Jitted public wrappers over the Pallas kernels.

On this CPU container the kernels execute in ``interpret=True`` mode (Pallas
interpreter); ``REPRO_PALLAS_COMPILED=1`` switches to compiled mode on real
TPU. The wrappers match the exchanger/optimizer plug-in contracts.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels import chunk_sum as _cs
from repro.kernels import fused_sgd as _fs
from repro.kernels import quantize as _q

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILED", "0") != "1"


def chunk_sum(chunks, block_n: int = _cs.DEFAULT_BLOCK_N):
    """Exchanger ``sum_fn`` plug-in: (k, ...) -> (...) fp32.

    Flattens trailing dims to the kernel's (k, n) contract."""
    k = chunks.shape[0]
    flat = chunks.reshape(k, -1)
    out = _cs.chunk_sum(flat, block_n=block_n, interpret=INTERPRET)
    return out.reshape(chunks.shape[1:])


def quant_fp16(x):
    return _q.quant_fp16(x.reshape(-1), interpret=INTERPRET).reshape(x.shape)


def dequant_fp16(x):
    return _q.dequant_fp16(x.reshape(-1), interpret=INTERPRET).reshape(x.shape)


def quant_int8(x, block_n: int = _q.DEFAULT_BLOCK_N):
    return _q.quant_int8(x.reshape(-1), block_n=block_n, interpret=INTERPRET)


def dequant_int8(q, scales, block_n: int = _q.DEFAULT_BLOCK_N):
    return _q.dequant_int8(q, scales, block_n=block_n, interpret=INTERPRET)


def fused_sgd(p, g, m, lr, momentum=0.9, nesterov=False):
    """Optimizer plug-in: nd-arrays, fp32 out, original shape preserved."""
    shape = p.shape
    po, mo = _fs.fused_sgd(p.reshape(-1), g.reshape(-1), m.reshape(-1), lr,
                           momentum=float(momentum), nesterov=bool(nesterov),
                           interpret=INTERPRET)
    return po.reshape(shape), mo.reshape(shape)
