"""Pallas TPU kernels for low-precision exchange (paper §3.2 fp16; int8 is
the beyond-paper extension).

- ``quant_fp16`` / ``dequant_fp16``: cast kernels (the fp16 wire format).
- ``quant_int8`` / ``dequant_int8``: blockwise-absmax int8. Each block of
  ``block_n`` values gets one fp32 scale (scale = absmax/127) — tiling that
  maps 1:1 onto the VMEM block so the reduction never leaves the tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

DEFAULT_BLOCK_N = 2048


# ---------------------------------------------------------------------------
# fp16 cast kernels
# ---------------------------------------------------------------------------

def _cast_kernel(dtype):
    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...].astype(dtype)
    return kern


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def quant_fp16(x, *, block_n: int = DEFAULT_BLOCK_N,
               interpret: bool | None = None):
    interpret = resolve_interpret(interpret)
    (n,) = x.shape
    pad = (-n) % block_n
    xp = jnp.pad(x, (0, pad)) if pad else x
    out = pl.pallas_call(
        _cast_kernel(jnp.float16),
        grid=(xp.shape[0] // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float16),
        interpret=interpret,
    )(xp)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def dequant_fp16(x, *, block_n: int = DEFAULT_BLOCK_N,
                 interpret: bool | None = None):
    interpret = resolve_interpret(interpret)
    (n,) = x.shape
    pad = (-n) % block_n
    xp = jnp.pad(x, (0, pad)) if pad else x
    out = pl.pallas_call(
        _cast_kernel(jnp.float32),
        grid=(xp.shape[0] // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=interpret,
    )(xp)
    return out[:n]


# ---------------------------------------------------------------------------
# int8 blockwise kernels
# ---------------------------------------------------------------------------

def _quant_int8_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = jnp.full(s_ref.shape, scale, jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def quant_int8(x, *, block_n: int = DEFAULT_BLOCK_N,
               interpret: bool | None = None):
    """x: (n,) float -> (q: (n,) int8, scales: (n_blocks,) fp32)."""
    interpret = resolve_interpret(interpret)
    (n,) = x.shape
    pad = (-n) % block_n
    xp = jnp.pad(x, (0, pad)) if pad else x
    nb = xp.shape[0] // block_n
    q, s = pl.pallas_call(
        _quant_int8_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct(xp.shape, jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)],
        interpret=interpret,
    )(xp)
    return q[:n], s


def _dequant_int8_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def dequant_int8(q, scales, *, block_n: int = DEFAULT_BLOCK_N,
                 interpret: bool | None = None):
    interpret = resolve_interpret(interpret)
    (n,) = q.shape
    pad = (-n) % block_n
    qp = jnp.pad(q, (0, pad)) if pad else q
    out = pl.pallas_call(
        _dequant_int8_kernel,
        grid=(qp.shape[0] // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((1,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, jnp.float32),
        interpret=interpret,
    )(qp, scales)
    return out[:n]
