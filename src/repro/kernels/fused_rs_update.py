"""Pallas TPU kernel: fused reduce-scatter tail — dequant + fp32 chunk sum
+ momentum-SGD — on the local parameter shard in one VMEM pass.

After the alltoall leg of the RS half, each rank holds ``k`` low-precision
chunks of its shard. The unfused pipeline materializes the fp32 sum in HBM
(``chunk_sum``), then re-reads it together with (p, m) for the update
(``fused_sgd``). This kernel streams one (k, block_n) tile of receives plus
the matching (p, m, wd_mask) blocks through VMEM and emits (p', m')
directly:

    g  = scale * sum_k dequant(recv[k])        (fp32 accumulation)
    g += weight_decay * wd_mask * p
    m' = mu * m + g
    p' = p - lr * (g + mu * m')    (nesterov)
       = p - lr * m'               (classic)

``scale`` folds the data-parallel mean (1/k) and any microbatch-accumulation
mean (1/m) into the same pass. The int8 variant takes one fp32 scale per
rank chunk (the wire format of ``asa8``) and dequantizes in-register.

Parity-tested against ``default_chunk_sum`` + ``fused_sgd`` in
``tests/test_kernels.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

DEFAULT_BLOCK_N = 2048


def _update_tail(r, p_ref, m_ref, mask_ref, lr_ref, po_ref, mo_ref, *,
                 momentum, nesterov, scale, weight_decay):
    """Shared sum + momentum-SGD tail; ``r`` is the dequantized (k, bn)
    receive tile (plain function — Pallas inlines it into both variants)."""
    g = jnp.sum(r, axis=0) * scale
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * mask_ref[...] * p
    lr = lr_ref[0]
    m_new = momentum * m + g
    step = g + momentum * m_new if nesterov else m_new
    po_ref[...] = p - lr * step
    mo_ref[...] = m_new


def _kernel(recv_ref, p_ref, m_ref, mask_ref, lr_ref, po_ref, mo_ref,
            **statics):
    r = recv_ref[...].astype(jnp.float32)          # (k, block_n)
    _update_tail(r, p_ref, m_ref, mask_ref, lr_ref, po_ref, mo_ref,
                 **statics)


def _kernel_q(recv_ref, scales_ref, p_ref, m_ref, mask_ref, lr_ref,
              po_ref, mo_ref, **statics):
    r = recv_ref[...].astype(jnp.float32) * scales_ref[...]   # (k,bn)*(k,1)
    _update_tail(r, p_ref, m_ref, mask_ref, lr_ref, po_ref, mo_ref,
                 **statics)


@functools.partial(jax.jit,
                   static_argnames=("momentum", "nesterov", "scale",
                                    "weight_decay", "block_n", "interpret"))
def fused_rs_update(recv, p, m, mask, lr, *, momentum: float = 0.9,
                    nesterov: bool = False, scale: float = 1.0,
                    weight_decay: float = 0.0, scales=None,
                    block_n: int = DEFAULT_BLOCK_N,
                    interpret: bool | None = None):
    """recv: (k, n) float or int8 chunks; p/m/mask: (n,); scales: (k,) fp32
    per-chunk dequant scales (int8 wire) or None -> (p', m') fp32 (n,)."""
    interpret = resolve_interpret(interpret)
    k, n = recv.shape
    pad = (-n) % block_n
    if pad:
        recv = jnp.pad(recv, ((0, 0), (0, pad)))
        p = jnp.pad(p, (0, pad))
        m = jnp.pad(m, (0, pad))
        mask = jnp.pad(mask, (0, pad))
    lr_arr = jnp.asarray([lr], jnp.float32)
    npad = n + pad
    grid = (npad // block_n,)
    vec = pl.BlockSpec((block_n,), lambda i: (i,))
    common = dict(
        grid=grid,
        out_specs=[vec, vec],
        out_shape=[jax.ShapeDtypeStruct((npad,), jnp.float32),
                   jax.ShapeDtypeStruct((npad,), jnp.float32)],
        interpret=interpret,
    )
    statics = dict(momentum=momentum, nesterov=nesterov, scale=scale,
                   weight_decay=weight_decay)
    if scales is None:
        po, mo = pl.pallas_call(
            functools.partial(_kernel, **statics),
            in_specs=[pl.BlockSpec((k, block_n), lambda i: (0, i)),
                      vec, vec, vec, pl.BlockSpec((1,), lambda i: (0,))],
            **common,
        )(recv, p, m, mask, lr_arr)
    else:
        po, mo = pl.pallas_call(
            functools.partial(_kernel_q, **statics),
            in_specs=[pl.BlockSpec((k, block_n), lambda i: (0, i)),
                      pl.BlockSpec((k, 1), lambda i: (0, 0)),
                      vec, vec, vec, pl.BlockSpec((1,), lambda i: (0,))],
            **common,
        )(recv, scales.reshape(k, 1).astype(jnp.float32), p, m, mask, lr_arr)
    return po[:n], mo[:n]
