"""Pallas TPU kernel: the paper's ASA summation kernel.

After the Alltoall leg, each rank holds ``k`` low-precision chunks that must
be summed at full precision (paper §3.2: "summation kernels ... executed in
parallel on GPUs", "transfer at half precision while summing at full").

Kernel contract:  (k, n) chunks (any float dtype)  ->  (n,) float32 sum.

TPU adaptation: grid over ``n`` in VMEM-sized blocks; the whole ``k`` axis of
one block is resident in VMEM (k is the data-parallel degree, <= 32, so a
(k, block_n) tile of bf16 at block_n=2048 is ~128KB — comfortably in the
~16MB VMEM). Accumulation is fp32 inside the kernel regardless of the input
dtype, matching the paper's full-precision-summation requirement. The lane
dimension (block_n) is a multiple of 128 for VPU alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

DEFAULT_BLOCK_N = 2048


def _chunk_sum_kernel(x_ref, o_ref):
    # x_ref: (k, block_n) in VMEM; o_ref: (block_n,) fp32
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(x, axis=0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def chunk_sum(chunks, *, block_n: int = DEFAULT_BLOCK_N,
              interpret: bool | None = None):
    """Sum ``chunks`` (k, n) over axis 0 with fp32 accumulation -> (n,) f32.

    ``interpret=None`` auto-selects per backend: compiled on TPU, the
    Pallas interpreter elsewhere (CPU containers).
    """
    interpret = resolve_interpret(interpret)
    k, n = chunks.shape
    pad = (-n) % block_n
    if pad:
        chunks = jnp.pad(chunks, ((0, 0), (0, pad)))
    npad = n + pad
    grid = (npad // block_n,)
    out = pl.pallas_call(
        _chunk_sum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((k, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=interpret,
    )(chunks)
    return out[:n]
