"""Pallas TPU kernel: fused per-slot logit gather + sampling transform.

The serving engine's decode/prefill steps end with, per slot s:

    row_s   = logits[s, idx_s, :]                (gather the slot's token row)
    greedy  = argmax(row_s)
    sampled = argmax(row_s / T_s + gumbel_s)     (Gumbel-max == categorical)

The unfused pipeline materializes the gathered (S, V) rows in HBM, then
re-reads them twice (scale+noise, argmax). This kernel streams one
(S, C, block_v) logit tile through VMEM per grid step and carries the
running (max, argmax) for both the greedy and the noise-perturbed rows in
the revisited output vectors — logits are read exactly once. The gather is
a one-hot contraction over the chunk axis (C == 1 for decode steps,
C == prefill_chunk for the prefill tail), which maps onto the VPU instead
of a dynamic gather.

Top-k/top-p sampling needs a vocab sort and stays on the jnp path
(``repro.serve.sampling``); the kernel serves the greedy/temperature fast
path. Parity-tested against ``slot_gather_sample_ref`` in
``tests/test_kernels.py`` (shared noise makes the comparison exact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

NEG_INF = -1e30
DEFAULT_BLOCK_V = 512


def _kernel(lg_ref, oh_ref, t_ref, nz_ref, gv_ref, gi_ref, sv_ref, si_ref,
            *, block_v: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gv_ref[...] = jnp.full_like(gv_ref, NEG_INF)
        gi_ref[...] = jnp.zeros_like(gi_ref)
        sv_ref[...] = jnp.full_like(sv_ref, NEG_INF)
        si_ref[...] = jnp.zeros_like(si_ref)

    lg = lg_ref[...].astype(jnp.float32)            # (S, C, bv)
    oh = oh_ref[...]                                # (S, C) one-hot fp32
    row = jnp.sum(lg * oh[..., None], axis=1)       # (S, bv) gathered rows
    idx = jax.lax.broadcasted_iota(jnp.int32, row.shape, 1) + i * block_v

    def fold(vals, bv_ref, bi_ref):
        m = jnp.max(vals, axis=1)
        am = jnp.argmax(vals, axis=1).astype(jnp.int32)
        gidx = jnp.take_along_axis(idx, am[:, None], axis=1)[:, 0]
        better = m > bv_ref[...]                    # strict: first tile wins
        bi_ref[...] = jnp.where(better, gidx, bi_ref[...])
        bv_ref[...] = jnp.where(better, m, bv_ref[...])

    fold(row, gv_ref, gi_ref)
    t = jnp.maximum(t_ref[...], 1e-6)               # (S,)
    fold(row / t[:, None] + nz_ref[...], sv_ref, si_ref)


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def slot_gather_sample(logits, onehot, temperature, noise, *,
                       block_v: int = DEFAULT_BLOCK_V,
                       interpret: bool | None = None):
    """logits: (S, C, V); onehot: (S, C) fp32 selecting each slot's token
    row; temperature: (S,) fp32; noise: (S, V) fp32 Gumbel.

    Returns (greedy (S,), sampled (S,)) int32 — the argmax of each slot's
    gathered row and of its temperature-scaled noise-perturbed row."""
    interpret = resolve_interpret(interpret)
    S, C, V = logits.shape
    pad = (-V) % block_v
    if pad:
        logits = jnp.pad(logits, ((0, 0), (0, 0), (0, pad)),
                         constant_values=NEG_INF)
        noise = jnp.pad(noise, ((0, 0), (0, pad)))
    vp = V + pad
    grid = (vp // block_v,)
    vec = pl.BlockSpec((S,), lambda i: (0,))
    gv, gi, sv, si = pl.pallas_call(
        functools.partial(_kernel, block_v=block_v),
        grid=grid,
        in_specs=[pl.BlockSpec((S, C, block_v), lambda i: (0, 0, i)),
                  pl.BlockSpec((S, C), lambda i: (0, 0)),
                  vec,
                  pl.BlockSpec((S, block_v), lambda i: (0, i))],
        out_specs=[vec, vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((S,), jnp.float32),
                   jax.ShapeDtypeStruct((S,), jnp.int32),
                   jax.ShapeDtypeStruct((S,), jnp.float32),
                   jax.ShapeDtypeStruct((S,), jnp.int32)],
        interpret=interpret,
    )(logits, onehot.astype(jnp.float32), temperature.astype(jnp.float32),
      noise.astype(jnp.float32))
    del gv, sv
    return gi, si
