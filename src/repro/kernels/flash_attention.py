"""Pallas flash-attention kernel family: the one attention hot path.

Tiled attention in the FlashAttention style (PAPERS.md: "FlashAttention:
Fast and Memory-Efficient Exact Attention with IO-Awareness") for every
attention site in the repo — train forward/backward, chunked prefill, and
(B,1) decode — so no path ever materializes an (S, S) score matrix in HBM.

Contracts shared by the whole family:

- **GQA grouping inside the kernel.** q is (B, Sq, H, Dk) and k/v are
  (B, Sk, KV, Dk/Dv) with G = H // KV query heads per kv head; the grid
  iterates (batch, kv_head, ...) and each q tile carries its group's G
  heads as extra rows of the score matmul ((block_q*G, block_k) on the
  MXU), so k/v are never repeated across query heads in HBM. KV=1 with
  Dk != Dv is the MLA absorbed-matmul layout (q/k in the latent+rope
  space, v = the latent itself).
- **fp32 online softmax, bf16/fp16 I/O.** Scores, the running (m, l)
  statistics and the output accumulator live in fp32 VMEM scratch;
  q/k/v/out move through HBM in the model's compute dtype.
- **Residuals are (out, lse).** The forward saves only the output and the
  per-row log-sum-exp (B, Sq, H) — the backward recomputes p tile-wise
  from (q, k, lse), never storing probabilities. This is the
  residual/VJP convention later fused kernels follow.
- **Masking = causal + sliding window + ragged tails.** Causality is
  evaluated against absolute positions ``q_off[b] + row`` (q_off=0 for
  train, the chunk start for prefill, the per-slot position vector for
  decode), so one kernel serves all three paths; ``window`` may be a
  traced scalar (per-layer windows inside layer scans). Key tiles
  entirely above the causal diagonal are skipped. Rows/keys padded up to
  the tile size are masked out (keys) or sliced off (rows).

Execution mode follows the package policy (compiled on TPU, interpreter
elsewhere, ``REPRO_PALLAS_INTERPRET`` override); parity against the
einsum oracles is pinned in ``tests/test_flash_attention.py``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
DEFAULT_DECODE_BLOCK_K = 512


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _scratch(shape):
    return pltpu.VMEM(shape, jnp.float32)


def _dot(a, b, trans_b: bool = False):
    dims = (((1,), (1,)), ((), ())) if trans_b else (((1,), (0,)), ((), ()))
    return jax.lax.dot_general(a, b, dims,
                               preferred_element_type=jnp.float32)


def _mask(keep_shape, i, j, q_off, window, kv_len, block_q, block_k,
          groups):
    """(rows, block_k) keep mask. Row r holds (q index r//G, group r%G)."""
    r = jax.lax.broadcasted_iota(jnp.int32, keep_shape, 0)
    c = jax.lax.broadcasted_iota(jnp.int32, keep_shape, 1)
    qpos = q_off + i * block_q + r // groups
    kpos = j * block_k + c
    keep = (kpos <= qpos) & (kpos < kv_len)
    dist = qpos - kpos
    return keep & ((window <= 0) | (dist < window))


def _tile_live(i, j, q_off, window, block_q, block_k):
    """Whether key tile j can contribute to q tile i: not entirely above
    the causal diagonal, and (for sliding windows) not entirely older than
    the window of the tile's oldest query. Exact — a skipped tile's mask
    is all-False, so every pruned contribution was a 0. Makes windowed
    attention's grid work linear in S instead of quadratic."""
    causal = j * block_k <= q_off + (i + 1) * block_q - 1
    in_window = (window <= 0) | (
        (j + 1) * block_k > q_off + i * block_q - window + 1)
    return causal & in_window


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(qoff_ref, win_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *, sm_scale, kv_len, block_q,
                block_k, groups):
    i, j = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)
    rows = block_q * groups
    q_off = qoff_ref[0, 0]
    win = win_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(_tile_live(i, j, q_off, win, block_q, block_k))
    def _compute():
        q = q_ref[...].reshape(rows, q_ref.shape[-1])
        k = k_ref[...].reshape(block_k, k_ref.shape[-1])
        s = _dot(q, k, trans_b=True) * sm_scale          # (rows, bk) fp32
        keep = _mask(s.shape, i, j, q_off, win, kv_len, block_q, block_k,
                     groups)
        s = jnp.where(keep, s, NEG_INF)
        m_prev = m_scr[...][:, :1]
        l_prev = l_scr[...][:, :1]
        m_next = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # explicit zeroing: when every key so far is masked m_next is still
        # NEG_INF and exp(s - m_next) would be 1, not 0
        p = jnp.where(keep, jnp.exp(s - m_next), 0.0)
        alpha = jnp.exp(m_prev - m_next)
        l_next = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_next, l_scr.shape)
        v = v_ref[...].reshape(block_k, v_ref.shape[-1])
        acc_scr[...] = acc_scr[...] * alpha + _dot(p.astype(v.dtype), v)

    @pl.when(j == nk - 1)
    def _store():
        l = l_scr[...][:, :1]
        m = m_scr[...][:, :1]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)
        lse = m[:, 0] + jnp.log(jnp.maximum(l[:, 0], 1e-30))
        lse_ref[...] = lse.reshape(lse_ref.shape)


def _fwd_call(q, k, v, q_off, window, sm_scale, kv_len, block_q, block_k,
              interpret):
    B, Sq, H, Dk = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    grid = (B, KV, Sq // block_q, Sk // block_k)
    rows = block_q * G
    q_spec = pl.BlockSpec((1, block_q, G, Dk), lambda b, h, i, j: (b, i, h, 0))
    kv = lambda d: pl.BlockSpec((1, block_k, 1, d),
                                lambda b, h, i, j: (b, j, h, 0))
    scalar = lambda im: pl.BlockSpec((1, 1), im)
    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, sm_scale=sm_scale, kv_len=kv_len,
                          block_q=block_q, block_k=block_k, groups=G),
        grid=grid,
        in_specs=[scalar(lambda b, h, i, j: (b, 0)),
                  scalar(lambda b, h, i, j: (0, 0)),
                  q_spec, kv(Dk), kv(Dv)],
        out_specs=[pl.BlockSpec((1, block_q, G, Dv),
                                lambda b, h, i, j: (b, i, h, 0)),
                   pl.BlockSpec((1, block_q, G),
                                lambda b, h, i, j: (b, i, h))],
        out_shape=[jax.ShapeDtypeStruct((B, Sq, H, Dv), q.dtype),
                   jax.ShapeDtypeStruct((B, Sq, H), jnp.float32)],
        scratch_shapes=[_scratch((rows, 128)), _scratch((rows, 128)),
                        _scratch((rows, Dv))],
        interpret=interpret,
    )(q_off, window, q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# backward (dq and dkv kernels; p recomputed tile-wise from lse)
# ---------------------------------------------------------------------------

def _dq_kernel(qoff_ref, win_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
               di_ref, dq_ref, dq_scr, *, sm_scale, kv_len, block_q,
               block_k, groups):
    i, j = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)
    rows = block_q * groups
    q_off = qoff_ref[0, 0]
    win = win_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    @pl.when(_tile_live(i, j, q_off, win, block_q, block_k))
    def _compute():
        q = q_ref[...].reshape(rows, q_ref.shape[-1])
        k = k_ref[...].reshape(block_k, k_ref.shape[-1])
        v = v_ref[...].reshape(block_k, v_ref.shape[-1])
        do = do_ref[...].reshape(rows, do_ref.shape[-1])
        lse = lse_ref[...].reshape(rows, 1)
        di = di_ref[...].reshape(rows, 1)
        s = _dot(q, k, trans_b=True) * sm_scale
        keep = _mask(s.shape, i, j, q_off, win, kv_len, block_q, block_k,
                     groups)
        s = jnp.where(keep, s, NEG_INF)
        p = jnp.exp(s - lse)                         # masked -> exp(-inf)=0
        dp = _dot(do, v, trans_b=True)               # (rows, bk)
        ds = p * (dp - di) * sm_scale
        dq_scr[...] = dq_scr[...] + _dot(ds.astype(k.dtype), k)

    @pl.when(j == nk - 1)
    def _store():
        dq_ref[...] = dq_scr[...].reshape(dq_ref.shape).astype(dq_ref.dtype)


def _dkv_kernel(qoff_ref, win_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                di_ref, dk_ref, dv_ref, dk_scr, dv_scr, *, sm_scale,
                kv_len, block_q, block_k, groups):
    j, i = pl.program_id(2), pl.program_id(3)      # kv tile j, q tile i
    nq = pl.num_programs(3)
    rows = block_q * groups
    q_off = qoff_ref[0, 0]
    win = win_ref[0, 0]

    @pl.when(i == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(_tile_live(i, j, q_off, win, block_q, block_k))
    def _compute():
        q = q_ref[...].reshape(rows, q_ref.shape[-1])
        k = k_ref[...].reshape(block_k, k_ref.shape[-1])
        v = v_ref[...].reshape(block_k, v_ref.shape[-1])
        do = do_ref[...].reshape(rows, do_ref.shape[-1])
        lse = lse_ref[...].reshape(rows, 1)
        di = di_ref[...].reshape(rows, 1)
        s = _dot(q, k, trans_b=True) * sm_scale
        keep = _mask(s.shape, i, j, q_off, win, kv_len, block_q, block_k,
                     groups)
        s = jnp.where(keep, s, NEG_INF)
        p = jnp.exp(s - lse)
        # contract over the rows axis: the G grouped query heads fold into
        # the same dk/dv tile, which is exactly the GQA gradient
        dv_scr[...] = dv_scr[...] + jax.lax.dot_general(
            p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = _dot(do, v, trans_b=True)
        ds = (p * (dp - di) * sm_scale).astype(q.dtype)
        dk_scr[...] = dk_scr[...] + jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(i == nq - 1)
    def _store():
        dk_ref[...] = dk_scr[...].reshape(dk_ref.shape).astype(dk_ref.dtype)
        dv_ref[...] = dv_scr[...].reshape(dv_ref.shape).astype(dv_ref.dtype)


def _bwd_call(q, k, v, q_off, window, out, lse, do, sm_scale, kv_len,
              block_q, block_k, interpret):
    B, Sq, H, Dk = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    rows = block_q * G
    di = jnp.sum(out.astype(jnp.float32) * do.astype(jnp.float32),
                 axis=-1)                                   # (B, Sq, H)
    scalar = lambda im: pl.BlockSpec((1, 1), im)
    kv_spec = lambda d, im: pl.BlockSpec((1, block_k, 1, d), im)
    row_spec = lambda d, im: pl.BlockSpec((1, block_q, G, d), im)
    vec_spec = lambda im: pl.BlockSpec((1, block_q, G), im)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, sm_scale=sm_scale, kv_len=kv_len,
                          block_q=block_q, block_k=block_k, groups=G),
        grid=(B, KV, Sq // block_q, Sk // block_k),
        in_specs=[scalar(lambda b, h, i, j: (b, 0)),
                  scalar(lambda b, h, i, j: (0, 0)),
                  row_spec(Dk, lambda b, h, i, j: (b, i, h, 0)),
                  kv_spec(Dk, lambda b, h, i, j: (b, j, h, 0)),
                  kv_spec(Dv, lambda b, h, i, j: (b, j, h, 0)),
                  row_spec(Dv, lambda b, h, i, j: (b, i, h, 0)),
                  vec_spec(lambda b, h, i, j: (b, i, h)),
                  vec_spec(lambda b, h, i, j: (b, i, h))],
        out_specs=row_spec(Dk, lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[_scratch((rows, Dk))],
        interpret=interpret,
    )(q_off, window, q, k, v, do, lse, di)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, sm_scale=sm_scale, kv_len=kv_len,
                          block_q=block_q, block_k=block_k, groups=G),
        grid=(B, KV, Sk // block_k, Sq // block_q),
        in_specs=[scalar(lambda b, h, j, i: (b, 0)),
                  scalar(lambda b, h, j, i: (0, 0)),
                  row_spec(Dk, lambda b, h, j, i: (b, i, h, 0)),
                  kv_spec(Dk, lambda b, h, j, i: (b, j, h, 0)),
                  kv_spec(Dv, lambda b, h, j, i: (b, j, h, 0)),
                  row_spec(Dv, lambda b, h, j, i: (b, i, h, 0)),
                  vec_spec(lambda b, h, j, i: (b, i, h)),
                  vec_spec(lambda b, h, j, i: (b, i, h))],
        out_specs=[kv_spec(Dk, lambda b, h, j, i: (b, j, h, 0)),
                   kv_spec(Dv, lambda b, h, j, i: (b, j, h, 0))],
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        scratch_shapes=[_scratch((block_k, Dk)), _scratch((block_k, Dv))],
        interpret=interpret,
    )(q_off, window, q, k, v, do, lse, di)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom VJP over the padded core
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _flash(q, k, v, q_off, window, sm_scale, kv_len, block_q, block_k,
           interpret):
    return _fwd_call(q, k, v, q_off, window, sm_scale, kv_len, block_q,
                     block_k, interpret)


def _flash_fwd(q, k, v, q_off, window, sm_scale, kv_len, block_q, block_k,
               interpret):
    out, lse = _fwd_call(q, k, v, q_off, window, sm_scale, kv_len, block_q,
                         block_k, interpret)
    return (out, lse), (q, k, v, q_off, window, out, lse)


def _flash_bwd(sm_scale, kv_len, block_q, block_k, interpret, res, cts):
    q, k, v, q_off, window, out, lse = res
    do, _ = cts          # the lse output is a residual, not a model output
    dq, dk, dv = _bwd_call(q, k, v, q_off, window, out, lse, do, sm_scale,
                           kv_len, block_q, block_k, interpret)
    zero = lambda x: np.zeros(x.shape, dtype=jax.dtypes.float0)
    return dq, dk, dv, zero(q_off), zero(window)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, q_off=None, window=0, sm_scale=None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool | None = None, return_lse: bool = False):
    """Fused tiled attention. q: (B, Sq, H, Dk); k: (B, Sk, KV, Dk);
    v: (B, Sk, KV, Dv) with H % KV == 0. Returns (B, Sq, H, Dv) [+ lse
    (B, Sq, H) fp32 when ``return_lse``; do not differentiate through lse].

    ``q_off``: absolute position of q row 0 — None/scalar/(B,) vector
    (train / chunked prefill / per-slot decode). ``window``: sliding
    window (<=0 = plain causal), python int or traced scalar. ``sm_scale``
    defaults to 1/sqrt(Dk). Ragged Sq/Sk are padded to the tile size
    internally; padded keys are masked, padded rows sliced off."""
    B, Sq, H, Dk = q.shape
    _, Sk, KV, _ = k.shape
    if H % KV:
        raise ValueError(f"H={H} not divisible by KV={KV}")
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(Dk)
    interpret = resolve_interpret(interpret)
    block_q = min(block_q, _round_up(Sq, 16))
    block_k = min(block_k, _round_up(Sk, 16))
    pq, pk = _round_up(Sq, block_q) - Sq, _round_up(Sk, block_k) - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    if q_off is None:
        q_off = jnp.zeros((B, 1), jnp.int32)
    else:
        q_off = jnp.broadcast_to(
            jnp.asarray(q_off, jnp.int32).reshape(-1, 1), (B, 1))
    window = jnp.asarray(window, jnp.int32).reshape(1, 1)
    out, lse = _flash(q, k, v, q_off, window, float(sm_scale), Sk,
                      block_q, block_k, interpret)
    out = out[:, :Sq]
    if not return_lse:
        return out
    # lse is a residual, not a differentiable output — the VJP discards
    # its cotangent, so enforce the contract rather than return silent
    # zero gradients to anyone who puts lse in a loss
    return out, jax.lax.stop_gradient(lse[:, :Sq])


# ---------------------------------------------------------------------------
# split-KV decode
# ---------------------------------------------------------------------------

def _decode_kernel(pos_ref, win_ref, q_ref, k_ref, v_ref, m_ref, l_ref,
                   acc_ref, *, sm_scale, kv_len, block_k, groups):
    j = pl.program_id(2)
    pos = pos_ref[0, 0]
    win = win_ref[0, 0]
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(_tile_live(0, j, pos, win, 1, block_k))
    def _compute():
        q = q_ref[...].reshape(groups, q_ref.shape[-1])
        k = k_ref[...].reshape(block_k, k_ref.shape[-1])
        v = v_ref[...].reshape(block_k, v_ref.shape[-1])
        s = _dot(q, k, trans_b=True) * sm_scale          # (G, bk)
        c = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        kpos = j * block_k + c
        keep = (kpos <= pos) & (kpos < kv_len)
        keep &= (win <= 0) | (pos - kpos < win)
        s = jnp.where(keep, s, NEG_INF)
        m = jnp.max(s, axis=1, keepdims=True)
        p = jnp.where(keep, jnp.exp(s - m), 0.0)
        m_ref[...] = jnp.broadcast_to(m[:, 0].reshape(m_ref.shape),
                                      m_ref.shape)
        l_ref[...] = jnp.sum(p, axis=1).reshape(l_ref.shape)
        acc_ref[...] = _dot(p.astype(v.dtype), v).reshape(acc_ref.shape)


def flash_decode(q, k, v, pos, *, window=0, sm_scale=None,
                 block_k: int = DEFAULT_DECODE_BLOCK_K,
                 interpret: bool | None = None):
    """Split-KV single-token decode. q: (B, 1, H, Dk); k/v: the full
    (B, S, KV, Dk/Dv) cache lanes; pos: scalar or (B,) per-slot positions
    (``decode_keep`` semantics: key t visible iff t <= pos[b] and within
    the window). The cache splits into ``ceil(S / block_k)`` independent
    key chunks — each computes a partial (m, l, acc) in one grid cell, and
    the partials merge with the standard online-softmax combine, so long
    caches parallelize across chunks instead of serializing through one
    accumulator. Returns (B, 1, H, Dv)."""
    B, Sq, H, Dk = q.shape
    _, S, KV, _ = k.shape
    Dv = v.shape[-1]
    if Sq != 1:
        raise ValueError(f"flash_decode wants a single query row, Sq={Sq}")
    if H % KV:
        raise ValueError(f"H={H} not divisible by KV={KV}")
    G = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(Dk)
    interpret = resolve_interpret(interpret)
    block_k = min(block_k, _round_up(S, 16))
    pk = _round_up(S, block_k) - S
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    ns = (S + pk) // block_k
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1, 1),
                           (B, 1))
    window = jnp.asarray(window, jnp.int32).reshape(1, 1)
    scalar = lambda im: pl.BlockSpec((1, 1), im)
    m, l, acc = pl.pallas_call(
        functools.partial(_decode_kernel, sm_scale=float(sm_scale),
                          kv_len=S, block_k=block_k, groups=G),
        grid=(B, KV, ns),
        in_specs=[scalar(lambda b, h, j: (b, 0)),
                  scalar(lambda b, h, j: (0, 0)),
                  pl.BlockSpec((1, 1, G, Dk), lambda b, h, j: (b, 0, h, 0)),
                  pl.BlockSpec((1, block_k, 1, Dk),
                               lambda b, h, j: (b, j, h, 0)),
                  pl.BlockSpec((1, block_k, 1, Dv),
                               lambda b, h, j: (b, j, h, 0))],
        out_specs=[pl.BlockSpec((1, 1, 1, G), lambda b, h, j: (b, h, j, 0)),
                   pl.BlockSpec((1, 1, 1, G), lambda b, h, j: (b, h, j, 0)),
                   pl.BlockSpec((1, 1, 1, G, Dv),
                                lambda b, h, j: (b, h, j, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, KV, ns, G), jnp.float32),
                   jax.ShapeDtypeStruct((B, KV, ns, G), jnp.float32),
                   jax.ShapeDtypeStruct((B, KV, ns, G, Dv), jnp.float32)],
        interpret=interpret,
    )(pos, window, q, k, v)
    return _combine_kv_splits(m, l, acc).astype(q.dtype)


def _combine_kv_splits(m, l, acc):
    """Online-softmax combine across independent KV splits: partials
    m/l (B, KV, ns, G) and acc (B, KV, ns, G, Dv) -> (B, 1, H, Dv) fp32.
    Shared by the contiguous (``flash_decode``) and paged
    (``flash_decode_paged``) split-KV kernels — a dead split's neutral
    partial (m=NEG_INF, l=0, acc=0) drops out exactly."""
    B, KV, _, G = m.shape
    Dv = acc.shape[-1]
    m_g = jnp.max(m, axis=2, keepdims=True)                  # (B,KV,1,G)
    alpha = jnp.exp(m - m_g)
    l_g = jnp.sum(alpha * l, axis=2)                         # (B,KV,G)
    out = jnp.sum(alpha[..., None] * acc, axis=2)            # (B,KV,G,Dv)
    out = out / jnp.maximum(l_g, 1e-30)[..., None]
    return out.reshape(B, 1, KV * G, Dv)


def _decode_paged_kernel(tbl_ref, pos_ref, win_ref, q_ref, k_ref, v_ref,
                         m_ref, l_ref, acc_ref, *, sm_scale, page_size,
                         groups):
    del tbl_ref                 # consumed by the BlockSpec index_maps
    b, j = pl.program_id(0), pl.program_id(2)
    pos = pos_ref[b]
    win = win_ref[0]
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    # page j holds logical rows [j*ps, (j+1)*ps); same liveness pruning as
    # the contiguous split-KV kernel with block_k = page_size
    @pl.when(_tile_live(0, j, pos, win, 1, page_size))
    def _compute():
        q = q_ref[...].reshape(groups, q_ref.shape[-1])
        k = k_ref[...].reshape(page_size, k_ref.shape[-1])
        v = v_ref[...].reshape(page_size, v_ref.shape[-1])
        s = _dot(q, k, trans_b=True) * sm_scale          # (G, ps)
        c = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        kpos = j * page_size + c
        keep = kpos <= pos
        keep &= (win <= 0) | (pos - kpos < win)
        s = jnp.where(keep, s, NEG_INF)
        m = jnp.max(s, axis=1, keepdims=True)
        p = jnp.where(keep, jnp.exp(s - m), 0.0)
        m_ref[...] = jnp.broadcast_to(m[:, 0].reshape(m_ref.shape),
                                      m_ref.shape)
        l_ref[...] = jnp.sum(p, axis=1).reshape(l_ref.shape)
        acc_ref[...] = _dot(p.astype(v.dtype), v).reshape(acc_ref.shape)


def flash_decode_paged(q, k_pages, v_pages, tables, pos, *, page_size: int,
                       window=0, sm_scale=None,
                       interpret: bool | None = None):
    """Split-KV decode over a *paged* cache: the grid's chunk axis walks
    each slot's block table one page per chunk, and the K/V BlockSpec
    index_maps read the physical page id from the scalar-prefetched table
    (``pltpu.PrefetchScalarGridSpec``), so page fetch is table-indexed
    inside the kernel — no gathered lane ever materializes in HBM. The
    compiled program is one trace for any table contents (tables/pos enter
    as same-shaped int32 inputs), preserving the engine's compile-once
    guarantee under request churn.

    q: (B, 1, H, Dk); k_pages/v_pages: (P, page_size, KV, Dk/Dv) physical
    pages; tables: (B, NP) int32 page ids (logical page j of slot b is
    physical page tables[b, j]); pos: (B,) per-slot positions. Pages at
    logical index > pos // page_size are skipped with neutral partials
    exactly like dead KV chunks in ``flash_decode`` — whatever stale page
    the table maps there (typically the null page 0) is never read into
    the combine. Returns (B, 1, H, Dv).

    Math is bit-identical to ``flash_decode(q, gather(k_pages, tables),
    ..., block_k=page_size)``: same per-page partials, same combine."""
    B, Sq, H, Dk = q.shape
    P_, ps, KV, _ = k_pages.shape
    Dv = v_pages.shape[-1]
    if Sq != 1:
        raise ValueError(f"flash_decode_paged wants one query row, Sq={Sq}")
    if ps != page_size:
        raise ValueError(f"page dim {ps} != page_size {page_size}")
    if H % KV:
        raise ValueError(f"H={H} not divisible by KV={KV}")
    G = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(Dk)
    interpret = resolve_interpret(interpret)
    NP = tables.shape[-1]
    tables = jnp.asarray(tables, jnp.int32).reshape(B, NP)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    window = jnp.asarray(window, jnp.int32).reshape(1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,       # tables, pos, window
        grid=(B, KV, NP),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dk),
                         lambda b, h, j, tbl, pv, win: (b, 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, Dk),
                         lambda b, h, j, tbl, pv, win: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, Dv),
                         lambda b, h, j, tbl, pv, win: (tbl[b, j], 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, G),
                         lambda b, h, j, tbl, pv, win: (b, h, j, 0)),
            pl.BlockSpec((1, 1, 1, G),
                         lambda b, h, j, tbl, pv, win: (b, h, j, 0)),
            pl.BlockSpec((1, 1, 1, G, Dv),
                         lambda b, h, j, tbl, pv, win: (b, h, j, 0, 0)),
        ],
    )
    m, l, acc = pl.pallas_call(
        functools.partial(_decode_paged_kernel, sm_scale=float(sm_scale),
                          page_size=page_size, groups=G),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, KV, NP, G), jnp.float32),
                   jax.ShapeDtypeStruct((B, KV, NP, G), jnp.float32),
                   jax.ShapeDtypeStruct((B, KV, NP, G, Dv), jnp.float32)],
        interpret=interpret,
    )(tables, pos, window, q, k_pages, v_pages)
    return _combine_kv_splits(m, l, acc).astype(q.dtype)
