"""Pure-jnp oracles for every Pallas kernel (allclose-tested in
tests/test_kernels.py and used as the CPU fallback path)."""
from __future__ import annotations

import jax.numpy as jnp


def chunk_sum_ref(chunks):
    """(k, n) -> (n,) fp32 sum."""
    return jnp.sum(chunks.astype(jnp.float32), axis=0)


def quant_fp16_ref(x):
    return x.astype(jnp.float16)


def dequant_fp16_ref(x):
    return x.astype(jnp.float32)


def quant_int8_ref(x, block_n: int = 2048):
    (n,) = x.shape
    pad = (-n) % block_n
    xp = jnp.pad(x, (0, pad)) if pad else x
    blocks = xp.reshape(-1, block_n).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:n], scale


def dequant_int8_ref(q, scales, block_n: int = 2048):
    (n,) = q.shape
    pad = (-n) % block_n
    qp = jnp.pad(q, (0, pad)) if pad else q
    blocks = qp.reshape(-1, block_n).astype(jnp.float32)
    out = blocks * scales[:, None]
    return out.reshape(-1)[:n]


def fused_sgd_ref(p, g, m, lr, momentum: float = 0.9, nesterov: bool = False):
    p = p.astype(jnp.float32)
    g = g.astype(jnp.float32)
    m = m.astype(jnp.float32)
    m_new = momentum * m + g
    step = g + momentum * m_new if nesterov else m_new
    return p - lr * step, m_new


def slot_gather_sample_ref(logits, onehot, temperature, noise):
    """(S,C,V) logits + (S,C) one-hot + (S,) temps + (S,V) Gumbel noise ->
    (greedy (S,), sampled (S,)) int32 (Gumbel-max temperature sampling)."""
    row = jnp.einsum("scv,sc->sv", logits.astype(jnp.float32),
                     onehot.astype(jnp.float32))
    greedy = jnp.argmax(row, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperature.astype(jnp.float32), 1e-6)
    sampled = jnp.argmax(row / t[:, None] + noise.astype(jnp.float32),
                         axis=-1).astype(jnp.int32)
    return greedy, sampled


def fused_rs_update_ref(recv, p, m, mask, lr, momentum: float = 0.9,
                        nesterov: bool = False, scale: float = 1.0,
                        weight_decay: float = 0.0, scales=None):
    """(k, n) chunks [+ (k,) int8 scales] -> fused mean + SGD on the shard."""
    r = recv.astype(jnp.float32)
    if scales is not None:
        r = r * scales.reshape(-1, 1).astype(jnp.float32)
    g = jnp.sum(r, axis=0) * scale
    p = p.astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * mask.astype(jnp.float32) * p
    return fused_sgd_ref(p, g, m, lr, momentum, nesterov)
