# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Pallas kernels and their execution-mode policy.

Every kernel takes ``interpret: bool | None``; ``None`` (the default)
resolves via :func:`default_interpret` — compiled on TPU, the Pallas
interpreter elsewhere — overridable per-process with
``REPRO_PALLAS_INTERPRET=0|1`` (or the legacy ``REPRO_PALLAS_COMPILED=1``).
"""
from __future__ import annotations

import os


def default_interpret() -> bool:
    """Whether Pallas kernels should run in interpreter mode.

    Priority: ``REPRO_PALLAS_INTERPRET`` env (0/1) > legacy
    ``REPRO_PALLAS_COMPILED=1`` > backend autodetect (compiled only on
    TPU — the interpreter is the only Pallas path on CPU hosts)."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    if os.environ.get("REPRO_PALLAS_COMPILED", "0") == "1":
        return False
    import jax
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    return default_interpret() if interpret is None else bool(interpret)
