"""Pallas TPU kernel: fused momentum-SGD update.

The parameter update after exchange touches p, g, m once each; unfused XLA
may materialize intermediates in HBM. This kernel streams (p, g, m) blocks
through VMEM and writes (p', m') in a single pass:

    m' = mu * m + g
    p' = p - lr * (g + mu * m')    (nesterov)
       = p - lr * m'               (classic)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

DEFAULT_BLOCK_N = 4096


def _fused_sgd_kernel(p_ref, g_ref, m_ref, lr_ref, po_ref, mo_ref, *,
                      momentum: float, nesterov: bool):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    lr = lr_ref[0]
    m_new = momentum * m + g
    step = g + momentum * m_new if nesterov else m_new
    po_ref[...] = p - lr * step
    mo_ref[...] = m_new


@functools.partial(jax.jit,
                   static_argnames=("momentum", "nesterov", "block_n",
                                    "interpret"))
def fused_sgd(p, g, m, lr, *, momentum: float = 0.9, nesterov: bool = False,
              block_n: int = DEFAULT_BLOCK_N, interpret: bool | None = None):
    """Flat fused update. p/g/m: (n,) -> (p', m') fp32.

    ``interpret=None`` auto-selects per backend (compiled on TPU)."""
    interpret = resolve_interpret(interpret)
    (n,) = p.shape
    pad = (-n) % block_n
    if pad:
        p = jnp.pad(p, (0, pad))
        g = jnp.pad(g, (0, pad))
        m = jnp.pad(m, (0, pad))
    lr_arr = jnp.asarray([lr], jnp.float32)
    kern = functools.partial(_fused_sgd_kernel, momentum=momentum,
                             nesterov=nesterov)
    po, mo = pl.pallas_call(
        kern,
        grid=(p.shape[0] // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct(p.shape, jnp.float32),
                   jax.ShapeDtypeStruct(p.shape, jnp.float32)],
        interpret=interpret,
    )(p, g, m, lr_arr)
    return po[:n], mo[:n]
