"""Map the jax>=0.5 API surface this codebase is written against onto the
jax 0.4.x actually installed.

The training/serving code (and the subprocess scripts embedded in the test
suite) use three symbols that moved or appeared after 0.4.37:

- ``jax.shard_map``       (0.4.x: ``jax.experimental.shard_map.shard_map``,
                           with ``auto=`` instead of ``axis_names=`` and
                           ``check_rep=`` instead of ``check_vma=``)
- ``jax.set_mesh``        (0.4.x: the ``Mesh`` context manager)
- ``jax.lax.axis_size``   (0.4.x: ``lax.psum(1, axis)`` — statically folded
                           for literal operands, so it stays a python int)

``install()`` is idempotent and a no-op for any symbol the running jax
already provides; it is called from ``repro/__init__.py`` so every
entrypoint — pytest, benchmarks, and the ``python -c`` subprocess dry-runs —
sees one consistent API.
"""
from __future__ import annotations

import jax


def _shim_axis_size() -> None:
    if hasattr(jax.lax, "axis_size"):
        return

    def axis_size(axis_name):
        """Size of a named mapped axis (static: psum folds literal ints)."""
        return jax.lax.psum(1, axis_name)

    jax.lax.axis_size = axis_size


def _shim_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma: bool = True):
        # jax>=0.5 treats mesh axes not in ``axis_names`` as auto (GSPMD)
        # axes. 0.4.x "partial-auto" is unusable for our BSP path: jaxlib
        # 0.4.36's SPMD partitioner aborts (IsManualSubgroup check) when a
        # manual-subgroup collective — the exchangers' all_to_all/all_gather
        # over 'data' — consumes any auto-sharded operand. Go fully manual
        # instead: specs never mention the extra axes, so inputs/outputs are
        # replicated over them and the body computes identically on every
        # slice — the paper's replicated data parallelism, with the model
        # axis idle inside shard_map on 0.4.x.
        del axis_names
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=bool(check_vma),
                          auto=frozenset())

    jax.shard_map = shard_map


def _shim_set_mesh() -> None:
    if hasattr(jax, "set_mesh"):
        return

    def set_mesh(mesh) -> None:
        """Enter ``mesh`` as the ambient mesh for the rest of the process.

        0.4.x has no global setter; pushing the ``Mesh`` context (and never
        popping) gives the same observable behaviour: bare ``PartitionSpec``s
        in ``with_sharding_constraint`` resolve against the latest mesh."""
        mesh.__enter__()

    jax.set_mesh = set_mesh


def install() -> None:
    _shim_axis_size()
    _shim_shard_map()
    _shim_set_mesh()
