"""Checkpointing: pytree <-> sharded .npz directory.

Flat key = '/'-joined tree path. Restore rebuilds onto the target sharding
(device_put against the existing state's shardings), so checkpoints travel
across mesh configurations.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}/{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def save_checkpoint(path: str, state, step: int | None = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)

    def to_np(v):
        a = np.asarray(v) if not hasattr(v, "dtype") or v.dtype !=             jax.numpy.bfloat16 else np.asarray(v, np.float32)
        return a
    arrays = {k: to_np(v) for k, v in flat.items()}
    np.savez(os.path.join(path, "state.npz"), **arrays)
    meta = {"step": int(step) if step is not None else 0,
            "keys": sorted(arrays.keys())}
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def restore_checkpoint(path: str, state_like):
    """Restore into the structure (and shardings/dtypes) of ``state_like``."""
    data = np.load(os.path.join(path, "state.npz"))
    flat_like = _flatten(state_like)
    missing = set(flat_like) - set(data.files)
    extra = set(data.files) - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")

    leaves, treedef = jax.tree.flatten(state_like)
    flat_keys = list(_flatten(state_like).keys())
    # _flatten and tree.flatten enumerate dicts in the same (insertion) order
    # only if keys are sorted consistently; rebuild by path instead.
    restored_flat = {}
    for k, like in flat_like.items():
        arr = data[k]
        target_dtype = like.dtype
        a = jax.numpy.asarray(arr).astype(target_dtype)
        if hasattr(like, "sharding") and like.sharding is not None:
            try:
                a = jax.device_put(a, like.sharding)
            except Exception:
                pass
        restored_flat[k] = a

    def rebuild(prefix, node):
        if isinstance(node, dict):
            return {k: rebuild(f"{prefix}/{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            seq = [rebuild(f"{prefix}/{i}", v) for i, v in enumerate(node)]
            return type(node)(seq) if isinstance(node, tuple) else seq
        return restored_flat[prefix]

    return rebuild("", state_like)


def latest_step(path: str) -> int:
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f)["step"]
