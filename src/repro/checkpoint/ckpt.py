"""Checkpointing: pytree <-> sharded .npz directory, crash-safe.

Flat key = '/'-joined tree path. Restore rebuilds onto the target sharding
(device_put against the existing state's shardings), so checkpoints travel
across mesh configurations.

Crash-safety contract (the preemption-safe-resume substrate — see
DESIGN.md "Fault tolerance & elasticity"):

- every save writes ``state-<step>.npz`` + ``meta-<step>.json`` through a
  temp file + atomic ``os.replace`` in the same directory, so a kill at
  any instant leaves either the old file or the new file, never a torn
  one; ``meta.json`` (the latest pointer, written last) carries a crc32
  ``checksum`` of the exact bytes on disk;
- restore verifies the checksum and, when the latest checkpoint is
  truncated/corrupt/missing, **falls back to the newest valid step**
  (with a warning + the ``fault/ckpt_fallbacks`` counter) instead of
  crashing mid-restore;
- the newest ``keep`` steps are retained (older state files pruned), so
  a fallback target exists even after the latest save was interrupted;
- the pre-crash-safe single-file layout (``state.npz`` + ``meta.json``
  without a checksum) still restores.

``workers`` in the meta records the elastic membership that wrote the
checkpoint (``repro.fault.elastic`` resumes onto that fleet and re-forms
membership from there).
"""
from __future__ import annotations

import io
import json
import os
import tempfile
import warnings
import zlib

import jax
import numpy as np


def _flatten(tree):
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}/{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def _atomic_write(path: str, data: bytes):
    """Write-to-temp + fsync + rename in the target directory: readers see
    the old bytes or the new bytes, never a torn file."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmp-",
                               suffix=os.path.basename(path))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _state_name(step: int) -> str:
    return f"state-{step:08d}.npz"


def _meta_name(step: int) -> str:
    return f"meta-{step:08d}.json"


def save_checkpoint(path: str, state, step: int | None = None,
                    algo: str | None = None, workers=None, keep: int = 3):
    """Crash-safe save of ``state`` at ``step`` into directory ``path``.

    Writes ``state-<step>.npz`` and its per-step meta atomically, then the
    ``meta.json`` latest pointer; retains the newest ``keep`` steps."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)

    def to_np(v):
        # npz has no bfloat16: store as fp32, restore casts back
        if hasattr(v, "dtype") and v.dtype == jax.numpy.bfloat16:
            return np.asarray(v, np.float32)
        return np.asarray(v)

    arrays = {k: to_np(v) for k, v in flat.items()}
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    data = buf.getvalue()
    step_i = int(step) if step is not None else 0
    meta = {"step": step_i, "keys": sorted(arrays.keys()),
            "file": _state_name(step_i),
            "checksum": zlib.crc32(data), "nbytes": len(data)}
    if algo is not None:
        meta["algo"] = algo
    if workers is not None:
        meta["workers"] = [int(w) for w in workers]
    meta_bytes = json.dumps(meta).encode()
    _atomic_write(os.path.join(path, _state_name(step_i)), data)
    _atomic_write(os.path.join(path, _meta_name(step_i)), meta_bytes)
    # latest pointer last: a crash before this line leaves the previous
    # latest intact and the new step discoverable by the fallback scan
    _atomic_write(os.path.join(path, "meta.json"), meta_bytes)
    if keep and keep > 0:
        for s in _saved_steps(path)[:-keep]:
            for name in (_state_name(s), _meta_name(s)):
                try:
                    os.unlink(os.path.join(path, name))
                except OSError:
                    pass


def _saved_steps(path: str) -> list:
    """Steps with a per-step meta present, ascending."""
    steps = []
    try:
        names = os.listdir(path)
    except OSError:
        return steps
    for n in names:
        if n.startswith("meta-") and n.endswith(".json"):
            try:
                steps.append(int(n[len("meta-"):-len(".json")]))
            except ValueError:
                pass
    return sorted(steps)


def _verify(path: str, meta: dict):
    """-> npz bytes if the recorded file exists and its crc32 matches,
    else None (truncated / corrupt / missing)."""
    fn = meta.get("file")
    if not fn:
        return None
    try:
        with open(os.path.join(path, fn), "rb") as f:
            data = f.read()
    except OSError:
        return None
    if "checksum" in meta and zlib.crc32(data) != meta["checksum"]:
        return None
    if "nbytes" in meta and len(data) != meta["nbytes"]:
        return None
    return data


def _load_valid(path: str) -> tuple:
    """-> (npz NpzFile, meta) of the newest checkpoint that passes its
    integrity check, falling back step by step; legacy single-file
    layouts (no checksum) load as-is."""
    tried = []
    for s in reversed(_saved_steps(path)):
        try:
            with open(os.path.join(path, _meta_name(s))) as f:
                meta = json.load(f)
        except (OSError, json.JSONDecodeError):
            tried.append(s)
            continue
        data = _verify(path, meta)
        if data is None:
            tried.append(s)
            continue
        if tried:
            warnings.warn(
                f"checkpoint {path!r}: step(s) {tried} truncated or "
                f"corrupt; falling back to newest valid step {s}",
                RuntimeWarning, stacklevel=3)
            from repro.telemetry import metrics
            metrics.counter("fault/ckpt_fallbacks").inc(len(tried))
        return np.load(io.BytesIO(data), allow_pickle=False), meta
    # legacy layout: one state.npz + meta.json, no integrity stamp
    legacy = os.path.join(path, "state.npz")
    if os.path.exists(legacy):
        meta_p = os.path.join(path, "meta.json")
        meta = {}
        if os.path.exists(meta_p):
            with open(meta_p) as f:
                meta = json.load(f)
        return np.load(legacy), meta
    raise FileNotFoundError(
        f"no valid checkpoint under {path!r}"
        + (f" (step(s) {tried} failed their integrity check)" if tried
           else ""))


def restore_checkpoint(path: str, state_like):
    """Restore into the structure (and shardings/dtypes) of ``state_like``
    from the newest *valid* checkpoint under ``path``."""
    data, _ = _load_valid(path)
    return _restore_tree(data, state_like)


def _restore_tree(data, state_like):
    flat_like = _flatten(state_like)
    missing = set(flat_like) - set(data.files)
    extra = set(data.files) - set(flat_like)
    if missing or extra:
        raise ValueError(
            f"checkpoint layout mismatch (written by a different "
            f"TrainPlan/algo?): missing={sorted(missing)[:5]} "
            f"extra={sorted(extra)[:5]}")

    leaves, treedef = jax.tree.flatten(state_like)
    flat_keys = list(_flatten(state_like).keys())
    # _flatten and tree.flatten enumerate dicts in the same (insertion) order
    # only if keys are sorted consistently; rebuild by path instead.
    restored_flat = {}
    for k, like in flat_like.items():
        arr = data[k]
        target_dtype = like.dtype
        a = jax.numpy.asarray(arr).astype(target_dtype)
        if hasattr(like, "sharding") and like.sharding is not None:
            # no fallback: a failed placement (e.g. the checkpointing mesh
            # is gone) must fail loudly — the resume contract promises the
            # restored state lands on ``state_like``'s shardings
            a = jax.device_put(a, like.sharding)
        restored_flat[k] = a

    def rebuild(prefix, node):
        if isinstance(node, dict):
            return {k: rebuild(f"{prefix}/{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            seq = [rebuild(f"{prefix}/{i}", v) for i, v in enumerate(node)]
            return type(node)(seq) if isinstance(node, tuple) else seq
        return restored_flat[prefix]

    return rebuild("", state_like)


def load_meta(path: str) -> dict:
    """Meta of the newest *valid* checkpoint (integrity-verified; falls
    back past truncated/corrupt steps like the restore path does)."""
    _, meta = _load_valid(path)
    return meta


def latest_step(path: str) -> int:
    return load_meta(path)["step"]


def restore_for_resume(path: str, state_like, expect_algo: str | None = None):
    """Resume entry point for the training engine: restore ``state_like``'s
    layout (structure, dtypes, shardings) from ``path`` and return
    ``(state, start_step)``.

    ``expect_algo`` guards against resuming under the wrong algorithm when
    the layouts happen to coincide (bsp and gspmd share ``params/opt/step``
    exactly; easgd and asgd share the ``center`` layout) — the key check
    alone cannot tell those apart, the recorded meta can.

    ``start_step`` comes from the checkpoint meta and is cross-checked
    against the restored ``state["step"]`` counter — the loop folds the rng
    with the global step index, so a wrong offset would silently change
    the data/rng schedule instead of replaying the uninterrupted run.

    A truncated/corrupt latest checkpoint (a save interrupted by the very
    preemption being resumed from) falls back to the newest valid step —
    the data and the returned ``start_step`` always come from the *same*
    verified checkpoint."""
    data, meta = _load_valid(path)
    recorded = meta.get("algo")
    if (expect_algo is not None and recorded is not None
            and recorded != expect_algo):
        raise ValueError(
            f"checkpoint algo mismatch: {path!r} was written by a "
            f"{recorded!r} plan, cannot resume as {expect_algo!r}")
    state = _restore_tree(data, state_like)
    step = int(meta.get("step", 0))
    if isinstance(state, dict) and "step" in state:
        in_state = int(np.asarray(state["step"]))
        if in_state != step:
            raise ValueError(
                f"checkpoint step mismatch: meta.json says {step} but "
                f"state['step'] is {in_state} ({path!r})")
    return state, step
