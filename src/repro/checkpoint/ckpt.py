"""Checkpointing: pytree <-> sharded .npz directory.

Flat key = '/'-joined tree path. Restore rebuilds onto the target sharding
(device_put against the existing state's shardings), so checkpoints travel
across mesh configurations.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}/{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def save_checkpoint(path: str, state, step: int | None = None,
                    algo: str | None = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)

    def to_np(v):
        a = np.asarray(v) if not hasattr(v, "dtype") or v.dtype !=             jax.numpy.bfloat16 else np.asarray(v, np.float32)
        return a
    arrays = {k: to_np(v) for k, v in flat.items()}
    np.savez(os.path.join(path, "state.npz"), **arrays)
    meta = {"step": int(step) if step is not None else 0,
            "keys": sorted(arrays.keys())}
    if algo is not None:
        meta["algo"] = algo
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def restore_checkpoint(path: str, state_like):
    """Restore into the structure (and shardings/dtypes) of ``state_like``."""
    data = np.load(os.path.join(path, "state.npz"))
    flat_like = _flatten(state_like)
    missing = set(flat_like) - set(data.files)
    extra = set(data.files) - set(flat_like)
    if missing or extra:
        raise ValueError(
            f"checkpoint layout mismatch (written by a different "
            f"TrainPlan/algo?): missing={sorted(missing)[:5]} "
            f"extra={sorted(extra)[:5]}")

    leaves, treedef = jax.tree.flatten(state_like)
    flat_keys = list(_flatten(state_like).keys())
    # _flatten and tree.flatten enumerate dicts in the same (insertion) order
    # only if keys are sorted consistently; rebuild by path instead.
    restored_flat = {}
    for k, like in flat_like.items():
        arr = data[k]
        target_dtype = like.dtype
        a = jax.numpy.asarray(arr).astype(target_dtype)
        if hasattr(like, "sharding") and like.sharding is not None:
            # no fallback: a failed placement (e.g. the checkpointing mesh
            # is gone) must fail loudly — the resume contract promises the
            # restored state lands on ``state_like``'s shardings
            a = jax.device_put(a, like.sharding)
        restored_flat[k] = a

    def rebuild(prefix, node):
        if isinstance(node, dict):
            return {k: rebuild(f"{prefix}/{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            seq = [rebuild(f"{prefix}/{i}", v) for i, v in enumerate(node)]
            return type(node)(seq) if isinstance(node, tuple) else seq
        return restored_flat[prefix]

    return rebuild("", state_like)


def load_meta(path: str) -> dict:
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f)


def latest_step(path: str) -> int:
    return load_meta(path)["step"]


def restore_for_resume(path: str, state_like, expect_algo: str | None = None):
    """Resume entry point for the training engine: restore ``state_like``'s
    layout (structure, dtypes, shardings) from ``path`` and return
    ``(state, start_step)``.

    ``expect_algo`` guards against resuming under the wrong algorithm when
    the layouts happen to coincide (bsp and gspmd share ``params/opt/step``
    exactly; easgd and asgd share the ``center`` layout) — the key check
    alone cannot tell those apart, the recorded meta can.

    ``start_step`` comes from the checkpoint meta and is cross-checked
    against the restored ``state["step"]`` counter — the loop folds the rng
    with the global step index, so a wrong offset would silently change
    the data/rng schedule instead of replaying the uninterrupted run."""
    meta = load_meta(path)
    recorded = meta.get("algo")
    if (expect_algo is not None and recorded is not None
            and recorded != expect_algo):
        raise ValueError(
            f"checkpoint algo mismatch: {path!r} was written by a "
            f"{recorded!r} plan, cannot resume as {expect_algo!r}")
    state = restore_checkpoint(path, state_like)
    step = int(meta.get("step", 0))
    if isinstance(state, dict) and "step" in state:
        in_state = int(np.asarray(state["step"]))
        if in_state != step:
            raise ValueError(
                f"checkpoint step mismatch: meta.json says {step} but "
                f"state['step'] is {in_state} ({path!r})")
    return state, step
