"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --smoke --steps 50 --exchanger asa --scheme subgd

    # async (EASGD center with fp16-wire elastic exchange):
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --smoke --algo easgd --tau 4 --alpha 0.5 --exchanger asa16

    # resume a checkpointed run:
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --smoke --steps 100 --ckpt /tmp/ck --resume /tmp/ck

    # elastic chaos run (quorum sync + injected faults; see repro.fault):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --smoke --algo easgd --tau 4 --workers 4 --quorum 2 \
        --fault-plan 'kill:3@9,straggle:2@13x2,join:3@33'

Runs the reduced (smoke) variant by default on the host CPU devices; the
full config is exercised through the dry-run (-m repro.launch.dryrun).
Every algorithm goes through the same engine (``repro.train.engine``), so
``--ckpt``/``--resume`` work for all of them. ``--quorum``/``--fault-plan``
(async algos only) route through ``repro.fault.elastic.elastic_train``:
dynamic membership, staleness-scaled quorum averaging, deterministic
fault injection.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import telemetry
from repro.configs import get_config, get_smoke_config
from repro.configs.base import with_attn_impl
from repro.data.synthetic import LMTokenSource, ImageSource
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import sgd_momentum, adamw, warmup_cosine, constant
from repro.train.engine import TrainPlan
from repro.train.loop import train


def synthetic_batch(cfg, batch_size: int, step: int, seq_len: int = 128):
    """The batch at index ``step`` — deterministic in (cfg, sizes, step),
    so it doubles as the elastic loop's ``batch_fn(step, k)``."""
    if cfg.family == "conv":
        return ImageSource(cfg.image_size, cfg.num_classes).batch(
            batch_size, step)
    b = LMTokenSource(cfg.vocab_size, seq_len).batch(batch_size, step)
    if cfg.family == "encdec":
        b["frames"] = np.random.default_rng(step).normal(
            0, 1, (batch_size, cfg.encoder_seq_len,
                   cfg.d_model)).astype(np.float32)
    if cfg.modality == "vlm":
        b["image_embeds"] = np.zeros(
            (batch_size, cfg.num_image_tokens, cfg.d_model), np.float32)
    return b


def synthetic_batches(cfg, batch_size: int, steps: int, seq_len: int = 128):
    for i in range(steps):
        yield synthetic_batch(cfg, batch_size, i, seq_len)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw"])
    ap.add_argument("--algo", default="bsp",
                    choices=["bsp", "easgd", "asgd", "gspmd"],
                    help="training plan: sync BSP, async EASGD/ASGD, or "
                         "GSPMD/FSDP")
    ap.add_argument("--exchanger", default="asa")
    ap.add_argument("--scheme", default="subgd", choices=["subgd", "awagd"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--bucket-bytes", type=int, default=0,
                    help="pack gradient leaves into flat buckets of up to "
                         "this many bytes before exchanging")
    ap.add_argument("--sharded-update", action="store_true",
                    help="ZeRO-1-style RS->update->AG: update only the "
                         "local 1/k shard between the exchange halves")
    ap.add_argument("--overlap", default=None, choices=["buckets"],
                    help="double-buffer the microbatch scan so bucket "
                         "reduce-scatters overlap the next backprop "
                         "(implies --sharded-update)")
    ap.add_argument("--tau", type=int, default=1,
                    help="easgd/asgd averaging period (steps between "
                         "center exchanges)")
    ap.add_argument("--alpha", type=float, default=None,
                    help="easgd elastic coefficient (default 0.5; asgd is "
                         "pinned to 1)")
    ap.add_argument("--mode", default="zero1", choices=["zero1", "ar"],
                    help="gspmd gradient reduction mode")
    ap.add_argument("--workers", type=int, default=None,
                    help="elastic fleet size (default: all visible "
                         "devices); only with --quorum/--fault-plan")
    ap.add_argument("--quorum", type=int, default=None,
                    help="min reporting workers for an averaging round "
                         "(easgd/asgd): below it the round degrades to a "
                         "local step; enables the elastic loop")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="deterministic fault injection, e.g. "
                         "'kill:1@9,straggle:2@5x3,corrupt:0@13' "
                         "(kind:worker@step[xrounds]); enables the "
                         "elastic loop")
    ap.add_argument("--attn-impl", default=None,
                    choices=["auto", "flash", "ref", "blockwise"],
                    help="attention implementation for the train step: "
                         "Pallas flash kernels (fwd + custom-VJP bwd), "
                         "einsum ref oracles, or the blockwise scan "
                         "(default: auto — flash where Pallas compiles)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", default=None, metavar="CKPT",
                    help="restore state/step/rng offset from a checkpoint "
                         "written by the same plan and continue")
    ap.add_argument("--metrics-out", default=None, metavar="JSONL",
                    help="write telemetry metrics (schema'd JSONL: "
                         "per-step time split, loss/lr, examples/s, "
                         "achieved model FLOP/s, exchange bytes-on-wire)")
    ap.add_argument("--trace-out", default=None, metavar="JSON",
                    help="write host-side spans as Chrome-trace/Perfetto "
                         "JSON (load at ui.perfetto.dev)")
    ap.add_argument("--no-profile", action="store_true",
                    help="disable per-program cost attribution "
                         "(profile/* and compile/* gauges); same as "
                         "REPRO_TELEMETRY_PROFILE=0")
    args = ap.parse_args()

    if args.metrics_out:
        telemetry.configure(metrics_out=args.metrics_out)
    if args.no_profile:
        telemetry.configure(profile=False)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = with_attn_impl(cfg, args.attn_impl)
    model = build_model(cfg)
    mesh = make_host_mesh()
    jax.set_mesh(mesh)
    opt = (sgd_momentum(weight_decay=0.0) if args.optimizer == "sgd"
           else adamw())
    lr_fn = warmup_cosine(args.lr, 10, args.steps)
    elastic = args.quorum is not None or args.fault_plan is not None
    try:
        plan = TrainPlan(algo=args.algo, exchanger=args.exchanger,
                         scheme=args.scheme, microbatches=args.microbatches,
                         bucket_bytes=args.bucket_bytes,
                         sharded_update=args.sharded_update,
                         overlap=args.overlap, tau=args.tau,
                         alpha=args.alpha, mode=args.mode,
                         quorum=args.quorum if elastic else None)
    except ValueError as e:
        ap.error(str(e))
    if elastic:
        if not plan.is_async:
            ap.error("--quorum/--fault-plan need an async plan "
                     "(--algo easgd|asgd); bsp/gspmd fault tolerance is "
                     "checkpoint restart via --ckpt/--resume")
        from repro.fault.elastic import elastic_train

        def batch_fn(step, k):
            # per-worker batch size held constant: the global batch
            # scales with the live fleet, like a real elastic run
            return synthetic_batch(cfg, args.batch * k, step, args.seq)

        try:
            _, erep = elastic_train(
                model, opt, lr_fn, batch_fn, plan=plan,
                num_workers=args.workers, num_steps=args.steps,
                fault_plan=args.fault_plan, ckpt_path=args.ckpt,
                ckpt_every=args.steps // 4 if args.ckpt else 0,
                resume_from=args.resume)
        except ValueError as e:
            raise SystemExit(str(e))
        if args.metrics_out:
            telemetry.flush(force=True)
            print(f"metrics -> {args.metrics_out}")
        if args.trace_out:
            telemetry.trace.export(args.trace_out)
            print(f"trace -> {args.trace_out}")
        print(f"done: {erep.steps} steps ({plan.algo} elastic), "
              f"fleet {erep.final_workers}, "
              f"rounds {erep.rounds_synced} synced / "
              f"{erep.rounds_skipped_quorum} below-quorum, "
              f"kills {erep.kills}, joins {erep.joins}, "
              f"rebuilds {erep.rebuilds}, payloads dropped "
              f"{erep.payloads_dropped} / corrupt {erep.payloads_corrupt}, "
              f"loss {erep.losses[0]:.4f} -> {erep.losses[-1]:.4f}")
        return
    batches = synthetic_batches(cfg, args.batch, args.steps, args.seq)
    try:
        _, report = train(model, opt, lr_fn, mesh, batches, plan=plan,
                          num_steps=args.steps, ckpt_path=args.ckpt,
                          resume_from=args.resume)
    except ValueError as e:
        if args.resume and "mismatch" in str(e):
            raise SystemExit(f"--resume {args.resume}: {e}")
        raise
    if args.metrics_out:
        # the JSONL sink attached above received periodic + final
        # snapshots from the train loop's flush boundaries
        print(f"metrics -> {args.metrics_out}")
    if args.trace_out:
        telemetry.trace.export(args.trace_out)
        print(f"trace -> {args.trace_out}")
    if not report.losses:
        if args.resume:
            print(f"done: nothing to do (resumed at step {report.steps})")
        else:
            print("done: no steps ran (empty batch source or --steps 0)")
        return
    print(f"done: {report.steps} steps ({plan.algo}), "
          f"{report.examples_per_s:.1f} ex/s total "
          f"({report.steady_examples_per_s:.1f} ex/s steady-state, "
          f"compile+first step {report.compile_time:.2f}s), "
          f"loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f}")


if __name__ == "__main__":
    main()
