"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh single --exchanger asa --out experiments/dryrun

The XLA_FLAGS assignment below MUST run before jax initializes a backend
(the host device count locks at first backend init, not at import — merely
importing jax, as ``repro/__init__``'s compat shims do, is safe; touching
``jax.devices()`` earlier is not). Do not import this module from processes
that need 1 device.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config, get_shape
from repro.core.bsp import make_bsp_step
from repro.core.exchanger import get_exchanger
from repro.core.gspmd import (fsdp_state_shardings, make_gspmd_step)
from repro.dist import act
from repro.dist.sharding import (batch_shardings, cache_shardings,
                                 dp_axes_of, dp_size_of, param_shardings,
                                 state_shardings)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_cache, abstract_state, decode_batch_specs,
                                sds, train_batch_specs)
from repro.models.registry import build_model
from repro.optim.optimizers import sgd_momentum
from repro.optim.schedule import constant
from repro.roofline.analysis import analyze, model_flops_6nd

# replicated-DP (paper-faithful BSP) is infeasible above this per-chip bound;
# larger archs use the GSPMD/ZeRO-1 path (see core/gspmd.py and DESIGN.md).
FSDP_THRESHOLD_BYTES = 12e9


def _bf16_params(params):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 and len(s.shape) >= 2
            else s.dtype),
        params)


def needs_fsdp(cfg, mesh) -> bool:
    tp = mesh.shape.get("model", 1)
    per_chip = cfg.param_count() * 4 * 3 / tp  # params+momentum+grads fp32
    return per_chip > FSDP_THRESHOLD_BYTES


def build_train(cfg, shape, mesh, exchanger_name: str, mode_override=None, unroll=True):
    model = build_model(cfg)
    opt = sgd_momentum(weight_decay=0.0)
    state = abstract_state(model, opt)
    batch = train_batch_specs(cfg, shape)
    dp = dp_axes_of(mesh)
    rng = sds((2,), jnp.uint32)

    def with_rng(fn):
        def wrapped(state, batch, seed):
            return fn(state, batch, jax.random.wrap_key_data(seed))
        return wrapped

    mode = mode_override or ("fsdp" if needs_fsdp(cfg, mesh) else "bsp")
    if mode == "bsp":
        step = make_bsp_step(model, opt, get_exchanger(exchanger_name),
                             constant(0.01), mesh, data_axes=dp,
                             unroll=unroll)
        state_sh = state_shardings(mesh, state)
    else:
        step = make_gspmd_step(model, opt, constant(0.01), mesh,
                               mode="zero1" if mode in ("fsdp", "zero1")
                               else "ar", unroll=unroll)
        state_sh = fsdp_state_shardings(mesh, state)

    fn = with_rng(step)
    in_sh = (state_sh, batch_shardings(mesh, batch),
             NamedSharding(mesh, P()))
    args = (state, batch, rng)
    return fn, args, in_sh, mode


def build_prefill(cfg, shape, mesh, unroll=True):
    model = build_model(cfg)
    params = _bf16_params(jax.eval_shape(model.init, jax.random.key(0)))
    batch = train_batch_specs(cfg, shape)
    batch.pop("labels", None)

    def fn(params, batch):
        return model.forward(params, batch, unroll=unroll)

    in_sh = (param_shardings(mesh, params), batch_shardings(mesh, batch))
    return fn, (params, batch), in_sh, "prefill"


def build_decode(cfg, shape, mesh, unroll=True):
    model = build_model(cfg)
    params = _bf16_params(jax.eval_shape(model.init, jax.random.key(0)))
    cache = abstract_cache(model, cfg, shape)
    batch = decode_batch_specs(cfg, shape)
    pos = sds((), jnp.int32)

    def fn(params, cache, batch, pos):
        logits, new_cache = model.decode_step(params, cache, batch, pos,
                                              seq_len=shape.seq_len,
                                              unroll=unroll)
        return jnp.argmax(logits[:, -1, :], axis=-1), new_cache

    in_sh = (param_shardings(mesh, params),
             cache_shardings(mesh, cache, shape.global_batch),
             batch_shardings(mesh, batch), NamedSharding(mesh, P()))
    return fn, (params, cache, batch, pos), in_sh, "decode"


def _scan_seg_lengths(cfg) -> list[int]:
    """Lengths of the lax.scan'ed layer segments (for cost extrapolation)."""
    from repro.models.transformer import segments
    if cfg.family == "encdec":
        return [cfg.num_encoder_layers, cfg.num_layers]
    if cfg.family == "conv":
        return []
    return [c for _, c in segments(cfg) if c > 1]


def _extrapolate(res1: dict, res2: dict, lstar: int) -> dict:
    """Roofline terms from unroll=1 and unroll=2 compiles.

    XLA costs a while-loop body once, so cost(u) = outside + u*body for
    equal-length scanned segments; total = c1 + (L-1)*(c2-c1)."""
    out = json.loads(json.dumps(res1))
    r1, r2 = res1["roofline"], res2["roofline"]
    for key in ("flops", "hbm_bytes", "coll_bytes", "model_flops"):
        body = max(r2[key] - r1[key], 0.0)
        out["roofline"][key] = r1[key] + (lstar - 1) * body
    rl = out["roofline"]
    from repro.roofline.analysis import PEAK_FLOPS, HBM_BW, ICI_BW
    rl["t_compute_s"] = rl["flops"] / PEAK_FLOPS
    rl["t_memory_s"] = rl["hbm_bytes"] / HBM_BW
    rl["t_collective_s"] = rl["coll_bytes"] / ICI_BW
    terms = {"compute": rl["t_compute_s"], "memory": rl["t_memory_s"],
             "collective": rl["t_collective_s"]}
    rl["dominant"] = max(terms, key=terms.get)
    rl["model_flops"] = res1["roofline"]["model_flops"]  # analytic, not scaled
    rl["useful_ratio"] = (rl["model_flops"] / rl["flops"]
                          if rl["flops"] else 0.0)
    c1, c2 = res1["collectives"], res2["collectives"]
    for kind, v1 in c1["counts"].items():
        v2 = c2["counts"].get(kind, v1)
        out["collectives"]["counts"][kind] = v1 + (lstar - 1) * max(v2 - v1, 0)
    for kind, v1 in c1["bytes_by_kind"].items():
        v2 = c2["bytes_by_kind"].get(kind, v1)
        out["collectives"]["bytes_by_kind"][kind] = (
            v1 + (lstar - 1) * max(v2 - v1, 0))
    out["extrapolated_from_unroll12"] = True
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool,
            exchanger: str = "asa", seq_shard: bool = True,
            mode_override=None, unroll: bool | None = None,
            block_kv: int = 0, replicate_attn: bool = False) -> dict:
    from repro.dist.sharding import set_replicate_attn
    set_replicate_attn(replicate_attn)
    cfg = get_config(arch)
    if block_kv and cfg.attention is not None:
        import dataclasses
        cfg = cfg.with_overrides(
            attention=dataclasses.replace(cfg.attention, block_kv=block_kv,
                                          block_unroll=True))
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    jax.set_mesh(mesh)
    result = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "exchanger": exchanger, "unrolled": bool(unroll),
              "block_kv": block_kv}
    t0 = time.time()

    # sequence-parallel activation constraint (memory): residual stream's
    # feature dim sharded over 'model' between layers.
    spec = P(None, None, "model") if seq_shard else None

    if shape.kind == "decode":
        spec = None  # single-token residual: no constraint

    try:
        def build(u):
            if shape.kind == "train":
                return build_train(cfg, shape, mesh, exchanger,
                                   mode_override, unroll=u)
            if shape.kind == "prefill":
                return build_prefill(cfg, shape, mesh, unroll=u)
            return build_decode(cfg, shape, mesh, unroll=u)

        chips = 512 if multi_pod else 256
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        mf = model_flops_6nd(cfg.active_param_count(), tokens,
                             "train" if shape.kind == "train" else "infer")

        def compile_once(u):
            fn, args, in_sh, mode = build(u)
            with act.activation_spec(spec):
                lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            compiled = lowered.compile()
            return analyze(compiled, model_flops_per_device=mf / chips), mode

        res1, mode = compile_once(1)
        result["mode"] = mode
        segs = _scan_seg_lengths(cfg)
        # single-pod roofline pass: second compile at unroll=2, extrapolate
        # per-layer costs (scan bodies are costed once by XLA)
        if (not multi_pod) and segs and all(s == segs[0] for s in segs) \
                and segs[0] > 1 and cfg.scan_layers:
            res2, _ = compile_once(2)
            result.update(_extrapolate(res1, res2, segs[0]))
        else:
            result.update(res1)
        result["compile_s"] = round(time.time() - t0, 1)
        result["ok"] = True
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        result["ok"] = False
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (assigned archs)")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--exchanger", default="asa")
    ap.add_argument("--mode", default=None,
                    help="override train mode: bsp|zero1|ar")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--block-kv", type=int, default=0,
                    help="blockwise attention KV block (0=naive baseline)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="", help="extra tag suffix for output")
    ap.add_argument("--replicate-attn", action="store_true",
                    help="replicate attention/SSM params (no TP on them)")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if args.mode:
                    tag += f"__{args.mode}"
                if args.exchanger != "asa":
                    tag += f"__{args.exchanger}"
                if args.block_kv:
                    tag += f"__bkv{args.block_kv}"
                if args.no_seq_shard:
                    tag += "__noseq"
                if args.replicate_attn:
                    tag += "__repattn"
                if args.tag:
                    tag += f"__{args.tag}"
                res = run_one(arch, shape, mp, args.exchanger,
                              seq_shard=not args.no_seq_shard,
                              mode_override=args.mode,
                              block_kv=args.block_kv,
                              replicate_attn=args.replicate_attn)
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                if res["ok"]:
                    rl = res["roofline"]
                    print(f"OK  {tag:60s} mode={res.get('mode','-'):7s} "
                          f"compile={res['compile_s']:6.1f}s "
                          f"t_comp={rl['t_compute_s']:.3e} "
                          f"t_mem={rl['t_memory_s']:.3e} "
                          f"t_coll={rl['t_collective_s']:.3e} "
                          f"dom={rl['dominant']}", flush=True)
                else:
                    print(f"FAIL {tag}: {res['error']}", flush=True)


if __name__ == "__main__":
    main()
