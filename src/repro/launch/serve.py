"""Serving launcher: batched greedy decode of a (smoke) model.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --batch 4 --prompt-len 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, get_config
from repro.models import build_model
from repro.train.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "conv":
        raise SystemExit("conv models have no decode step")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(model, params, prompt, max_new=args.max_new,
                   seq_len=args.prompt_len + args.max_new)
    dt = time.perf_counter() - t0
    toks = args.batch * args.max_new
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s batched greedy)")
    print(out[0])


if __name__ == "__main__":
    main()
