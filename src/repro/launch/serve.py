"""Serving launcher: continuous-batching engine over a (smoke) model.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --num-requests 16 --max-slots 4 --prefill-chunk 16 \
        --temperature 0.8 --top-k 40 --top-p 0.95

``--reference`` runs the old static-batch greedy path
(``train.serve.generate``) instead — the parity oracle and the baseline
``bench_serve`` measures the engine against.

SLO guardrails (DESIGN.md "Serve robustness"): ``--deadline-ms`` stamps a
per-request budget (hopeless requests are shed, in-flight ones past
deadline cancelled), ``--max-queue``/``--shed-policy`` bound the submit
queue, ``--drain-on-sigterm PATH`` installs a SIGTERM handler that drains
gracefully and snapshots unfinished work (restartable via the same path),
and ``--fault-plan`` hands the run to the deterministic chaos loop
(``repro.serve.chaos``) instead of the plain workload.
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.configs import get_smoke_config, get_config
from repro.configs.base import with_attn_impl
from repro.models import build_model
from repro.serve import Engine, SamplingParams
from repro.train.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8,
                    help="mean prompt length (mixed workload)")
    ap.add_argument("--max-new", type=int, default=16,
                    help="mean output length (mixed workload)")
    ap.add_argument("--max-slots", type=int, default=4,
                    help="decode lanes in the fixed slot pool")
    ap.add_argument("--max-seq", type=int, default=0,
                    help="cache rows per slot (0: auto from workload)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens prefilled per model call")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV cache page size in tokens (0: contiguous "
                         "per-slot lanes — the legacy/oracle layout)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="physical pages in the shared KV pool (0: "
                         "worst-case auto — every slot can reach max_seq; "
                         "smaller values oversubscribe HBM and gate "
                         "admission on actual usage)")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="hash page-aligned prompt prefixes and serve "
                         "repeats from shared pages (copy-on-write; "
                         "attention families only)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fused-sampling", action="store_true",
                    help="slot_gather Pallas kernel fast path "
                         "(greedy/temperature only)")
    ap.add_argument("--attn-impl", default=None,
                    choices=["auto", "flash", "ref", "blockwise"],
                    help="attention implementation for prefill/decode: "
                         "Pallas flash kernels, einsum ref oracles, or "
                         "the blockwise scan (default: auto — flash "
                         "where Pallas compiles)")
    ap.add_argument("--reference", action="store_true",
                    help="static-batch greedy generate() instead of the "
                         "engine")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO budget: shed if unmeetable in "
                         "queue, cancel in-flight past deadline")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the submit queue (0: unbounded); full "
                         "queues reject with REJECTED_QUEUE_FULL")
    ap.add_argument("--shed-policy", default="reject-newest",
                    choices=["reject-newest", "reject-no-deadline"],
                    help="who loses when the bounded queue overflows")
    ap.add_argument("--drain-on-sigterm", default=None, metavar="SNAP",
                    help="SIGTERM drains gracefully and snapshots "
                         "unfinished work to SNAP (atomic+crc32); if SNAP "
                         "exists at startup, queued work resumes from it")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="run the deterministic serve chaos loop under "
                         "this seeded FaultPlan instead of the plain "
                         "workload (kinds: qflood/stall/cancel/pagepress, "
                         "grammar kind:magnitude@step[xD])")
    ap.add_argument("--metrics-out", default=None, metavar="JSONL",
                    help="write telemetry metrics (schema'd JSONL: "
                         "prefill/decode throughput, TTFT, queue wait, "
                         "page-pool occupancy, prefix hit-rate, COW and "
                         "admission/eviction counters)")
    ap.add_argument("--trace-out", default=None, metavar="JSON",
                    help="write host-side spans (per-request lifecycle + "
                         "decode dispatches) as Chrome-trace/Perfetto JSON")
    ap.add_argument("--no-profile", action="store_true",
                    help="disable per-program cost attribution "
                         "(profile/* and compile/* gauges); same as "
                         "REPRO_TELEMETRY_PROFILE=0")
    args = ap.parse_args()
    if args.no_profile:
        telemetry.configure(profile=False)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family != "decoder":
        raise SystemExit(f"{cfg.family!r} models have no serve path")
    cfg = with_attn_impl(cfg, args.attn_impl)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    rng = np.random.RandomState(args.seed)
    lens = np.maximum(1, rng.poisson(args.prompt_len, args.num_requests))
    news = np.maximum(1, rng.poisson(args.max_new, args.num_requests))
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in lens]

    if args.reference:
        t0 = time.perf_counter()
        done = 0
        for p, m in zip(prompts, news):
            out = generate(model, params, jnp.asarray([p], jnp.int32),
                           max_new=int(m), seq_len=len(p) + int(m))
            jax.block_until_ready(out)
            done += int(m)
        dt = time.perf_counter() - t0
        print(f"reference generate: {done} tokens in {dt:.2f}s "
              f"({done / dt:.1f} tok/s)")
        return

    if args.fault_plan:
        from repro.serve.chaos import main as chaos_main
        chaos_main(["--arch", args.arch, "--fault-plan", args.fault_plan,
                    "--seed", str(args.seed),
                    "--requests", str(args.num_requests),
                    "--max-slots", str(args.max_slots),
                    "--page-size", str(args.page_size or 8),
                    "--num-pages", str(args.num_pages),
                    "--max-queue", str(args.max_queue or 16),
                    "--shed-policy", args.shed_policy, "--replay"]
                   + (["--metrics-out", args.metrics_out]
                      if args.metrics_out else [])
                   + (["--trace-out", args.trace_out]
                      if args.trace_out else []))
        return

    max_seq = args.max_seq or int((lens + news).max())
    eng = Engine(model, params, max_slots=args.max_slots, max_seq=max_seq,
                 prefill_chunk=args.prefill_chunk,
                 fused_sampling=args.fused_sampling,
                 page_size=args.page_size, num_pages=args.num_pages,
                 prefix_cache=args.prefix_cache,
                 max_queue=args.max_queue, shed_policy=args.shed_policy)
    if args.drain_on_sigterm:
        import os

        def _drain(signum, frame):
            snap = eng.drain(args.drain_on_sigterm)
            print(f"SIGTERM: drained to {args.drain_on_sigterm} "
                  f"({len(snap['queued']) + len(snap['inflight'])} "
                  f"requests snapshotted)")
            raise SystemExit(0)

        signal.signal(signal.SIGTERM, _drain)
        if os.path.exists(args.drain_on_sigterm):
            resumed = eng.load_snapshot(args.drain_on_sigterm)
            print(f"resumed {len(resumed)} queued requests from "
                  f"{args.drain_on_sigterm}")
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p, seed=args.seed)
    rids = [eng.submit(p, int(m), sp, deadline_ms=args.deadline_ms)
            for p, m in zip(prompts, news)]
    rids = [r for r in rids if r]          # bounded queue may refuse some
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    st = eng.stats
    lat = st.token_latency_percentiles()
    ttft = st.ttft_percentiles()
    qw = st.queue_wait_percentiles()
    print(f"served {len(rids)} requests / {st.decoded_tokens} tokens "
          f"in {dt:.2f}s on {args.max_slots} slots "
          f"(prefill {st.prefill_tok_s():.1f} tok/s, "
          f"decode {st.decode_tok_s():.1f} tok/s, "
          f"p50/p99 token latency {lat[50] * 1e3:.1f}/{lat[99] * 1e3:.1f} ms)")
    print(f"ttft p50/p99 {ttft[50] * 1e3:.1f}/{ttft[99] * 1e3:.1f} ms "
          f"(queue wait p50/p99 {qw[50] * 1e3:.1f}/{qw[99] * 1e3:.1f} ms, "
          f"{st.admissions} admitted / {st.evictions} evicted)")
    print(f"decode compiled {eng.trace_counts['decode']}x across "
          f"{st.steps} steps")
    if args.deadline_ms is not None or args.max_queue:
        print(f"guardrails: {st.goodput_tokens} tokens within deadline "
              f"(goodput {st.goodput_tok_s():.1f} tok/s), {st.shed} shed, "
              f"{st.cancelled} cancelled, {st.deadline_misses} deadline "
              f"misses, {st.rejected_queue_full} queue-rejected, "
              f"{st.watchdog_stalls} watchdog stalls, brownout clamped "
              f"{st.brownout_clamped}")
    if eng.allocator is not None:
        al = eng.allocator
        print(f"paged cache: {eng.num_pages} pages x {eng.page_size} tok, "
              f"final occupancy {al.occupancy():.2f}, "
              f"prefix hit-rate {al.hit_rate():.2f} "
              f"({al.hit_tokens} tok cached), {al.cow_copies} COW copies, "
              f"{al.evictions} cache evictions")
    if rids:
        print("sample:", results[int(rids[0])][:16])
    if args.metrics_out:
        telemetry.dump_metrics(args.metrics_out)
        print(f"metrics -> {args.metrics_out}")
    if args.trace_out:
        telemetry.trace.export(args.trace_out)
        print(f"trace -> {args.trace_out}")


if __name__ == "__main__":
    main()
