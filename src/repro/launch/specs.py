"""``input_specs()``: ShapeDtypeStruct stand-ins for every model input at
every (arch x input-shape), plus abstract state/cache construction — no
device allocation (dry-run contract).

Modality frontends are STUBS per the assignment: VLM image tokens arrive as
precomputed patch/VQ embeddings, audio as precomputed frame embeddings.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models.registry import Model


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def train_batch_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "conv":
        return {"images": sds((B, cfg.image_size, cfg.image_size, 3),
                              jnp.float32),
                "labels": sds((B,), jnp.int32)}
    if cfg.family == "encdec":
        return {"frames": sds((B, cfg.encoder_seq_len, cfg.d_model),
                              jnp.float32),
                "tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32)}
    batch = {}
    n_text = S
    if cfg.modality == "vlm":
        n_img = min(cfg.num_image_tokens, S // 2)
        n_text = S - n_img
        batch["image_embeds"] = sds((B, n_img, cfg.d_model), jnp.float32)
    batch["tokens"] = sds((B, n_text), jnp.int32)
    batch["labels"] = sds((B, n_text), jnp.int32)
    return batch


def decode_batch_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    return {"tokens": sds((shape.global_batch, 1), jnp.int32)}


def abstract_params(model: Model, key=None):
    return jax.eval_shape(model.init, jax.random.key(0))


def abstract_state(model: Model, optimizer):
    params = abstract_params(model)
    return {
        "params": params,
        "opt": jax.eval_shape(optimizer.init, params),
        "step": sds((), jnp.int32),
    }


def abstract_cache(model: Model, cfg: ArchConfig, shape: InputShape):
    return jax.eval_shape(
        functools.partial(model.init_cache, shape.global_batch,
                          shape.seq_len))
