"""Production mesh construction (TPU v5e target).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16).

A FUNCTION (not module-level constant) so importing never touches jax device
state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(num_devices: int | None = None, axes=("data",)):
    """Small CPU-device mesh for tests/examples (paper-scale: 8 workers)."""
    n = num_devices or len(jax.devices())
    if len(axes) == 1:
        return jax.make_mesh((n,), axes)
    # split roughly evenly
    import math
    a = int(math.sqrt(n))
    while n % a:
        a -= 1
    return jax.make_mesh((n // a, a), axes)
