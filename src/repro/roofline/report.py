"""Generate EXPERIMENTS.md tables from experiments/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os


def load_results(dirpath: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def _fmt_b(x: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x / div:.1f}{unit}"
    return f"{x:.0f}B"


ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def dryrun_table(rows, mesh="16x16") -> str:
    lines = ["| arch | shape | mode | ok | per-dev arg bytes | temp bytes | "
             "collectives | compile |",
             "|---|---|---|---|---|---|---|---|"]
    rs = [r for r in rows if r["mesh"] == mesh
          and "__bsp" not in json.dumps(r.get("exchanger", ""))]
    rs.sort(key=lambda r: (r["arch"], ORDER.index(r["shape"])
                           if r["shape"] in ORDER else 9))
    for r in rs:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | "
                         f"{r.get('mode', '-')} | FAIL: "
                         f"{r.get('error', '?')[:60]} | | | | |")
            continue
        mem = r["memory"]
        colls = r["collectives"]["counts"]
        cstr = " ".join(f"{k.split('-')[-1] if False else k}:{v}"
                        for k, v in sorted(colls.items())) or "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('mode', '-')} | ok | "
            f"{_fmt_b(mem['argument_bytes'])} | {_fmt_b(mem['temp_bytes'])} | "
            f"{cstr} | {r.get('compile_s', 0):.0f}s |")
    return "\n".join(lines)


def roofline_table(rows, mesh="16x16") -> str:
    lines = ["| arch | shape | t_compute | t_memory | t_collective | "
             "dominant | MODEL/HLO flops | coll bytes/dev |",
             "|---|---|---|---|---|---|---|---|"]
    rs = [r for r in rows if r["mesh"] == mesh and r.get("ok")]
    rs.sort(key=lambda r: (r["arch"], ORDER.index(r["shape"])
                           if r["shape"] in ORDER else 9))
    for r in rs:
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rl['t_compute_s'])} | "
            f"{_fmt_s(rl['t_memory_s'])} | {_fmt_s(rl['t_collective_s'])} | "
            f"**{rl['dominant']}** | {rl['useful_ratio']:.2f} | "
            f"{_fmt_b(rl['coll_bytes'])} |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = load_results()
    print("## Dry-run (single-pod 16x16)\n")
    print(dryrun_table(rows, "16x16"))
    print("\n## Dry-run (multi-pod 2x16x16)\n")
    print(dryrun_table(rows, "2x16x16"))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(rows, "16x16"))
