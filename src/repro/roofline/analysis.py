"""Roofline analysis from compiled dry-run artifacts.

Terms (per device, TPU v5e constants):
    compute    = HLO_flops / PEAK_FLOPS
    memory     = HLO_bytes / HBM_BW
    collective = collective_bytes / ICI_BW

``cost_analysis()`` reports PER-DEVICE flops/bytes post-partitioning (verified
empirically), with while-loop bodies counted ONCE — the dry-run therefore
unrolls layer scans. Collective bytes are parsed from the optimized HLO
(``compiled.as_text()``): per-shard operand shapes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import os
import re
from dataclasses import dataclass, field

# TPU v5e
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # B/s
ICI_BW = 50e9            # B/s per link


def peaks() -> dict:
    """The peak model every achieved-vs-peak gauge divides by: the TPU v5e
    constants above, overridable per deployment via ``REPRO_PEAK_FLOPS`` /
    ``REPRO_PEAK_HBM_BW`` / ``REPRO_PEAK_ICI_BW`` (so MFU on other
    hardware is honest without a code change). Values are FLOP/s and B/s
    per device."""
    def _env(name, default):
        try:
            v = float(os.environ.get(name, "") or 0)
        except ValueError:
            v = 0.0
        return v if v > 0 else default
    return {"flops": _env("REPRO_PEAK_FLOPS", PEAK_FLOPS),
            "hbm_bw": _env("REPRO_PEAK_HBM_BW", HBM_BW),
            "ici_bw": _env("REPRO_PEAK_ICI_BW", ICI_BW)}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# result may be a single shape or a tuple of shapes; sum every shape
_COLL_RE = re.compile(
    r"=\s+(\([^)]*\)|[a-z0-9]+\[[\d,]*\]\S*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    bytes_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device bytes moved by each collective op.

    Approximation (documented): bytes-moved-per-device ~ result-shape bytes
    for AG/RS/A2A/permute; 2x for all-reduce (reduce + broadcast phases of a
    ring). The (k-1)/k factor is dropped (<7% at k=16).
    """
    stats = CollectiveStats()
    for m in _COLL_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        b = sum(_shape_bytes(dt, dims)
                for dt, dims in _SHAPE_RE.findall(shapes))
        if kind == "all-reduce":
            b *= 2
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
    return stats


_DOT_RE = re.compile(r"=\s+\S+\s+(?:dot|convolution)\(")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.-]+)\s*=")
_REF_RE = re.compile(r"%([\w.-]+)")


def overlap_evidence(hlo_text: str) -> dict:
    """Evidence that exchange collectives interleave with backward compute.

    The double-buffered ``overlap="buckets"`` schedule puts microbatch
    *i-1*'s reduce-scatter inside the scan/while body next to microbatch
    *i*'s backward dots (serialized exchange lives after the loop, so no
    single computation mixes the two). Two signals per computation:

    - **order**: a collective printed before the computation's last dot
      (on TPU the latency-hiding scheduler hoists the async ``-start``);
    - **independence**: a collective whose transitive operand closure
      contains no dot of the same computation — it consumes only
      loop-carried state, so it is *issuable* before the first backward
      dot regardless of how a synchronous backend (CPU) ordered the text.
    """
    blocks, cur = [], []
    for line in hlo_text.splitlines():
        cur.append(line)
        if line.startswith("}"):
            blocks.append(cur)
            cur = []
    if cur:
        blocks.append(cur)
    n_mixed = 0
    ordered = independent = False
    for blk in blocks:
        coll_idx = [i for i, l in enumerate(blk) if _COLL_RE.search(l)]
        dot_idx = [i for i, l in enumerate(blk) if _DOT_RE.search(l)]
        if not (coll_idx and dot_idx):
            continue
        n_mixed += 1
        if min(coll_idx) < max(dot_idx):
            ordered = True
        deps, dots = {}, set()
        dot_set = set(dot_idx)
        for i, l in enumerate(blk):
            m = _DEF_RE.match(l)
            if not m:
                continue
            name = m.group(1)
            deps[name] = [r for r in _REF_RE.findall(l.split("=", 1)[1])]
            if i in dot_set:
                dots.add(name)
        for i in coll_idx:
            m = _DEF_RE.match(blk[i])
            if not m:
                continue
            seen, stack = set(), list(deps.get(m.group(1), []))
            while stack:
                r = stack.pop()
                if r in seen:
                    continue
                seen.add(r)
                stack.extend(deps.get(r, []))
            if not (seen & dots):
                independent = True
                break
    return {"rs_before_last_dot": ordered or independent,
            "comm_independent_of_dots": independent,
            "computations_mixing_comm_and_dots": n_mixed}


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    model_flops: float = 0.0     # analytic 6ND (per device)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
        }


def analyze(compiled, *, model_flops_per_device: float = 0.0) -> dict:
    """Full analysis of one compiled executable."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):    # jax 0.4.x: list of per-program dicts
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    colls = parse_collectives(txt)
    rl = Roofline(flops, hbm, colls.total_bytes,
                  model_flops=model_flops_per_device)
    ma = compiled.memory_analysis()
    return {
        "roofline": rl.as_dict(),
        "collectives": {"counts": colls.counts,
                        "bytes_by_kind": colls.bytes_by_kind},
        "memory": {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ma, "temp_size_in_bytes", 0))
            + int(getattr(ma, "argument_size_in_bytes", 0)),
        },
    }


def model_flops_6nd(n_active_params: int, tokens: int, kind: str) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D inference (fwd only)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens


def attention_flops_bytes(*, batch: int, q_len: int, kv_len: int,
                          heads: int, kv_heads: int, head_dim_k: int,
                          head_dim_v: int = 0, window: int = 0,
                          causal: bool = True, q_start: int = 0,
                          kind: str = "fwd", dtype_bytes: int = 2) -> dict:
    """Analytic FLOPs and minimal HBM bytes for (windowed-)causal
    attention — the roofline an exact fused kernel can at best achieve.

    ``pairs`` counts surviving (q, k) interactions: query at absolute
    position ``q_start + i`` sees ``min(pos+1, kv_len)`` keys, clipped to
    ``window`` when one is set — so windowed layers get a *linear* (not
    quadratic) compute term and the bench can report achieved-vs-roofline
    per masking mode. FLOPs: 2·(Dk+Dv) per pair per head forward (QK^T +
    PV); the backward recomputes the score tile and runs the dQ/dK/dV
    matmuls (3·Dk + 2·Dv dots of 2 FLOPs each). Bytes: one q/k/v read +
    one out write at ``dtype_bytes`` (+ the fp32 lse/di residual rows and
    a re-read of everything for ``fwd+bwd``) — no (S, S) term at all,
    which is exactly what separates flash from the dense XLA path."""
    import numpy as np
    Dk = head_dim_k
    Dv = head_dim_v or head_dim_k
    if causal:
        pos = q_start + np.arange(q_len, dtype=np.int64)
        per_q = np.minimum(pos + 1, kv_len)
        if window > 0:
            per_q = np.minimum(per_q, window)
        pairs = int(per_q.sum())
    else:
        pairs = q_len * kv_len
    f_fwd = 2.0 * batch * heads * pairs * (Dk + Dv)
    f_bwd = 2.0 * batch * heads * pairs * (3 * Dk + 2 * Dv)
    flops = f_fwd + (f_bwd if kind != "fwd" else 0.0)
    qo_bytes = batch * q_len * heads * (Dk + Dv) * dtype_bytes
    kv_bytes = batch * kv_len * kv_heads * (Dk + Dv) * dtype_bytes
    hbm = qo_bytes + kv_bytes
    if kind != "fwd":
        hbm += 2 * (qo_bytes + kv_bytes)          # re-read + grad writes
        hbm += batch * q_len * heads * 2 * 4      # lse + di, fp32
    return {"flops": flops, "hbm_bytes": float(hbm), "pairs": pairs,
            "intensity": flops / max(hbm, 1.0)}
