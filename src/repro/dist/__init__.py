"""repro.dist — the distribution layer: sharding specs, activation
constraints, and mesh-aware placement for every train/serve path.

- ``repro.dist.sharding``: param/state/batch/cache spec rules + sanitizer
- ``repro.dist.act``:      sequence-parallel activation constraints
"""
from repro.dist import act, sharding
from repro.dist.sharding import (MODEL_AXIS, batch_shardings, cache_shardings,
                                 dp_axes_of, dp_size_of, param_shardings,
                                 param_spec, sanitize_spec,
                                 set_replicate_attn, state_shardings)

__all__ = [
    "MODEL_AXIS", "act", "sharding", "batch_shardings", "cache_shardings",
    "dp_axes_of", "dp_size_of", "param_shardings", "param_spec",
    "sanitize_spec", "set_replicate_attn", "state_shardings",
]
