"""Activation sharding constraints (sequence-parallel residual stream).

The model executors call ``act.constrain(x)`` on the residual stream between
layers (``models/transformer.py``, ``models/encdec.py``). Outside an
``activation_spec`` context that is an identity — smoke tests and eager
training pay nothing. Inside (the dry-run compiles with
``P(None, None, 'model')``: the residual feature dim sharded over the TP
axis) it becomes a rank-padded ``with_sharding_constraint``, pinning the
between-layer activation layout so XLA keeps the residual stream distributed
instead of all-gathering it after every layer — the activation-memory side
of tensor parallelism.

The spec is sanitized against the ambient mesh (the one installed by
``jax.set_mesh``) so a non-divisible feature dim degrades to replicated
rather than failing to compile.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import sanitize_spec

_STATE = threading.local()


def current_spec():
    """The active activation PartitionSpec, or None outside any context."""
    return getattr(_STATE, "spec", None)


@contextlib.contextmanager
def activation_spec(spec):
    """Make ``spec`` the activation constraint for the enclosed trace/compile.

    ``spec`` may be None (explicit no-op, e.g. decode shapes where the
    single-token residual is too small to shard). Contexts nest; the previous
    spec is restored on exit."""
    prev = getattr(_STATE, "spec", None)
    _STATE.spec = spec
    try:
        yield
    finally:
        _STATE.spec = prev


def _bound_axes():
    """Mesh axes currently bound as *manual* (shard_map) axes at trace time.

    A with_sharding_constraint may only reference auto axes; entries naming
    manual axes must drop. Under the 0.4.x fully-manual BSP shard_map every
    axis is bound, so the constraint degenerates to the identity there —
    jax>=0.5 partial shard_map leaves 'model' auto and keeps it."""
    try:
        from jax._src import core as jcore
        return frozenset(jcore.get_axis_env().axis_names())
    except Exception:  # noqa: BLE001 - introspection is best-effort
        return frozenset()


def _ambient_mesh():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and len(m.axis_names) > 0:
            return m
    except Exception:  # noqa: BLE001 - mesh introspection is best-effort
        pass
    return None


def constrain(x):
    """Apply the active activation constraint to ``x`` (identity if none).

    The spec is right-aligned to ``x``'s rank: leading dims are padded with
    None (batch/seq stay unconstrained), an over-long spec is trimmed from
    the left. With an ambient mesh available the padded spec is sanitized so
    non-divisible dims fall back to replicated instead of erroring."""
    spec = current_spec()
    if spec is None:
        return x
    entries = list(spec)
    nd = x.ndim
    if len(entries) > nd:
        entries = entries[len(entries) - nd:]
    entries = [None] * (nd - len(entries)) + entries
    bound = _bound_axes()
    if bound:
        def free(e):
            if isinstance(e, (tuple, list)):
                e = tuple(a for a in e if a not in bound)
                return e[0] if len(e) == 1 else (e or None)
            return None if e in bound else e
        entries = [free(e) for e in entries]
    if all(e is None for e in entries):
        return x
    mesh = _ambient_mesh()
    if mesh is not None:
        p = sanitize_spec(P(*entries), x.shape, mesh)
    else:
        p = P(*entries)
    return jax.lax.with_sharding_constraint(x, p)
