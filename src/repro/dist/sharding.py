"""Mesh-aware sharding rules — the single authority for parameter, optimizer
state, batch, and cache placement on the ``(pod, data, model)`` meshes.

Every train/serve path asks this module where things live:

- BSP (``core/bsp.py``):     ``state_shardings`` / ``batch_shardings`` give
  the jit ``in_shardings``; parameters are model-sharded only, replicated
  over the data/pod axes so the exchangers' shard_map manual axes stay
  untouched.
- GSPMD/ZeRO-1 (``core/gspmd.py``): ``fsdp_param_spec`` extends
  ``param_spec`` with the data axis on a free dimension.
- async plans (``core/easgd.py``): per-worker replica stacks put the
  leading worker dim over the data axes; the engine
  (``repro.train.engine``) composes these placements per TrainPlan and
  ``batch_shardings`` splits gspmd batches.
- dry-run (``launch/dryrun.py``):   all builders, on 16x16 and 2x16x16.
- decode (``build_decode``):        ``param_shardings`` + ``cache_shardings``.

Placement policy (tensor parallelism over ``MODEL_AXIS``):

===============================  ==========================================
leaf                             spec (for the unstacked trailing dims)
===============================  ==========================================
attention q/k/v, MLA up-proj     heads dim on ``model``
attention out (wo)               contracting (heads*hd) dim on ``model``
MLA latent down-proj (wdkv)      latent dim on ``model``
MoE experts (wi/wu/wd)           expert dim on ``model`` (expert parallel)
dense/shared FFN wi/wu           ffn dim on ``model``
dense/shared FFN wd              ffn (contracting) dim on ``model``
SSM in-proj wz/wx                d_inner dim on ``model``
SSM out_proj                     d_inner (contracting) dim on ``model``
embeddings / lm head             vocab dim on ``model``
conv kernels, norms, biases,     replicated
router, SSM scalars, rope keys
===============================  ==========================================

Leaves inside stacked layer segments carry a leading layer dim; specs are
right-aligned to the leaf rank, so the same rule covers stacked and
unstacked layouts.  ``sanitize_spec`` then repairs any axis whose dim is not
divisible by the mesh extent — relocating it to the nearest free divisible
dim (preferring dims to the right: 20 heads on model=16 move to head_dim)
or dropping it to replicated when nothing divides.

``set_replicate_attn(True)`` (dry-run ``--replicate-attn``) turns off tensor
parallelism for attention/SSM mixer parameters, leaving only FFN/embedding
TP — the ablation knob for attention-collective cost.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"

# attention/SSM mixer leaves affected by set_replicate_attn
_ATTN_KEYS = frozenset({"wq", "wk", "wv", "wo", "bq", "bk", "bv",
                        "wuk", "wuv", "wdkv", "wkr"})
_SSM_KEYS = frozenset({"wz", "wx", "wbc", "wdt", "out_proj",
                       "conv_w", "conv_b", "A_log", "dt_bias", "D", "norm"})

_REPLICATE_ATTN = False


def set_replicate_attn(flag: bool) -> None:
    """Globally replicate attention/SSM mixer params (no TP on them)."""
    global _REPLICATE_ATTN
    _REPLICATE_ATTN = bool(flag)


# ---------------------------------------------------------------------------
# mesh topology
# ---------------------------------------------------------------------------

def dp_axes_of(mesh) -> tuple:
    """Data-parallel axes (everything but ``model``): ('data',) single-pod,
    ('pod', 'data') multi-pod — mesh order, as the exchangers expect."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


def dp_size_of(mesh) -> int:
    """Total data-parallel world size (product over data+pod axes)."""
    k = 1
    for a in dp_axes_of(mesh):
        k *= mesh.shape[a]
    return k


def _extent(mesh, entry) -> int:
    """Mesh extent of one PartitionSpec entry (axis name or tuple of them)."""
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        k = 1
        for a in entry:
            k *= mesh.shape[a]
        return k
    return mesh.shape[entry]


def _dp_entry(mesh):
    """The spec entry sharding one dim over all data axes (None if pure-TP)."""
    dp = dp_axes_of(mesh)
    if not dp:
        return None
    return dp if len(dp) > 1 else dp[0]


# ---------------------------------------------------------------------------
# spec sanitizer
# ---------------------------------------------------------------------------

def sanitize_spec(spec, shape, mesh) -> P:
    """Repair ``spec`` for ``shape`` on ``mesh``: every surviving mesh axis
    divides its dim, or it is gone.

    For each entry whose dim is NOT divisible by the entry's mesh extent,
    relocate it to the nearest *free* divisible dim — scanning right first
    (20 heads on model=16 move to head_dim), then left — and drop it
    entirely when nothing divides. Trailing ``None``s are stripped, so a
    fully-dropped 1-D spec comes back as ``P()``.

    Only needs ``mesh.axis_names``/``mesh.shape``, so tests may pass a fake
    mesh without allocating devices.
    """
    entries = list(spec)
    if len(entries) > len(shape):
        entries = entries[:len(shape)]
    entries += [None] * (len(shape) - len(entries))
    for i, e in enumerate(entries):
        if e is None:
            continue
        # axes absent from this mesh (e.g. 'model' on a pure-DP mesh) drop
        if isinstance(e, (tuple, list)):
            e = tuple(a for a in e if a in mesh.shape)
            e = e[0] if len(e) == 1 else (e or None)
        elif e not in mesh.shape:
            e = None
        entries[i] = e
        if e is None:
            continue
        k = _extent(mesh, e)
        if k <= 1 or shape[i] % k == 0:
            continue
        cands = [j for j in range(i + 1, len(entries))
                 if entries[j] is None and shape[j] % k == 0]
        cands += [j for j in range(i - 1, -1, -1)
                  if entries[j] is None and shape[j] % k == 0]
        entries[i] = None
        if cands:
            entries[cands[0]] = e
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


# ---------------------------------------------------------------------------
# parameter rule engine
# ---------------------------------------------------------------------------

def _path_names(path) -> list:
    """Key names along a jax tree path (DictKey/SequenceKey/GetAttrKey)."""
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "name"):
            names.append(str(e.name))
        elif hasattr(e, "idx"):
            names.append(str(e.idx))
        else:
            names.append(str(e))
    return names


def _base_rule(names: list, key: str, leaf) -> tuple:
    """Spec for the trailing (unstacked) dims; () means fully replicated."""
    M = MODEL_AXIS
    if _REPLICATE_ATTN and (key in _ATTN_KEYS or key in _SSM_KEYS):
        return ()
    if key in ("wq", "wk", "wv", "wuk", "wuv"):
        return (None, M, None)          # (d|R, heads, head_dim): shard heads
    if key == "wo":
        return (M, None)                # (heads*hd, d): shard contracting dim
    if key in ("bq", "bk", "bv"):
        return (M, None)                # (heads, head_dim)
    if key == "wdkv":
        return (None, M)                # (d, kv_lora_rank): shard the latent
    if key == "wkr":
        return ()                       # shared rope key: small, replicated
    if key in ("wi", "wu", "wd") and "moe" in names and "shared" not in names:
        return (M, None, None)          # (E, ., .): expert parallelism
    if key in ("wi", "wu", "wz", "wx"):
        return (None, M)                # (d, ffn|d_inner): shard hidden dim
    if key in ("wd", "out_proj"):
        return (M, None)                # (ffn|d_inner, d): shard hidden dim
    if key == "embed":
        return (M, None)                # (vocab, d): shard vocab
    if key == "head":
        return (None, M)                # (d, vocab): shard vocab
    if key == "w":
        # vision: 2-D fc sharded on out-features, 4-D conv kernels replicated
        return (None, M) if getattr(leaf, "ndim", 0) == 2 else ()
    return ()   # norms, biases, router, conv, meta tokens, scalars


def param_spec(path, leaf) -> P:
    """PartitionSpec for one parameter leaf, right-aligned to its rank.

    ``path`` is a ``jax.tree_util`` key path (as produced by
    ``tree_map_with_path``); the rule keys off the leaf's dict-key name and
    its ancestors, so stacked-layer leading dims are transparently skipped.
    The result is *not* divisibility-checked — compose with
    ``sanitize_spec`` (the ``*_shardings`` builders do)."""
    names = _path_names(path)
    key = names[-1] if names else ""
    nd = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
    base = list(_base_rule(names, key, leaf))
    if not base:
        return P()
    if len(base) > nd:
        base = base[len(base) - nd:]
    return P(*([None] * (nd - len(base)) + base))


# ---------------------------------------------------------------------------
# sharding builders (NamedSharding trees for jit in_shardings)
# ---------------------------------------------------------------------------

def param_shardings(mesh, params):
    """Model-sharded, data-replicated NamedShardings for a parameter tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, sanitize_spec(param_spec(path, leaf), leaf.shape, mesh)),
        params)


def state_shardings(mesh, state):
    """BSP train-state shardings: the paper's replicated data parallelism.

    Parameters and optimizer state are replicated over the WHOLE mesh (the
    exchangers own the data axes as shard_map manual axes; the model axis
    contributes through activation constraints only). Replication is also a
    hard requirement on jaxlib 0.4.x: its SPMD partitioner aborts when a
    manual-subgroup collective (the exchanger's all_to_all/all_gather over
    'data') consumes an operand sharded on an auto axis. Architectures too
    big to replicate take the GSPMD/ZeRO-1 path (``fsdp_state_shardings``),
    selected by the FSDP threshold in ``launch/dryrun.py``."""
    rep = NamedSharding(mesh, P())
    return jax.tree.map(lambda _: rep, state)


def batch_shardings(mesh, batch):
    """Batch leaves sharded over the data(+pod) axes on dim 0."""
    dpe = _dp_entry(mesh)

    def leaf(l):
        spec = P(dpe) if dpe is not None else P()
        return NamedSharding(mesh, sanitize_spec(spec, l.shape, mesh))

    return jax.tree.map(leaf, batch)


def cache_shardings(mesh, cache, global_batch: int,
                    page_batch: int | None = None):
    """Decode-cache shardings: batch dim over data axes, head-like dims over
    ``model`` (KV heads for GQA k/v, the latent for MLA ckv, SSM heads for
    recurrent state); conv windows and rope keys replicated.

    ``page_batch``: page count of a paged serve pool — attention leaves
    there carry (layers, num_pages, page_size, ...) instead of a slot
    batch dim, and the page dim shards over the data axes exactly like the
    slot dim does (pages are the unit of cache parallelism)."""
    dpe = _dp_entry(mesh)

    def leaf(path, l):
        names = _path_names(path)
        key = names[-1] if names else ""
        entries = [None] * l.ndim
        if dpe is not None:
            for i, s in enumerate(l.shape):
                if s == global_batch or (page_batch is not None
                                         and s == page_batch):
                    entries[i] = dpe
                    break
        if not _REPLICATE_ATTN:
            mi = None
            if key in ("k", "v") and l.ndim >= 2:
                mi = l.ndim - 2          # (..., S, KV, hd): KV heads
            elif key == "ckv":
                mi = l.ndim - 1          # (..., S, R): MLA latent
            elif key == "state" and l.ndim >= 3:
                mi = l.ndim - 3          # (..., nh, N, P): SSM heads
            if mi is not None and entries[mi] is None:
                entries[mi] = MODEL_AXIS
        return NamedSharding(mesh, sanitize_spec(P(*entries), l.shape, mesh))

    return jax.tree_util.tree_map_with_path(leaf, cache)
