"""Serving: batched autoregressive decode against a KV/SSM cache."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.registry import Model, build_model


def make_serve_step(model: Model, *, seq_len: int, unroll: bool = False):
    """Returns ``serve(params, cache, tokens(B,1), pos) -> (next, cache)``
    sampling greedily. ``pos`` is the current cache write index."""

    def serve(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache,
                                          {"tokens": tokens}, pos,
                                          seq_len=seq_len, unroll=unroll)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve


def generate(model: Model, params, prompt, *, max_new: int, seq_len: int,
             mesh=None):
    """Greedy generation: prefill the prompt token-by-token (functional
    reference path), then decode ``max_new`` tokens."""
    B, S0 = prompt.shape
    total = S0 + max_new
    cache = model.init_cache(B, total)
    serve = jax.jit(make_serve_step(model, seq_len=total))
    tok = prompt[:, :1]
    out = [tok]
    for i in range(total - 1):
        if i + 1 < S0:
            nxt_forced = prompt[:, i + 1:i + 2]
            _, cache = serve(params, cache, tok, jnp.int32(i))
            tok = nxt_forced
        else:
            tok, cache = serve(params, cache, tok, jnp.int32(i))
        out.append(tok)
    return jnp.concatenate(out, axis=1)
