"""Serving: batched autoregressive decode against a KV/SSM cache.

``generate`` is the functional reference path and the serving engine's
greedy parity oracle (``repro.serve.engine`` must match it bit-for-bit per
request). Prefill is one batched ``chunk_prefill`` call that writes every
layer's cache in a single pass — the old loop issued one ``serve()`` call
per forced prompt token, paying S0 model dispatches and S0 wasted LM-head
projections for logits it threw away (``_generate_stepwise`` keeps that
path as the cross-check oracle for the prefill rewrite itself).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.registry import Model, build_model


def make_serve_step(model: Model, *, seq_len: int, unroll: bool = False):
    """Returns ``serve(params, cache, tokens(B,1), pos) -> (next, cache)``
    sampling greedily. ``pos`` is the current cache write index."""

    def serve(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache,
                                          {"tokens": tokens}, pos,
                                          seq_len=seq_len, unroll=unroll)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve


def generate(model: Model, params, prompt, *, max_new: int, seq_len: int,
             mesh=None):
    """Greedy generation: one whole-prompt prefill call, then decode
    ``max_new`` tokens. Families without a chunked prefill (encdec) keep
    the token-by-token forced-decode path."""
    if model.chunk_prefill is None:
        return _generate_stepwise(model, params, prompt, max_new=max_new,
                                  seq_len=seq_len)
    B, S0 = prompt.shape
    total = S0 + max_new
    cache = model.init_cache(B, total)
    serve = jax.jit(make_serve_step(model, seq_len=total))
    prefill = jax.jit(functools.partial(model.chunk_prefill,
                                        seq_len=total))
    logits, cache = prefill(params, cache, prompt, jnp.int32(0),
                            jnp.int32(S0))
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out = [prompt, tok]
    for i in range(S0, total - 1):
        tok, cache = serve(params, cache, tok, jnp.int32(i))
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def _generate_stepwise(model: Model, params, prompt, *, max_new: int,
                       seq_len: int):
    """Token-by-token forced-prefill reference (the pre-rewrite ``generate``
    semantics): one decode call per prompt token, logits discarded. Kept as
    the oracle proving the one-call prefill preserves outputs."""
    B, S0 = prompt.shape
    total = S0 + max_new
    cache = model.init_cache(B, total)
    serve = jax.jit(make_serve_step(model, seq_len=total))
    tok = prompt[:, :1]
    out = [tok]
    for i in range(total - 1):
        if i + 1 < S0:
            nxt_forced = prompt[:, i + 1:i + 2]
            _, cache = serve(params, cache, tok, jnp.int32(i))
            tok = nxt_forced
        else:
            tok, cache = serve(params, cache, tok, jnp.int32(i))
        out.append(tok)
    return jnp.concatenate(out, axis=1)
