"""Training loop: engine step + parallel loader + metrics + checkpointing.

Algorithm-agnostic: a :class:`~repro.train.engine.TrainPlan` resolves to an
engine and the loop drives it — bsp, easgd, asgd and gspmd all share this
loop, its checkpoint save/resume, and its loss accounting. The legacy
keyword surface (``exchanger=``, ``scheme=``, ...) still works and simply
builds a bsp plan.

Resume contract: the rng is folded with the *global* step index and the
loop consumes (and discards) the first ``start_step`` batches of the
iterable, so a run restored from a mid-run checkpoint replays exactly the
uninterrupted run (bitwise — tested per algo in ``tests/test_engine.py``).
Callers therefore pass a batch iterable that restarts from step 0. The
skip pays the loader's cost for the discarded batches — cheap for the
synthetic/index-keyed sources here, where producing batch i is O(1); a
loader with expensive staging should defer device transfer until a batch
is actually consumed so the skip stays metadata-only.

Telemetry (host-side only — no op is added to the jitted step):

- spans ``train/data`` / ``train/step`` / ``train/flush`` per step, so a
  ``--trace-out`` Perfetto file shows where host wall time goes. Steps
  dispatch asynchronously: ``train/step`` times *dispatch*; queued device
  work surfaces in the ``train/flush`` span at log boundaries and in the
  loop-iteration histogram.
- histograms ``train/data_time_s`` / ``train/step_time_s`` (loop
  iteration, first step excluded — that one is compile) and counters
  ``train/steps`` / ``train/examples`` / ``train/tokens`` /
  ``exchange/bytes_wire`` (the engine's analytic per-step wire traffic).
- gauges at flush boundaries only (one device sync per window, never per
  step): ``train/loss``, ``train/lr``, ``train/examples_per_s``,
  ``train/model_flops_s`` (6·N·D achieved, cross-referenced from
  ``roofline.analysis.model_flops_6nd``), ``train/mfu`` when
  ``REPRO_PEAK_FLOPS`` names the device peak, ``train/grad_norm`` when
  the opt-in is on, and ``train/device_mem_bytes`` when the backend
  exposes ``memory_stats()``.

The first step's wall time (compile + first execution) is recorded as
``TrainReport.compile_time`` and excluded from
``TrainReport.steady_examples_per_s`` — ``examples_per_s`` keeps the
total-wall-clock meaning it always had.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

import jax

from repro import telemetry
from repro.checkpoint.ckpt import restore_for_resume, save_checkpoint
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer
from repro.roofline.analysis import model_flops_6nd
from repro.telemetry import anomaly, metrics, profile, trace
from repro.train.engine import TrainPlan, build_engine

# exchange-half micro-timing materializes one (k, ...) zero-gradient stack;
# skip it beyond this size (the cost capture via lower() still happens)
_HALF_TIMING_CAP_BYTES = 256 << 20

# when logging is off, losses still move to host in bounded windows (a long
# run must not accumulate one device scalar per step)
_FLUSH_CAP = 100


@dataclass
class TrainReport:
    steps: int = 0
    losses: list = field(default_factory=list)
    wall_time: float = 0.0
    examples_per_s: float = 0.0
    # first-step wall time (compile + first execution) and the rate with
    # that step excluded — the honest steady-state throughput
    compile_time: float = 0.0
    steady_examples_per_s: float = 0.0


def _count_params(model: Model) -> int:
    import numpy as np
    abs_p = jax.eval_shape(model.init, jax.random.key(0))
    return int(sum(int(np.prod(l.shape)) if l.shape else 1
                   for l in jax.tree.leaves(abs_p)))


def _batch_counts(batch) -> tuple[int, int]:
    """(examples, tokens) in one global batch. Token-shaped leading leaf
    (B, S) counts B*S tokens; image/label-only batches count examples."""
    first = jax.tree.leaves(batch)[0]
    b = int(first.shape[0])
    toks = batch.get("tokens") if isinstance(batch, dict) else None
    if toks is not None and len(toks.shape) >= 2:
        return b, int(toks.shape[0]) * int(toks.shape[1])
    return b, b


def _device_mem_bytes():
    try:
        stats = jax.local_devices()[0].memory_stats()
    except Exception:  # noqa: BLE001 — backend without memory introspection
        return None
    if not stats:
        return None
    return stats.get("bytes_in_use")


def _profile_exchange_halves(model: Model, plan: TrainPlan, mesh) -> None:
    """Per-half exchange attribution: standalone jitted RS/AG programs
    (``exchanger.half_programs``) are lowered for cost analysis and — when
    the gradient stack is small enough — micro-timed on zeros so the
    profile carries measured achieved-bandwidth for each half. Collective
    bytes come from the analytic ``wire_summary`` (same numbers as
    ``exchange/bytes_per_step``). Never raises into the train loop."""
    import time as _time

    import numpy as np

    from repro.core.exchanger import (get_exchanger, half_programs,
                                      wire_summary)
    try:
        ex = get_exchanger(plan.exchanger)
        if ex.kind == "none":
            return
        axis = plan.data_axes[-1]
        params_abs = jax.eval_shape(model.init, jax.random.key(0))
        rs_fn, ag_fn, grads_abs, shards_abs, rsplan = half_programs(
            ex, params_abs, mesh, axis=axis,
            bucket_bytes=plan.bucket_bytes)
        ws = wire_summary(ex, rsplan,
                          param_ag=bool(plan.sharded_update or plan.overlap))
        profile.capture("exchange/rs", rs_fn, grads_abs,
                        coll_bytes=ws["rs_bytes"])
        if shards_abs:
            profile.capture("exchange/ag", ag_fn, shards_abs,
                            coll_bytes=ws["ag_bytes"])
        stack_bytes = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                          for l in jax.tree.leaves(grads_abs))
        if stack_bytes > _HALF_TIMING_CAP_BYTES:
            return
        import jax.numpy as jnp
        grads = jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype),
                             grads_abs)
        shards = [jnp.zeros(l.shape, l.dtype) for l in shards_abs]
        for name, fn, args in (("exchange/rs", rs_fn, grads),
                               ("exchange/ag", ag_fn, shards)):
            if not args and name == "exchange/ag":
                continue
            t0 = _time.perf_counter()
            out = fn(args)
            jax.block_until_ready(out)
            profile.compile_time(name, _time.perf_counter() - t0)
            for _ in range(2):
                t0 = _time.perf_counter()
                out = fn(args)
                jax.block_until_ready(out)
                profile.observe(name, _time.perf_counter() - t0)
    except Exception as e:  # noqa: BLE001 — attribution never breaks training
        metrics.counter("profile/capture_errors").inc()
        trace.instant("profile/exchange_halves_error",
                      error=f"{type(e).__name__}: {e}")


def train(model: Model, optimizer: Optimizer, lr_fn, mesh, batches, *,
          plan: TrainPlan | None = None, algo: str = "bsp",
          exchanger: str = "asa", scheme: str = "subgd",
          data_axes=("data",), num_steps: int = 100, seed: int = 0,
          log_every: int = 10, ckpt_path: str | None = None,
          ckpt_every: int = 0, ckpt_keep: int = 3,
          resume_from: str | None = None,
          state=None, sum_fn=None, microbatches: int = 1,
          bucket_bytes: int = 0, sharded_update: bool = False,
          overlap: str | None = None, tau: int = 1,
          alpha: float | None = None, mode: str = "zero1",
          print_fn=print) -> tuple[dict, TrainReport]:
    """``batches``: iterable of device-ready batches (e.g. ParallelLoader).

    Pass ``plan`` to pick the algorithm explicitly; the remaining algo
    keywords (``exchanger``/``scheme``/``tau``/``alpha``/``mode``/...) are
    the flat legacy surface and are folded into a plan when ``plan`` is
    None. ``resume_from`` restores a checkpoint written by the same plan
    (state + step + rng fold offset) and continues to ``num_steps``."""
    if plan is None:
        plan = TrainPlan(algo=algo, exchanger=exchanger, scheme=scheme,
                         data_axes=tuple(data_axes),
                         microbatches=microbatches,
                         bucket_bytes=bucket_bytes,
                         sharded_update=sharded_update, overlap=overlap,
                         tau=tau, alpha=alpha, mode=mode)
    engine = build_engine(plan, model, optimizer, lr_fn, mesh,
                          sum_fn=sum_fn)
    if state is None:
        state = engine.init_state(jax.random.key(seed))
    start_step = 0
    if resume_from:
        # restore onto the engine-initialized state: structure, dtypes AND
        # placement (sharded opt-state shards land back on their ranks)
        state, start_step = restore_for_resume(resume_from, state,
                                               expect_algo=plan.algo)
    rng = jax.random.key(seed + 1)

    # -- telemetry handles (all no-ops when REPRO_TELEMETRY=0) --------------
    c_steps = metrics.counter("train/steps")
    c_examples = metrics.counter("train/examples")
    c_tokens = metrics.counter("train/tokens")
    h_data = metrics.histogram("train/data_time_s")
    h_step = metrics.histogram("train/step_time_s")
    h_flush = metrics.histogram("train/flush_time_s")
    g_loss = metrics.gauge("train/loss")
    g_lr = metrics.gauge("train/lr")
    g_exps = metrics.gauge("train/examples_per_s")
    g_flops = metrics.gauge("train/model_flops_s")
    metrics.info("train/plan", algo=plan.algo, exchanger=plan.exchanger,
                 scheme=plan.scheme, arch=getattr(model.cfg, "name", ""))
    wire = engine.wire
    c_wire = metrics.counter("exchange/bytes_wire")
    if wire:
        metrics.info("exchange/config",
                     **{k: wire[k] for k in ("strategy", "wire_dtype",
                                             "ag_dtype", "k", "num_buckets",
                                             "sync_every")})
        metrics.gauge("exchange/bytes_per_step").set(wire["bytes_per_step"])
    n_params = _count_params(model)
    peak_flops = float(os.environ.get("REPRO_PEAK_FLOPS", "0") or 0)
    # step-time anomaly watch: spikes (robust-z vs a rolling median/MAD
    # window) and sustained regressions (fast-vs-slow EWMA) land as
    # anomaly/* counters + trace instants
    det_step = anomaly.StreamDetector("train/step_time")
    seen_progs: set = set()

    report = TrainReport()
    report.steps = start_step
    n_examples = 0
    n_tokens = 0
    t0 = time.perf_counter()
    it = iter(batches)
    try:
        for _ in range(start_step):   # batches the checkpointed run saw
            next(it)
    except StopIteration:
        return state, report
    # losses stay on device between flush boundaries: a per-step float()
    # would block dispatch every step. Flushed every log_every steps (or
    # _FLUSH_CAP when logging is off) so the buffer stays bounded.
    flush_every = min(log_every, _FLUSH_CAP) if log_every else _FLUSH_CAP
    device_losses = []
    device_grad_norm = None
    saved_at = None
    t_steady0 = t0
    steady_base_ex = steady_base_tok = 0
    for i in range(start_step, num_steps):
        t_iter0 = time.perf_counter()
        with trace.span("train/data"):
            try:
                batch = next(it)
            except StopIteration:
                break
        t_step0 = time.perf_counter()
        with trace.span("train/step", step=i):
            state, step_metrics = engine.step(
                state, batch, jax.random.fold_in(rng, i), step_idx=i)
        device_losses.append(step_metrics["loss"])
        device_grad_norm = step_metrics.get("grad_norm")
        b_ex, b_tok = _batch_counts(batch)
        n_examples += b_ex
        n_tokens += b_tok
        first_step = i == start_step
        # which jitted program this iteration dispatched (the async loop
        # alternates local/sync on the host-side step index)
        if plan.is_async:
            prog = ("train/sync" if (i + 1) % plan.tau == 0
                    else "train/local")
        else:
            prog = "train/step"
        if first_step:
            # the first step carries compilation: block so its cost lands
            # here (one extra sync for the whole run) and keep it out of
            # the steady-state histograms/rates
            with trace.span("train/compile_block"):
                jax.block_until_ready(device_losses[-1])
            report.compile_time = time.perf_counter() - t_step0
            seen_progs.add(prog)
            if profile.enabled() and wire:
                with trace.span("profile/exchange_halves"):
                    _profile_exchange_halves(model, plan, mesh)
            t_steady0 = time.perf_counter()
            steady_base_ex, steady_base_tok = n_examples, n_tokens
        c_steps.inc()
        c_examples.inc(b_ex)
        c_tokens.inc(b_tok)
        if wire:
            c_wire.inc(wire["bytes_per_step"])
        h_data.observe(t_step0 - t_iter0)
        if not first_step:
            t_now = time.perf_counter()
            h_step.observe(t_now - t_iter0)
            # join measured duration into the program's profile — under
            # async dispatch the loop's backpressure amortizes device time
            # into these iteration figures (same caveat as h_step). Each
            # program's own first dispatch is its compiling call
            # (train/sync first fires at step tau-1) — keep it out of the
            # per-program mean like the first step stays out of h_step.
            if prog in seen_progs:
                profile.observe(prog, t_now - t_step0)
            else:
                seen_progs.add(prog)
            det_step.observe(t_now - t_step0)
        if log_every and (i % log_every == 0 or i == num_steps - 1):
            with trace.span("train/flush", step=i):
                t_f = time.perf_counter()
                loss = float(device_losses[-1])       # device sync
                h_flush.observe(time.perf_counter() - t_f)
            print_fn(f"step {i:5d}  loss {loss:.4f}")
            g_loss.set(loss)
            g_lr.set(float(lr_fn(i)))
            if device_grad_norm is not None:
                metrics.gauge("train/grad_norm").set(
                    float(device_grad_norm))
            steady_t = time.perf_counter() - t_steady0
            if steady_t > 0 and n_examples > steady_base_ex:
                g_exps.set((n_examples - steady_base_ex) / steady_t)
                flops_s = model_flops_6nd(
                    n_params, n_tokens - steady_base_tok, "train") / steady_t
                g_flops.set(flops_s)
                if peak_flops > 0:
                    metrics.gauge("train/mfu").set(flops_s / peak_flops)
            mem = _device_mem_bytes()
            if mem is not None:
                metrics.gauge("train/device_mem_bytes").set(mem)
            telemetry.flush(force=False)
        if len(device_losses) >= flush_every:
            report.losses.extend(float(l) for l in device_losses)
            device_losses.clear()
        if ckpt_path and ckpt_every and (i + 1) % ckpt_every == 0:
            with trace.span("train/checkpoint", step=i + 1):
                save_checkpoint(ckpt_path, state, step=i + 1,
                                algo=plan.algo, keep=ckpt_keep)
            saved_at = i + 1
        report.steps = i + 1
    with trace.span("train/final_block"):
        jax.block_until_ready(state)
    report.wall_time = time.perf_counter() - t0
    report.losses.extend(float(l) for l in device_losses)
    report.examples_per_s = n_examples / max(report.wall_time, 1e-9)
    steady_t = time.perf_counter() - t_steady0
    if n_examples > steady_base_ex and steady_t > 0:
        report.steady_examples_per_s = ((n_examples - steady_base_ex)
                                        / steady_t)
    if ckpt_path and report.steps != saved_at:
        # the in-loop save already covered the final step when ckpt_every
        # divides it — don't write the same step twice
        save_checkpoint(ckpt_path, state, step=report.steps, algo=plan.algo,
                        keep=ckpt_keep)
    telemetry.flush(force=True)
    return state, report
