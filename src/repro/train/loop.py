"""Training loop: engine step + parallel loader + metrics + checkpointing.

Algorithm-agnostic: a :class:`~repro.train.engine.TrainPlan` resolves to an
engine and the loop drives it — bsp, easgd, asgd and gspmd all share this
loop, its checkpoint save/resume, and its loss accounting. The legacy
keyword surface (``exchanger=``, ``scheme=``, ...) still works and simply
builds a bsp plan.

Resume contract: the rng is folded with the *global* step index and the
loop consumes (and discards) the first ``start_step`` batches of the
iterable, so a run restored from a mid-run checkpoint replays exactly the
uninterrupted run (bitwise — tested per algo in ``tests/test_engine.py``).
Callers therefore pass a batch iterable that restarts from step 0. The
skip pays the loader's cost for the discarded batches — cheap for the
synthetic/index-keyed sources here, where producing batch i is O(1); a
loader with expensive staging should defer device transfer until a batch
is actually consumed so the skip stays metadata-only.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax

from repro.checkpoint.ckpt import restore_for_resume, save_checkpoint
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer
from repro.train.engine import TrainPlan, build_engine

# when logging is off, losses still move to host in bounded windows (a long
# run must not accumulate one device scalar per step)
_FLUSH_CAP = 100


@dataclass
class TrainReport:
    steps: int = 0
    losses: list = field(default_factory=list)
    wall_time: float = 0.0
    examples_per_s: float = 0.0


def train(model: Model, optimizer: Optimizer, lr_fn, mesh, batches, *,
          plan: TrainPlan | None = None, algo: str = "bsp",
          exchanger: str = "asa", scheme: str = "subgd",
          data_axes=("data",), num_steps: int = 100, seed: int = 0,
          log_every: int = 10, ckpt_path: str | None = None,
          ckpt_every: int = 0, resume_from: str | None = None,
          state=None, sum_fn=None, microbatches: int = 1,
          bucket_bytes: int = 0, sharded_update: bool = False,
          overlap: str | None = None, tau: int = 1,
          alpha: float | None = None, mode: str = "zero1",
          print_fn=print) -> tuple[dict, TrainReport]:
    """``batches``: iterable of device-ready batches (e.g. ParallelLoader).

    Pass ``plan`` to pick the algorithm explicitly; the remaining algo
    keywords (``exchanger``/``scheme``/``tau``/``alpha``/``mode``/...) are
    the flat legacy surface and are folded into a plan when ``plan`` is
    None. ``resume_from`` restores a checkpoint written by the same plan
    (state + step + rng fold offset) and continues to ``num_steps``."""
    if plan is None:
        plan = TrainPlan(algo=algo, exchanger=exchanger, scheme=scheme,
                         data_axes=tuple(data_axes),
                         microbatches=microbatches,
                         bucket_bytes=bucket_bytes,
                         sharded_update=sharded_update, overlap=overlap,
                         tau=tau, alpha=alpha, mode=mode)
    engine = build_engine(plan, model, optimizer, lr_fn, mesh,
                          sum_fn=sum_fn)
    if state is None:
        state = engine.init_state(jax.random.key(seed))
    start_step = 0
    if resume_from:
        # restore onto the engine-initialized state: structure, dtypes AND
        # placement (sharded opt-state shards land back on their ranks)
        state, start_step = restore_for_resume(resume_from, state,
                                               expect_algo=plan.algo)
    rng = jax.random.key(seed + 1)

    report = TrainReport()
    report.steps = start_step
    n_examples = 0
    t0 = time.perf_counter()
    it = iter(batches)
    try:
        for _ in range(start_step):   # batches the checkpointed run saw
            next(it)
    except StopIteration:
        return state, report
    # losses stay on device between flush boundaries: a per-step float()
    # would block dispatch every step. Flushed every log_every steps (or
    # _FLUSH_CAP when logging is off) so the buffer stays bounded.
    flush_every = min(log_every, _FLUSH_CAP) if log_every else _FLUSH_CAP
    device_losses = []
    saved_at = None
    for i in range(start_step, num_steps):
        try:
            batch = next(it)
        except StopIteration:
            break
        state, metrics = engine.step(state, batch,
                                     jax.random.fold_in(rng, i), step_idx=i)
        device_losses.append(metrics["loss"])
        first = jax.tree.leaves(batch)[0]
        n_examples += int(first.shape[0])
        if log_every and (i % log_every == 0 or i == num_steps - 1):
            print_fn(f"step {i:5d}  loss {float(device_losses[-1]):.4f}")
        if len(device_losses) >= flush_every:
            report.losses.extend(float(l) for l in device_losses)
            device_losses.clear()
        if ckpt_path and ckpt_every and (i + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_path, state, step=i + 1, algo=plan.algo)
            saved_at = i + 1
        report.steps = i + 1
    jax.block_until_ready(state)
    report.wall_time = time.perf_counter() - t0
    report.losses.extend(float(l) for l in device_losses)
    report.examples_per_s = n_examples / max(report.wall_time, 1e-9)
    if ckpt_path and report.steps != saved_at:
        # the in-loop save already covered the final step when ckpt_every
        # divides it — don't write the same step twice
        save_checkpoint(ckpt_path, state, step=report.steps, algo=plan.algo)
    return state, report
