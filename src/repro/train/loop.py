"""Training loop: BSP step + parallel loader + metrics + checkpointing."""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import save_checkpoint
from repro.core.bsp import (init_sharded_train_state, init_train_state,
                            make_bsp_step)
from repro.core.exchanger import get_exchanger
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer


@dataclass
class TrainReport:
    steps: int = 0
    losses: list = field(default_factory=list)
    wall_time: float = 0.0
    examples_per_s: float = 0.0


def train(model: Model, optimizer: Optimizer, lr_fn, mesh, batches, *,
          exchanger: str = "asa", scheme: str = "subgd",
          data_axes=("data",), num_steps: int = 100, seed: int = 0,
          log_every: int = 10, ckpt_path: str | None = None,
          ckpt_every: int = 0, state=None, sum_fn=None,
          microbatches: int = 1, bucket_bytes: int = 0,
          sharded_update: bool = False, overlap: str | None = None,
          print_fn=print) -> tuple[dict, TrainReport]:
    """``batches``: iterable of device-ready batches (e.g. ParallelLoader).

    ``sharded_update``/``overlap``/``bucket_bytes`` select the
    RS->update->AG pipeline (see ``core/bsp.py``); the sharded optimizer
    state is initialized here when no ``state`` is passed."""
    from repro.core.exchanger import default_chunk_sum
    ex = get_exchanger(exchanger)
    sharded = bool(sharded_update or overlap)
    step_fn = jax.jit(make_bsp_step(
        model, optimizer, ex, lr_fn, mesh, data_axes=data_axes,
        scheme=scheme, sum_fn=sum_fn or default_chunk_sum,
        microbatches=microbatches, bucket_bytes=bucket_bytes,
        sharded_update=sharded_update, overlap=overlap))
    if state is None:
        if sharded:
            state = init_sharded_train_state(
                model, optimizer, jax.random.key(seed), mesh,
                data_axes=data_axes, bucket_bytes=bucket_bytes)
        else:
            state = init_train_state(model, optimizer, jax.random.key(seed))
    rng = jax.random.key(seed + 1)

    report = TrainReport()
    n_examples = 0
    t0 = time.perf_counter()
    it = iter(batches)
    # losses stay on device between log boundaries: a per-step float()
    # would block dispatch every step (the deferred trace is materialized
    # once at the end)
    device_losses = []
    for i in range(num_steps):
        try:
            batch = next(it)
        except StopIteration:
            break
        state, metrics = step_fn(state, batch, jax.random.fold_in(rng, i))
        device_losses.append(metrics["loss"])
        first = jax.tree.leaves(batch)[0]
        n_examples += int(first.shape[0])
        if log_every and (i % log_every == 0 or i == num_steps - 1):
            print_fn(f"step {i:5d}  loss {float(device_losses[-1]):.4f}")
        if ckpt_path and ckpt_every and (i + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_path, state, step=i + 1)
        report.steps = i + 1
    jax.block_until_ready(state)
    report.wall_time = time.perf_counter() - t0
    report.losses = [float(l) for l in device_losses]
    report.examples_per_s = n_examples / max(report.wall_time, 1e-9)
    if ckpt_path:
        save_checkpoint(ckpt_path, state, step=report.steps)
    return state, report
