"""The unified training engine: one ``TrainPlan`` drives every algorithm.

The paper's headline claim is that synchronous *and* asynchronous training
live in one framework over a shared exchanger layer. This module is that
seam: a :class:`TrainPlan` names the algorithm (``bsp`` | ``easgd`` |
``asgd`` | ``gspmd``) plus its knobs, and :func:`build_engine` resolves it
to one :class:`Engine` — ``(init_state, step, state_shardings)`` — with a
single canonical state layout:

    {"params": ..., "opt": ..., "step": int32[]}   (+ algo extras)

- ``bsp``   : params/opt replicated (or per-bucket flat shards with
              ``sharded_update``); the exchanger moves gradients.
- ``easgd`` : params/opt are per-worker replica stacks (leading worker
              dim over the data axes) + the ``center`` extra; the
              exchanger moves elastic center deltas every ``tau`` steps.
- ``asgd``  : easgd's alpha=1 point — the center applies the summed
              worker deltas (tau-bounded staleness), workers re-fetch.
- ``gspmd`` : params/opt FSDP-sharded; GSPMD lowers the ASA collective
              schedule from sharding constraints (no explicit exchanger).

``train/loop.py``, ``checkpoint/ckpt.py`` and ``launch/train.py`` consume
only this interface, so checkpoint save/resume, loss accounting and the
CLI are algorithm-agnostic. ``Engine.step`` takes the *global* step index
as a host-side argument: for the async plans the engine dispatches
between two jitted programs (local-only vs sync) so that non-averaging
steps compile without any param-sized collective, and resumable runs keep
tau phase and rng folding aligned with the uninterrupted run.
"""
from __future__ import annotations

from dataclasses import dataclass
from math import prod
from typing import Any, Callable

import jax

from repro.core.bsp import (init_sharded_train_state, init_train_state,
                            make_bsp_step)
from repro.core.easgd import init_async_state, make_async_step
from repro.core.exchanger import (default_chunk_sum, get_exchanger,
                                  make_rs_plan, wire_summary)
from repro.core.gspmd import fsdp_state_shardings, make_gspmd_step
from repro.dist.sharding import batch_shardings
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer

ALGOS = ("bsp", "easgd", "asgd", "gspmd")


@dataclass(frozen=True)
class TrainPlan:
    """Declarative selection of a training algorithm + its knobs.

    Validated eagerly so a bad combination fails at plan construction, not
    at trace time. Knob applicability (see DESIGN.md "Training engine"):

    =============== ======= =========== =======
    knob            bsp     easgd/asgd  gspmd
    =============== ======= =========== =======
    exchanger       grads   center      — (GSPMD lowers the collectives)
    scheme          yes     —           —
    microbatches    yes     —           —
    bucket_bytes    yes     yes         —
    sharded_update  yes     —           —
    overlap         yes     —           —
    tau             —       yes         —
    alpha           —       easgd only  —
    quorum          —       yes (elastic) —
    mode            —       —           ar | zero1
    =============== ======= =========== =======

    ``alpha=None`` resolves to the algo default (0.5 for easgd, 1 for
    asgd — asgd IS the alpha=1 point and rejects any other value).
    """
    algo: str = "bsp"
    exchanger: str = "asa"
    scheme: str = "subgd"            # bsp: subgd | awagd
    microbatches: int = 1
    bucket_bytes: int = 0
    sharded_update: bool = False
    overlap: str | None = None       # bsp: None | "buckets"
    tau: int = 1                     # easgd/asgd averaging period
    alpha: float | None = None       # easgd elastic coefficient
    mode: str = "zero1"              # gspmd: ar | zero1
    quorum: int | None = None        # elastic: min reporters per round
    data_axes: tuple = ("data",)

    def __post_init__(self):
        object.__setattr__(self, "data_axes", tuple(self.data_axes))
        if self.algo not in ALGOS:
            raise ValueError(f"unknown algo {self.algo!r}; known: {ALGOS}")
        if self.scheme not in ("subgd", "awagd"):
            raise ValueError(f"unknown scheme {self.scheme!r}")
        if self.mode not in ("ar", "zero1"):
            raise ValueError(f"unknown gspmd mode {self.mode!r}")
        if self.overlap not in (None, "buckets"):
            raise ValueError(f"unknown overlap mode {self.overlap!r}")
        if self.tau < 1:
            raise ValueError(f"tau must be >= 1 (got {self.tau})")
        if self.algo != "bsp":
            bad = [n for n, v in (("sharded_update", self.sharded_update),
                                  ("overlap", self.overlap),
                                  ("microbatches", self.microbatches > 1),
                                  ("scheme", self.scheme != "subgd"))
                   if v]
            if bad:
                raise ValueError(f"{'/'.join(bad)} are BSP-only knobs "
                                 f"(algo={self.algo!r})")
        if not self.is_async and self.tau != 1:
            raise ValueError(f"tau is an easgd/asgd knob "
                             f"(algo={self.algo!r}); it would be silently "
                             f"ignored")
        if self.algo == "gspmd" and self.exchanger != "asa":
            raise ValueError("gspmd lowers its own collectives from "
                             "sharding constraints; the exchanger knob "
                             "does not apply")
        if self.algo != "gspmd" and self.mode != "zero1":
            raise ValueError(f"mode is a gspmd knob (algo={self.algo!r})")
        if self.alpha is not None:
            if not self.is_async:
                raise ValueError(f"alpha is an async knob "
                                 f"(algo={self.algo!r})")
            if self.algo == "asgd" and self.alpha != 1.0:
                raise ValueError("asgd is pinned to alpha=1 (the center "
                                 "applies the full delta sum); use "
                                 "algo='easgd' for elastic alpha")
        else:
            # self-describing plan: resolve the algo default eagerly
            object.__setattr__(self, "alpha",
                               1.0 if self.algo == "asgd" else 0.5)
        if self.is_async and self.exchanger == "none":
            raise ValueError("async plans need a real exchanger for the "
                             "center traffic (exchanger='none')")
        if self.quorum is not None:
            if not self.is_async:
                raise ValueError(f"quorum is an elastic easgd/asgd knob "
                                 f"(algo={self.algo!r})")
            if self.quorum < 1:
                raise ValueError(f"quorum must be >= 1 (got {self.quorum})")

    @property
    def is_async(self) -> bool:
        return self.algo in ("easgd", "asgd")


@dataclass(frozen=True)
class Engine:
    """A resolved plan: everything the train loop needs, and nothing else.

    ``step(state, batch, rng, step_idx) -> (state, metrics)`` — jitted;
    ``step_idx`` is the global (resume-aware) step number, used only for
    host-side dispatch (tau phase). ``init_state(key)`` builds the state on
    its canonical placement; ``state_shardings(state)`` reads it back (the
    tree checkpoint restore targets)."""
    plan: TrainPlan
    init_state: Callable[[Any], Any]
    step: Callable[..., Any]
    # analytic per-rank wire traffic (``exchanger.wire_summary``) for
    # telemetry — None when the plan has no explicit exchanger (gspmd
    # lowers its own collectives) or no exchange at all ('none')
    wire: dict | None = None
    # the engine's jitted programs by attribution name ("train/step", or
    # "train/local"/"train/sync" for the async plans) — what
    # ``repro.telemetry.profile`` captures cost analysis for
    jitted: dict | None = None

    def state_shardings(self, state):
        return jax.tree.map(lambda l: getattr(l, "sharding", None), state)


def _plan_wire(plan: TrainPlan, model: Model, mesh) -> dict | None:
    """Static bytes-on-wire accounting for the plan's exchange traffic."""
    if plan.algo == "gspmd" or plan.exchanger == "none":
        return None
    ex = get_exchanger(plan.exchanger)
    params_abs = jax.eval_shape(model.init, jax.random.key(0))
    k = int(mesh.shape[plan.data_axes[-1]])
    rsplan = make_rs_plan(params_abs, k, plan.bucket_bytes)
    if plan.algo == "bsp":
        per_exchange = plan.microbatches if plan.overlap else 1
        ws = wire_summary(ex, rsplan,
                          param_ag=bool(plan.sharded_update or plan.overlap))
        # overlapped buckets exchange every microbatch's gradient (m× wire
        # volume hidden behind backprop) — count what actually moves
        ws["bytes_per_step"] = (ws["rs_bytes"] * per_exchange
                                + ws["ag_bytes"] + ws["small_bytes"])
        return ws
    # easgd/asgd: delta RS + updated-center AG every tau-th step
    return wire_summary(ex, rsplan, sync_every=plan.tau)


@dataclass(frozen=True)
class ElasticPrograms:
    """The async plan resolved for ONE membership (one k / one mesh).

    The elastic loop (``repro.fault.elastic``) holds exactly one of these
    at a time and rebuilds it — through this same constructor path, so
    plan resolution is shared with ``build_engine`` — whenever the
    membership controller changes the fleet. ``sync`` is the quorum
    variant: ``sync(state, batch, rng, absorb, attract)`` with (k,) fp32
    per-worker weight vectors (see ``core.easgd.make_async_step``)."""
    plan: TrainPlan
    mesh: Any
    k: int
    local: Callable
    sync: Callable
    init_state: Callable[[Any], Any]
    wire: dict | None = None


def build_elastic_programs(plan: TrainPlan, model: Model,
                           optimizer: Optimizer, lr_fn: Callable, mesh, *,
                           sum_fn=None) -> ElasticPrograms:
    """Resolve an async ``plan`` to local/quorum-sync programs on ``mesh``.

    This is ``build_engine``'s async arm with the quorum sync step — the
    membership-change rebuild path. The mesh may span any subset of
    devices (the surviving fleet); k is read off it."""
    if not plan.is_async:
        raise ValueError(f"elastic programs are an easgd/asgd feature "
                         f"(algo={plan.algo!r})")
    sum_fn = sum_fn or default_chunk_sum
    ex = get_exchanger(plan.exchanger)
    k = prod(int(mesh.shape[a]) for a in plan.data_axes)
    local, sync = make_async_step(
        model, optimizer, ex, lr_fn, mesh, algo=plan.algo, alpha=plan.alpha,
        data_axes=plan.data_axes, sum_fn=sum_fn,
        bucket_bytes=plan.bucket_bytes, quorum=True)

    def init_state(key):
        return init_async_state(model, optimizer, key, k, mesh=mesh,
                                data_axes=plan.data_axes)

    from repro import telemetry
    wire = _plan_wire(plan, model, mesh)
    ilocal = telemetry.profile.instrument("train/local", jax.jit(local))
    isync = telemetry.profile.instrument(
        "train/sync", jax.jit(sync),
        coll_bytes=wire["bytes_per_exchange"] if wire else 0.0)
    return ElasticPrograms(plan, mesh, k, ilocal, isync, init_state, wire)


def build_engine(plan: TrainPlan, model: Model, optimizer: Optimizer,
                 lr_fn: Callable, mesh, *, sum_fn=None) -> Engine:
    """Resolve ``plan`` to ``(init_state, step, state_shardings)``."""
    if plan.quorum is not None:
        raise ValueError(
            "quorum plans are elastic: drive them with "
            "repro.fault.elastic.elastic_train (build_engine builds "
            "fixed-membership engines and would silently ignore quorum)")
    sum_fn = sum_fn or default_chunk_sum

    from repro import telemetry

    if plan.algo == "bsp":
        ex = get_exchanger(plan.exchanger)
        sharded = bool(plan.sharded_update or plan.overlap)
        jstep = jax.jit(make_bsp_step(
            model, optimizer, ex, lr_fn, mesh, data_axes=plan.data_axes,
            scheme=plan.scheme, sum_fn=sum_fn,
            microbatches=plan.microbatches, bucket_bytes=plan.bucket_bytes,
            sharded_update=plan.sharded_update, overlap=plan.overlap,
            grad_norm=telemetry.config().grad_norm))
        wire = _plan_wire(plan, model, mesh)
        istep = telemetry.profile.instrument(
            "train/step", jstep,
            coll_bytes=wire["bytes_per_step"] if wire else 0.0)

        def step(state, batch, rng, step_idx: int = 0):
            del step_idx
            return istep(state, batch, rng)

        def init_state(key):
            if sharded:
                return init_sharded_train_state(
                    model, optimizer, key, mesh, data_axes=plan.data_axes,
                    bucket_bytes=plan.bucket_bytes)
            return init_train_state(model, optimizer, key)

        return Engine(plan, init_state, step, wire,
                      {"train/step": jstep})

    if plan.is_async:
        ex = get_exchanger(plan.exchanger)
        k = prod(mesh.shape[a] for a in plan.data_axes)
        local, sync = make_async_step(
            model, optimizer, ex, lr_fn, mesh, algo=plan.algo,
            alpha=plan.alpha, data_axes=plan.data_axes, sum_fn=sum_fn,
            bucket_bytes=plan.bucket_bytes)
        jlocal, jsync = jax.jit(local), jax.jit(sync)
        wire = _plan_wire(plan, model, mesh)
        ilocal = telemetry.profile.instrument("train/local", jlocal)
        isync = telemetry.profile.instrument(
            "train/sync", jsync,
            coll_bytes=wire["bytes_per_exchange"] if wire else 0.0)

        def step(state, batch, rng, step_idx: int = 0):
            # tau is structural: non-averaging steps run a program with no
            # param-sized collective at all
            fn = isync if (int(step_idx) + 1) % plan.tau == 0 else ilocal
            return fn(state, batch, rng)

        def init_state(key):
            return init_async_state(model, optimizer, key, k, mesh=mesh,
                                    data_axes=plan.data_axes)

        return Engine(plan, init_state, step, wire,
                      {"train/local": jlocal, "train/sync": jsync})

    # gspmd
    abs_state = jax.eval_shape(
        lambda k: init_train_state(model, optimizer, k), jax.random.key(0))
    state_sh = fsdp_state_shardings(mesh, abs_state)
    base = make_gspmd_step(model, optimizer, lr_fn, mesh, mode=plan.mode)

    def constrained(state, batch, rng):
        new_state, metrics = base(state, batch, rng)
        # pin the output placement so the FSDP layout is a fixed point of
        # the step (and checkpoint restore targets a stable sharding)
        new_state = jax.tree.map(jax.lax.with_sharding_constraint,
                                 new_state, state_sh)
        return new_state, metrics

    jstep = jax.jit(constrained)
    istep = telemetry.profile.instrument("train/step", jstep)

    def step(state, batch, rng, step_idx: int = 0):
        del step_idx
        batch = jax.device_put(batch, batch_shardings(mesh, batch))
        return istep(state, batch, rng)

    def init_state(key):
        return jax.device_put(init_train_state(model, optimizer, key),
                              state_sh)

    return Engine(plan, init_state, step, None, {"train/step": jstep})
