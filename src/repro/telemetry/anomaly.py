"""Online step-time anomaly detection: EWMA + rolling-MAD detectors.

Two detectors, both host-side, O(1) memory, and silent when telemetry is
off:

- :class:`StreamDetector` watches ONE scalar stream (per-step wall time)
  for **spikes** (one observation far outside the recent distribution)
  and **regressions** (a sustained shift of the level). Spikes use a
  robust z-score against a rolling median/MAD window — median absolute
  deviation is outlier-proof where a stddev would be dragged by the very
  spikes it should flag. Regressions compare a fast EWMA against a slow
  EWMA baseline: ``fast > slow * (1 + tol)`` for ``patience`` consecutive
  observations fires once, then the baseline re-anchors so a permanent
  shift is reported once, not forever.

      z = (x - median) / (1.4826 * MAD + eps)

  (1.4826 scales MAD to the stddev of a normal distribution.)

- :class:`FleetDetector` watches per-worker durations *cross-sectionally*
  (one observation per worker per step) and flags stragglers relative to
  the fleet median: worker w is flagged when ``d_w > max(rel * median,
  median + z * 1.4826 * MAD)``. The relative-factor arm makes the
  decision exact when the non-straggling workers tie (MAD = 0 — the
  simulated elastic loop's case), which keeps the feedback into
  ``MembershipController`` deterministic and replays bit-identical.

Every firing increments an ``anomaly/*`` counter and drops a trace
instant, so Perfetto shows *when* the step stream went bad next to the
spans that show *where* the time went.
"""
from __future__ import annotations

from collections import deque
from statistics import median

from repro.telemetry import _runtime, metrics, trace

# MAD -> stddev scale for a normal distribution
_MAD_K = 1.4826
_EPS = 1e-12


def _slug(name: str) -> str:
    return name.replace("/", "_")


class StreamDetector:
    """Spike + regression detection over one scalar stream.

    ``observe(x)`` returns ``{"spike": bool, "regression": bool, "z": f}``
    and records ``anomaly/<stream>/spikes`` / ``.../regressions`` counters
    plus trace instants on firings. Pass ``registry`` to record into a
    standalone registry (serve's always-live ``EngineStats``); default is
    the process-wide one via the gated accessors.
    """

    def __init__(self, name: str, *, window: int = 64, min_n: int = 8,
                 spike_z: float = 8.0, regress_tol: float = 0.5,
                 patience: int = 5, alpha_fast: float = 0.3,
                 alpha_slow: float = 0.03, registry=None):
        self.name = name
        self.window: deque = deque(maxlen=window)
        self.min_n = min_n
        self.spike_z = spike_z
        self.regress_tol = regress_tol
        self.patience = patience
        self.alpha_fast = alpha_fast
        self.alpha_slow = alpha_slow
        self.ewma_fast: float | None = None
        self.ewma_slow: float | None = None
        self._over = 0          # consecutive observations above the band
        self.spikes = 0
        self.regressions = 0
        self._registry = registry

    def _counter(self, what: str):
        name = f"anomaly/{_slug(self.name)}/{what}"
        if self._registry is not None:
            return self._registry.counter(name)
        return metrics.counter(name)

    def robust_z(self, x: float) -> float:
        if len(self.window) < self.min_n:
            return 0.0
        med = median(self.window)
        mad = median(abs(v - med) for v in self.window)
        return (x - med) / (_MAD_K * mad + _EPS)

    def observe(self, x: float) -> dict:
        x = float(x)
        if not _runtime._state.enabled:
            return {"spike": False, "regression": False, "z": 0.0}
        z = self.robust_z(x)
        spike = z > self.spike_z
        if spike:
            self.spikes += 1
            self._counter("spikes").inc()
            trace.instant("anomaly/spike", stream=self.name, value=x,
                          z=round(z, 2))
        self.window.append(x)
        a_f, a_s = self.alpha_fast, self.alpha_slow
        self.ewma_fast = (x if self.ewma_fast is None
                          else a_f * x + (1 - a_f) * self.ewma_fast)
        self.ewma_slow = (x if self.ewma_slow is None
                          else a_s * x + (1 - a_s) * self.ewma_slow)
        regression = False
        if (len(self.window) >= self.min_n
                and self.ewma_fast > self.ewma_slow * (1 + self.regress_tol)):
            self._over += 1
            if self._over >= self.patience:
                regression = True
                self.regressions += 1
                self._counter("regressions").inc()
                trace.instant("anomaly/regression", stream=self.name,
                              ewma_fast=self.ewma_fast,
                              ewma_slow=self.ewma_slow)
                # re-anchor: a sustained shift reports once, not every step
                self.ewma_slow = self.ewma_fast
                self._over = 0
        else:
            self._over = 0
        return {"spike": spike, "regression": regression, "z": z}


class FleetDetector:
    """Cross-sectional straggler detection over per-worker durations.

    ``observe({worker: seconds})`` returns the workers flagged this round.
    A worker is a straggler when its duration exceeds BOTH arms of

        max(rel_thresh * median,  median + spike_z * 1.4826 * MAD)

    evaluated over the fleet — i.e. it must be a large *relative* outlier
    (robust to the MAD collapsing to 0 when the rest of the fleet ties)
    AND far in robust-z terms when there is spread. ``patience``
    consecutive flagged rounds are required before a worker is reported
    (default 1: flag immediately).
    """

    def __init__(self, *, rel_thresh: float = 3.0, spike_z: float = 6.0,
                 min_workers: int = 3, patience: int = 1):
        self.rel_thresh = rel_thresh
        self.spike_z = spike_z
        self.min_workers = min_workers
        self.patience = patience
        self._streak: dict = {}
        self.flagged_total = 0

    def observe(self, durations: dict) -> list:
        if not _runtime._state.enabled or len(durations) < self.min_workers:
            return []
        vals = list(durations.values())
        med = median(vals)
        mad = median(abs(v - med) for v in vals)
        cut = max(self.rel_thresh * med, med + self.spike_z * _MAD_K * mad)
        out = []
        for w, d in durations.items():
            if d > cut and med > 0:
                streak = self._streak.get(w, 0) + 1
                self._streak[w] = streak
                if streak >= self.patience:
                    out.append(w)
            else:
                self._streak[w] = 0
        self.flagged_total += len(out)
        return sorted(out)
