"""Per-program performance attribution: the ``ProgramProfile`` registry.

Every jitted hot program — the train step, the exchange RS/AG halves, the
prefill chunk, the decode step — gets one :class:`ProgramProfile` that
joins two sources:

- **compile-time cost**: ``jitted.lower(*args).cost_analysis()`` — the
  per-device flops / HBM-bytes estimate XLA computes *without* building an
  executable (verified cheap on jax 0.4.x: it reuses the jit trace cache
  and never compiles). Collective bytes come from the caller's analytic
  wire accounting (``exchanger.wire_summary`` / ``Engine.wire``) because
  the pre-optimization StableHLO text has no compiled-HLO collectives to
  parse — same modeling discipline as ``exchange/bytes_wire``.
- **measured durations**: the instrument sites (train loop, serve engine,
  exchange-half micro-timer) feed per-call wall times via
  :func:`observe` — the join contract is *name equality* with the span
  that times the program (``train/step``, ``serve/decode_step``, ...).

The join emits achieved-FLOPs / achieved-bandwidth / MFU gauges against
:func:`repro.roofline.analysis.peaks` (env-overridable peak model), so
"decode runs at 9% of the memory roofline" is a metric in every
``--metrics-out`` dump, not a bench-day observation.

Host-side only: nothing here adds an op to a jitted program — ``lower()``
reuses the trace the first dispatch created (or primes the cache for it),
and :func:`instrument` wraps *dispatch*, never the program. Gated by the
telemetry switch plus ``REPRO_TELEMETRY_PROFILE=0`` (profile-only off);
capture failures increment ``profile/capture_errors`` and never break the
caller.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.telemetry import _runtime, metrics, trace

_profiles: dict = {}


def enabled() -> bool:
    return _runtime._state.enabled and _runtime._state.config.profile


def _slug(name: str) -> str:
    return name.replace("/", "_")


@dataclass
class ProgramProfile:
    """Cost + measured-duration attribution for one jitted program."""
    name: str
    flops: float = 0.0           # per-device, from cost_analysis
    hbm_bytes: float = 0.0       # per-device, pre-optimization estimate
    coll_bytes: float = 0.0      # per-rank analytic wire bytes (caller)
    calls: int = 0
    total_time_s: float = 0.0
    compile_time_s: float = 0.0
    capture_time_s: float = 0.0
    captured: bool = False
    meta: dict = field(default_factory=dict)

    @property
    def mean_time_s(self) -> float:
        return self.total_time_s / self.calls if self.calls else 0.0

    @property
    def achieved_flops_s(self) -> float:
        m = self.mean_time_s
        return self.flops / m if m > 0 else 0.0

    @property
    def achieved_hbm_bw(self) -> float:
        m = self.mean_time_s
        return self.hbm_bytes / m if m > 0 else 0.0

    @property
    def achieved_coll_bw(self) -> float:
        m = self.mean_time_s
        return self.coll_bytes / m if m > 0 else 0.0

    def roofline(self) -> dict:
        """Ratios vs the (env-overridable) peak model; the roofline bound
        time and which term dominates."""
        from repro.roofline.analysis import peaks
        pk = peaks()
        terms = {"compute": self.flops / pk["flops"],
                 "memory": self.hbm_bytes / pk["hbm_bw"],
                 "collective": self.coll_bytes / pk["ici_bw"]}
        return {
            "mfu": self.achieved_flops_s / pk["flops"],
            "hbm_frac": self.achieved_hbm_bw / pk["hbm_bw"],
            "coll_frac": self.achieved_coll_bw / pk["ici_bw"],
            "t_roofline_s": max(terms.values()),
            "bound": max(terms, key=terms.get),
        }

    def gauges(self) -> dict:
        """The metric names/values this profile exports (flat
        ``profile/<program>/<quantity>`` namespace)."""
        s = _slug(self.name)
        out = {}
        if self.captured:
            out[f"profile/{s}/flops"] = self.flops
            out[f"profile/{s}/hbm_bytes"] = self.hbm_bytes
            out[f"profile/{s}/coll_bytes"] = self.coll_bytes
        if self.calls:
            out[f"profile/{s}/calls"] = float(self.calls)
            out[f"profile/{s}/mean_time_s"] = self.mean_time_s
        if self.captured and self.calls:
            rl = self.roofline()
            out[f"profile/{s}/achieved_flops_s"] = self.achieved_flops_s
            out[f"profile/{s}/achieved_hbm_bw"] = self.achieved_hbm_bw
            out[f"profile/{s}/mfu"] = rl["mfu"]
            out[f"profile/{s}/hbm_frac"] = rl["hbm_frac"]
            if self.coll_bytes:
                out[f"profile/{s}/achieved_coll_bw"] = self.achieved_coll_bw
                out[f"profile/{s}/coll_frac"] = rl["coll_frac"]
        return out


def _get(name: str) -> ProgramProfile:
    p = _profiles.get(name)
    if p is None:
        p = _profiles[name] = ProgramProfile(name)
    return p


def get(name: str) -> ProgramProfile | None:
    return _profiles.get(name)


def programs() -> dict:
    return dict(_profiles)


def reset() -> None:
    _profiles.clear()


def capture(name: str, jfn, *args, coll_bytes: float = 0.0,
            **kwargs) -> ProgramProfile | None:
    """Record compile-time cost analysis for ``jfn`` called with ``args``.

    Uses the AOT ``lower()`` path *without* ``compile()`` — on jax 0.4.x
    the lowered cost analysis shares the jit trace cache (no retrace when
    the program already dispatched, and the trace is reused when it
    dispatches later) while an AOT ``compile()`` would pay a full second
    XLA compile. Never raises: failures count in
    ``profile/capture_errors``."""
    if not enabled():
        return None
    prof = _get(name)
    t0 = time.perf_counter()
    try:
        lowered = jfn.lower(*args, **kwargs)
        ca = lowered.cost_analysis()
        if isinstance(ca, (list, tuple)):   # jax 0.4.x wraps in a list
            ca = ca[0] if ca else {}
        ca = ca or {}
        prof.flops = float(ca.get("flops", 0.0))
        prof.hbm_bytes = float(ca.get("bytes accessed", 0.0))
    except Exception as e:  # noqa: BLE001 — attribution must never break a run
        metrics.counter("profile/capture_errors").inc()
        prof.meta["capture_error"] = f"{type(e).__name__}: {e}"
        return None
    prof.coll_bytes = float(coll_bytes or 0.0)
    prof.capture_time_s = time.perf_counter() - t0
    prof.captured = True
    return prof


def observe(name: str, seconds: float) -> None:
    """Join one measured call duration into the program's profile."""
    if not enabled():
        return
    prof = _get(name)
    prof.calls += 1
    prof.total_time_s += float(seconds)


def compile_time(name: str, seconds: float) -> None:
    """Record a program's first-call (compile + first execution) wall time
    as a ``compile/*`` gauge — the per-program view TrainReport's single
    ``compile_time`` scalar can't give."""
    if not enabled():
        return
    _get(name).compile_time_s = float(seconds)
    metrics.gauge(f"compile/{_slug(name)}_s").set(float(seconds))


def instrument(name: str, jfn, *, coll_bytes: float = 0.0):
    """Wrap a jitted callable with first-call attribution: cost capture
    (before the call — donated buffers are still alive), then a blocked
    timing of the compile + first execution. Later calls pass through
    untouched; disabled telemetry passes through from call zero. The
    wrapped program itself is never altered (byte-identical on/off)."""
    state = {"first": True}

    def wrapped(*args, **kwargs):
        if state["first"] and enabled():
            state["first"] = False
            import jax
            with trace.span("profile/capture", program=name):
                capture(name, jfn, *args, coll_bytes=coll_bytes, **kwargs)
            t0 = time.perf_counter()
            out = jfn(*args, **kwargs)
            jax.block_until_ready(out)
            compile_time(name, time.perf_counter() - t0)
            return out
        return jfn(*args, **kwargs)

    wrapped.jitted = jfn    # introspection: the unwrapped program
    wrapped.program_name = name
    return wrapped


def emit(registry=None) -> None:
    """Write every profile's gauges into ``registry`` (default: the
    process-wide registry) so flush/dump picks them up."""
    if not enabled():
        return
    if registry is None:
        registry = _runtime.default_registry()
    for prof in _profiles.values():
        for gname, v in prof.gauges().items():
            registry.gauge(gname).set(v)


def summary() -> list:
    """One dict per captured program — the report CLI's table source."""
    out = []
    for name in sorted(_profiles):
        p = _profiles[name]
        row = {"program": name, "flops": p.flops, "hbm_bytes": p.hbm_bytes,
               "coll_bytes": p.coll_bytes, "calls": p.calls,
               "mean_time_s": p.mean_time_s,
               "compile_time_s": p.compile_time_s,
               "achieved_flops_s": p.achieved_flops_s,
               "achieved_hbm_bw": p.achieved_hbm_bw}
        if p.captured and p.calls:
            row.update(p.roofline())
        out.append(row)
    return out
