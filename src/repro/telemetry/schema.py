"""The telemetry wire schema: versioning, run context, validators.

One schema is shared by three producers so they stay comparable:

- live train/serve runs (``--metrics-out`` JSONL / ``--trace-out`` trace)
- ``benchmarks/run.py --json`` (``BENCH_*.json`` artifacts)
- tests (in-memory snapshots)

Every metrics record carries ``schema_version`` + ``ts``; every file opens
with a ``run`` record describing the host/device/backend that produced it
(the attribution satellite: a BENCH json or a metrics JSONL from three PRs
ago says *what machine and backend* its numbers came from).

The validators are dependency-free (no jsonschema) and are what the CI
telemetry-smoke step runs against freshly produced files.
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time

SCHEMA_VERSION = 1

KINDS = ("run", "counter", "gauge", "histogram", "info")


def run_context() -> dict:
    """Host/device/backend identity for run attribution. jax is imported
    lazily (and optionally): the schema itself must load anywhere."""
    ctx = {
        "host": platform.node(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "pid": os.getpid(),
        "argv": list(sys.argv),
        "time_unix": time.time(),
    }
    try:
        import jax
        ctx["jax"] = jax.__version__
        ctx["backend"] = jax.default_backend()
        devs = jax.devices()
        ctx["device_kind"] = devs[0].device_kind if devs else ""
        ctx["device_count"] = len(devs)
    except Exception:  # noqa: BLE001 — no jax / no backend is still a run
        ctx["backend"] = "unknown"
    return ctx


def run_record() -> dict:
    return {"schema_version": SCHEMA_VERSION, "kind": "run",
            "ts": time.time(), "run": run_context()}


# ---------------------------------------------------------------------------
# validators
# ---------------------------------------------------------------------------

def _err(errs, where, msg):
    errs.append(f"{where}: {msg}")


def validate_record(rec, where: str = "record") -> list:
    """Validate one metrics record; returns a list of problems (empty =
    valid)."""
    errs: list = []
    if not isinstance(rec, dict):
        _err(errs, where, f"not an object: {type(rec).__name__}")
        return errs
    if rec.get("schema_version") != SCHEMA_VERSION:
        _err(errs, where, f"schema_version != {SCHEMA_VERSION}: "
             f"{rec.get('schema_version')!r}")
    kind = rec.get("kind")
    if kind not in KINDS:
        _err(errs, where, f"unknown kind {kind!r}")
        return errs
    if not isinstance(rec.get("ts"), (int, float)):
        _err(errs, where, "missing/non-numeric ts")
    if kind == "run":
        run = rec.get("run")
        if not isinstance(run, dict):
            _err(errs, where, "run record without run object")
        else:
            for k in ("host", "backend"):
                if k not in run:
                    _err(errs, where, f"run context missing {k!r}")
        return errs
    if not isinstance(rec.get("name"), str) or not rec.get("name"):
        _err(errs, where, "missing name")
    if kind in ("counter", "gauge"):
        if not isinstance(rec.get("value"), (int, float)):
            _err(errs, where, f"{kind} without numeric value")
    elif kind == "info":
        if not isinstance(rec.get("labels"), dict):
            _err(errs, where, "info without labels object")
    elif kind == "histogram":
        bounds, counts = rec.get("bounds"), rec.get("counts")
        if not isinstance(bounds, list) or not isinstance(counts, list):
            _err(errs, where, "histogram without bounds/counts lists")
        elif not all(isinstance(b, (int, float)) and not isinstance(b, bool)
                     for b in bounds):
            # guard before sorted(): a str/None bound must be a diagnostic,
            # not a TypeError out of the validator
            _err(errs, where, "non-numeric histogram bounds")
        elif not all(isinstance(c, int) and not isinstance(c, bool)
                     for c in counts):
            _err(errs, where, "non-integer histogram counts")
        else:
            if len(counts) != len(bounds) + 1:
                _err(errs, where, f"len(counts)={len(counts)} != "
                     f"len(bounds)+1={len(bounds) + 1}")
            if list(bounds) != sorted(bounds):
                _err(errs, where, "bounds not ascending")
            if sum(counts) != rec.get("count"):
                _err(errs, where, f"count={rec.get('count')} != "
                     f"sum(counts)={sum(counts)}")
        for k in ("count", "sum", "min", "max"):
            if not isinstance(rec.get(k), (int, float)):
                _err(errs, where, f"histogram missing {k!r}")
    return errs


def validate_metrics_jsonl(path: str) -> list:
    """Validate a ``--metrics-out`` file: JSON per line, a leading run
    record, every record schema-valid."""
    errs: list = []
    n = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                _err(errs, f"{path}:{lineno}", f"bad json: {e}")
                continue
            if n == 0 and rec.get("kind") != "run":
                _err(errs, f"{path}:{lineno}",
                     "first record must be kind='run'")
            errs.extend(validate_record(rec, f"{path}:{lineno}"))
            n += 1
    if n == 0:
        _err(errs, path, "empty metrics file")
    return errs


def validate_trace(path: str) -> list:
    """Validate a ``--trace-out`` Chrome-trace/Perfetto JSON file."""
    errs: list = []
    with open(path) as f:
        try:
            obj = json.load(f)
        except json.JSONDecodeError as e:
            return [f"{path}: bad json: {e}"]
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return [f"{path}: not a Chrome trace (no traceEvents)"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return [f"{path}: traceEvents is not a list "
                f"({type(events).__name__})"]
    other = obj.get("otherData", {})
    if other.get("schema_version") != SCHEMA_VERSION:
        _err(errs, path, "otherData.schema_version missing/stale")
    if "backend" not in other.get("run", {}):
        _err(errs, path, "otherData.run context missing")
    open_async: dict = {}      # (name, id) -> open 'b' count
    for i, ev in enumerate(events):
        where = f"{path}:traceEvents[{i}]"
        if not isinstance(ev, dict):
            _err(errs, where, "event is not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "b", "e", "i", "M"):
            _err(errs, where, f"unknown phase {ph!r}")
            continue
        for k in ("name", "ph", "pid", "tid", "ts"):
            if k not in ev:
                _err(errs, where, f"missing {k!r}")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            _err(errs, where, "complete event without dur")
        if ph in ("b", "e"):
            if "id" not in ev:
                _err(errs, where, "async event without id")
            else:
                key = (ev.get("name"), ev["id"])
                if ph == "b":
                    open_async[key] = open_async.get(key, 0) + 1
                elif open_async.get(key, 0) <= 0:
                    _err(errs, where, f"async end before begin for "
                         f"name={ev.get('name')!r} id={ev['id']!r}")
                else:
                    open_async[key] -= 1
    return errs


def validate_bench_obj(obj, where: str = "bench") -> list:
    """Validate an in-memory BENCH object (what ``benchmarks/run.py``
    checks *before* writing ``--json``)."""
    errs: list = []
    if not isinstance(obj, dict):
        return [f"{where}: not an object: {type(obj).__name__}"]
    if obj.get("schema_version") != SCHEMA_VERSION:
        _err(errs, where, "missing/stale schema_version")
    if "backend" not in obj.get("run", {}):
        _err(errs, where, "missing run context")
    rows = obj.get("rows")
    if not isinstance(rows, list):
        _err(errs, where, "missing rows list")
    else:
        for i, r in enumerate(rows):
            if not isinstance(r, dict) or "name" not in r:
                _err(errs, f"{where}:rows[{i}]", "row without name")
            elif not isinstance(r.get("us_per_call"), (int, float)):
                _err(errs, f"{where}:rows[{i}]",
                     "row without numeric us_per_call")
    return errs


def validate_bench_json(path: str) -> list:
    """Validate a ``BENCH_*.json`` artifact written by benchmarks/run.py."""
    with open(path) as f:
        try:
            obj = json.load(f)
        except json.JSONDecodeError as e:
            return [f"{path}: bad json: {e}"]
    return validate_bench_obj(obj, path)
