"""Process-local metrics registry: counters, gauges, histograms, sinks.

Design rules (DESIGN.md "Telemetry"):

- **Bounded memory.** A histogram is a fixed vector of bucket counts plus
  count/sum/min/max — never a list of observations. Long-running servers
  and training loops record into O(1) state per metric.
- **Host-side only.** Nothing here touches jax; metrics take plain Python
  numbers. Instrumentation sites convert device values explicitly (and
  only at flush boundaries, never per hot-path call).
- **Cheap when off.** The module-level accessors in
  :mod:`repro.telemetry.metrics` return the shared :data:`NOOP` object
  when telemetry is disabled — recording into it is one attribute lookup
  and a ``pass``. A :class:`Registry` instance itself is always live
  (``repro.serve.EngineStats`` owns one regardless of the global switch,
  because its public stats must work with telemetry off).

Metric names are ``area/quantity[_unit]`` (``train/step_time_s``,
``exchange/bytes_wire``, ``serve/ttft_s``) — the flat namespace the JSONL
schema and the Perfetto traces share.
"""
from __future__ import annotations

import json
import math
import time
from bisect import bisect_right


def exp_buckets(lo: float, hi: float, per_decade: int = 8) -> tuple:
    """Log-spaced bucket boundaries covering [lo, hi]."""
    if lo <= 0 or hi <= lo:
        raise ValueError(f"need 0 < lo < hi (got {lo}, {hi})")
    n = int(math.ceil(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10.0 ** (i / per_decade) for i in range(n + 1))


# default boundaries for wall-clock seconds: 10us .. 100s, 8 per decade
TIME_BUCKETS = exp_buckets(1e-5, 100.0, 8)


class Counter:
    """Monotone accumulator (``inc``); value is a plain number."""
    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"kind": "counter", "name": self.name, "value": self.value}


class Gauge:
    """Last-write-wins value (``set``)."""
    __slots__ = ("name", "value")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"kind": "gauge", "name": self.name, "value": self.value}


class Info:
    """Static string labels (strategy names, dtypes, versions)."""
    __slots__ = ("name", "labels")
    kind = "info"

    def __init__(self, name: str):
        self.name = name
        self.labels = {}

    def set(self, **labels) -> None:
        self.labels.update({k: str(v) for k, v in labels.items()})

    def snapshot(self) -> dict:
        return {"kind": "info", "name": self.name, "labels": dict(self.labels)}


class Histogram:
    """Fixed-boundary histogram: ``len(bounds) + 1`` counts (the last bin
    is the +inf overflow), plus count/sum/min/max. Percentiles are read
    back by linear interpolation inside the resolved bucket — accurate to
    one bucket width (tested against numpy in ``tests/test_telemetry.py``).
    """
    __slots__ = ("name", "bounds", "counts", "count", "sum", "min", "max")
    kind = "histogram"

    def __init__(self, name: str, buckets=None):
        self.name = name
        self.bounds = tuple(float(b) for b in (buckets or TIME_BUCKETS))
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError(f"bucket boundaries must ascend: {name}")
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v) -> None:
        v = float(v)
        self.counts[bisect_right(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Interpolated percentile (q in [0, 100]) from the bucket counts."""
        if not self.count:
            return 0.0
        rank = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c and cum + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else self.min
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                return lo + (hi - lo) * max(rank - cum, 0.0) / c
            cum += c
        return self.max

    def percentiles(self, qs=(50, 99)) -> dict:
        return {q: self.percentile(q) for q in qs}

    def snapshot(self) -> dict:
        return {"kind": "histogram", "name": self.name, "count": self.count,
                "sum": self.sum,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "bounds": list(self.bounds), "counts": list(self.counts)}


class _Noop:
    """The disabled-path metric: every recording call is a no-op and every
    accessor is a constant. One shared instance (:data:`NOOP`) is returned
    for *all* metric kinds so the off path allocates nothing per call."""
    __slots__ = ()
    kind = "noop"
    name = "noop"
    value = 0
    count = 0
    sum = 0.0
    mean = 0.0

    def inc(self, n=1):
        pass

    def set(self, *a, **kw):
        pass

    def observe(self, v):
        pass

    def percentile(self, q):
        return 0.0

    def percentiles(self, qs=(50, 99)):
        return {q: 0.0 for q in qs}


NOOP = _Noop()

_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram,
          "info": Info}


class Registry:
    """A named collection of metrics with attachable sinks.

    Accessors are get-or-create and type-checked: asking for an existing
    name with a different kind is a bug, not a silent new metric. The
    default process-wide registry lives in :mod:`repro.telemetry._runtime`;
    standalone instances (e.g. per serve engine) are cheap.
    """

    def __init__(self, label: str = ""):
        self.label = label
        self._metrics: dict = {}
        self._sinks: list = []

    def _get(self, name: str, kind: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = _KINDS[kind](name, **kw)
            self._metrics[name] = m
        elif m.kind != kind:
            raise TypeError(f"metric {name!r} is a {m.kind}, not a {kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge")

    def histogram(self, name: str, buckets=None) -> Histogram:
        h = self._metrics.get(name)
        if h is not None and h.kind == "histogram":
            return h
        return self._get(name, "histogram", buckets=buckets)

    def info(self, name: str, **labels) -> Info:
        m = self._get(name, "info")
        if labels:
            m.set(**labels)
        return m

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str):
        return self._metrics[name]

    def names(self) -> list:
        return sorted(self._metrics)

    def snapshot(self, ts: float | None = None) -> list:
        """One schema record per metric (see ``repro.telemetry.schema``)."""
        from repro.telemetry.schema import SCHEMA_VERSION
        ts = time.time() if ts is None else ts
        out = []
        for name in sorted(self._metrics):
            rec = self._metrics[name].snapshot()
            rec["schema_version"] = SCHEMA_VERSION
            rec["ts"] = ts
            if self.label:
                rec["reg"] = self.label
            out.append(rec)
        return out

    # -- sinks --------------------------------------------------------------

    def add_sink(self, sink) -> None:
        self._sinks.append(sink)

    def flush(self, force: bool = True) -> None:
        """Push a full snapshot to every sink (periodic sinks may skip when
        not ``force`` and their interval has not elapsed)."""
        if not self._sinks:
            return
        records = self.snapshot()
        now = time.time()
        for s in self._sinks:
            s.emit(records, now, force)

    def close(self) -> None:
        self.flush(force=True)
        for s in self._sinks:
            s.close()
        self._sinks = []


class MemorySink:
    """Keeps every flushed snapshot — the test sink."""

    def __init__(self):
        self.snapshots: list = []

    def emit(self, records, now, force) -> None:
        self.snapshots.append(records)

    def close(self) -> None:
        pass


class JsonlSink:
    """Appends one JSON line per metric per flush. The file opens lazily
    and starts with a ``run`` header record (host/device/backend context)
    so any JSONL is self-describing."""

    def __init__(self, path: str, every_s: float = 0.0):
        self.path = path
        self.every_s = every_s
        self._f = None
        self._last = 0.0

    def _open(self):
        if self._f is None:
            from repro.telemetry.schema import run_record
            self._f = open(self.path, "w")
            self._f.write(json.dumps(run_record()) + "\n")
        return self._f

    def emit(self, records, now, force) -> None:
        if not force and self.every_s and now - self._last < self.every_s:
            return
        self._last = now
        f = self._open()
        for rec in records:
            f.write(json.dumps(rec) + "\n")
        f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class ConsoleSink:
    """Periodic one-line summaries of scalar metrics (counters/gauges and
    histogram count/mean) — the human tail -f."""

    def __init__(self, print_fn=print, every_s: float = 30.0):
        self.print_fn = print_fn
        self.every_s = every_s
        self._last = 0.0

    def emit(self, records, now, force) -> None:
        if not force and self.every_s and now - self._last < self.every_s:
            return
        self._last = now
        parts = []
        for r in records:
            if r["kind"] == "counter":
                parts.append(f"{r['name']}={r['value']}")
            elif r["kind"] == "gauge":
                parts.append(f"{r['name']}={r['value']:.4g}")
            elif r["kind"] == "histogram" and r["count"]:
                parts.append(f"{r['name']}: n={r['count']} "
                             f"mean={r['sum'] / r['count']:.3g}")
        if parts:
            self.print_fn("[telemetry] " + "  ".join(parts))

    def close(self) -> None:
        pass
