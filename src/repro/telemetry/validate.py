"""Schema validator CLI — what the CI telemetry-smoke step runs.

    python -m repro.telemetry.validate metrics.jsonl more.jsonl \
        --trace trace.json --bench BENCH_quick.json

Exit 0 iff every file validates; problems print one per line.
"""
from __future__ import annotations

import argparse
import sys

from repro.telemetry.schema import (validate_bench_json,
                                    validate_metrics_jsonl, validate_trace)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("metrics", nargs="*", help="metrics JSONL files")
    ap.add_argument("--trace", nargs="*", default=[],
                    help="Chrome-trace/Perfetto JSON files")
    ap.add_argument("--bench", nargs="*", default=[],
                    help="BENCH_*.json artifacts")
    args = ap.parse_args(argv)
    if not (args.metrics or args.trace or args.bench):
        ap.error("nothing to validate")
    errs = []
    for p in args.metrics:
        errs.extend(validate_metrics_jsonl(p))
    for p in args.trace:
        errs.extend(validate_trace(p))
    for p in args.bench:
        errs.extend(validate_bench_json(p))
    for e in errs:
        print(e, file=sys.stderr)
    n = len(args.metrics) + len(args.trace) + len(args.bench)
    print(f"validated {n} file(s): "
          + ("OK" if not errs else f"{len(errs)} problem(s)"))
    return 1 if errs else 0


if __name__ == "__main__":
    raise SystemExit(main())
