"""Process-wide telemetry state: the on/off switch, the default registry,
attached registries, and sinks.

Telemetry is **default-on** (recording into in-process state costs ~a
microsecond per call); ``REPRO_TELEMETRY=0`` in the environment flips the
whole surface to the shared no-op fast path before anything records.
``set_enabled`` flips it at runtime (the overhead benchmark and the
on-vs-off parity tests use this).

The switch gates *recording through the module-level accessors* — a
standalone :class:`~repro.telemetry.registry.Registry` instance keeps
working regardless (serve's ``EngineStats`` depends on that).
"""
from __future__ import annotations

import os
from dataclasses import dataclass

from repro.telemetry.registry import (ConsoleSink, JsonlSink, Registry,
                                      NOOP)


def _env_enabled() -> bool:
    return os.environ.get("REPRO_TELEMETRY", "1") not in ("0", "off", "false")


@dataclass
class TelemetryConfig:
    """Opt-in knobs beyond the on/off switch. ``grad_norm`` adds a global
    gradient-norm to the train-step metrics — an *in-graph* op, so it is
    off by default (the host-side-only rule) and only honored when a user
    asks (env ``REPRO_TELEMETRY_GRADNORM=1`` or ``configure``).
    ``profile`` gates per-program cost attribution
    (:mod:`repro.telemetry.profile`) — default on, host-side only; env
    ``REPRO_TELEMETRY_PROFILE=0`` turns just the attribution off."""
    grad_norm: bool = False
    profile: bool = True


class _State:
    def __init__(self):
        self.enabled = _env_enabled()
        self.registry = Registry()
        self.extra: list = []          # (registry) attached for export
        self.config = TelemetryConfig(
            grad_norm=os.environ.get("REPRO_TELEMETRY_GRADNORM", "0")
            not in ("0", ""),
            profile=os.environ.get("REPRO_TELEMETRY_PROFILE", "1")
            not in ("0", "off", "false"))


_state = _State()


def enabled() -> bool:
    return _state.enabled


def set_enabled(on: bool) -> None:
    _state.enabled = bool(on)


def config() -> TelemetryConfig:
    return _state.config


def default_registry() -> Registry:
    """The live default registry — independent of the enabled switch (the
    accessors in :mod:`repro.telemetry.metrics` do the gating)."""
    return _state.registry


def attach_registry(reg: Registry) -> None:
    """Include a standalone registry (e.g. a serve engine's) in
    ``flush``/``dump_metrics`` output."""
    if reg not in _state.extra and reg is not _state.registry:
        _state.extra.append(reg)


def detach_registry(reg: Registry) -> None:
    if reg in _state.extra:
        _state.extra.remove(reg)


def all_registries() -> list:
    return [_state.registry] + list(_state.extra)


def add_sink(sink) -> None:
    _state.registry.add_sink(sink)


def configure(metrics_out: str | None = None,
              console_every: float | None = None,
              grad_norm: bool | None = None,
              profile: bool | None = None) -> None:
    """Launcher-facing setup: attach a JSONL sink and/or a periodic console
    summary to the default registry, set opt-in knobs."""
    if metrics_out:
        add_sink(JsonlSink(metrics_out))
    if console_every is not None:
        add_sink(ConsoleSink(every_s=console_every))
    if grad_norm is not None:
        _state.config.grad_norm = bool(grad_norm)
    if profile is not None:
        _state.config.profile = bool(profile)


def flush(force: bool = False) -> None:
    """Push snapshots of the default registry to its sinks. Attached
    registries ride along: their records are merged into the default
    registry's sink stream."""
    reg = _state.registry
    if not reg._sinks:
        return
    from repro.telemetry import profile
    profile.emit(reg)       # refresh per-program attribution gauges
    import time
    records = []
    for r in all_registries():
        records.extend(r.snapshot())
    now = time.time()
    for s in reg._sinks:
        s.emit(records, now, force)


def dump_metrics(path: str, extra=()) -> None:
    """Write one full snapshot of the default + attached (+ ``extra``)
    registries as schema'd JSONL with a leading run record."""
    import json

    from repro.telemetry import profile
    from repro.telemetry.schema import run_record
    profile.emit(_state.registry)
    regs = all_registries() + [r for r in extra
                               if r not in all_registries()]
    with open(path, "w") as f:
        f.write(json.dumps(run_record()) + "\n")
        for r in regs:
            for rec in r.snapshot():
                f.write(json.dumps(rec) + "\n")


def reset() -> None:
    """Drop all recorded state (tests). Keeps the enabled flag."""
    from repro.telemetry import profile
    _state.registry.close()
    _state.registry = Registry()
    _state.extra = []
    profile.reset()


__all__ = ["enabled", "set_enabled", "config", "configure",
           "default_registry", "attach_registry", "detach_registry",
           "all_registries", "add_sink", "flush", "dump_metrics", "reset",
           "TelemetryConfig", "NOOP"]
