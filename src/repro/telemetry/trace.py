"""Low-overhead host-side span tracing with Chrome-trace/Perfetto export.

    from repro.telemetry import trace
    with trace.span("exchange/rs", bytes=n):
        ...
    trace.export("trace.json")      # load in ui.perfetto.dev / about:tracing

Spans record host wall-clock (``time.perf_counter``) begin/duration —
they time *dispatch and host work*, never device internals: the rule that
keeps the jitted programs byte-identical with telemetry on or off (the
compile-once guards in tests pin this). Nested ``span``s on one thread
render as a flame stack (Perfetto nests complete events by time
containment per track); request-scoped lifecycles that overlap arbitrarily
use the async pair :func:`async_begin`/:func:`async_end` keyed by an id
(one Perfetto track per id).

The event buffer is bounded (:data:`MAX_EVENTS`); overflow increments a
drop counter rather than growing — a long-serving process can leave
tracing on.
"""
from __future__ import annotations

import json
import os
import threading
import time

MAX_EVENTS = 1 << 18     # ~262k events; each is a small tuple

_T0 = time.perf_counter()          # trace epoch (exported ts are µs from here)
_T0_UNIX = time.time()

_lock = threading.Lock()
_events: list = []
_dropped = 0
_tids: dict = {}


def _tid() -> int:
    ident = threading.get_ident()
    t = _tids.get(ident)
    if t is None:
        with _lock:
            t = _tids.setdefault(ident, len(_tids))
    return t


def _push(ev) -> None:
    global _dropped
    if len(_events) < MAX_EVENTS:
        _events.append(ev)
    else:
        _dropped += 1


class _Span:
    """A live complete-event span (context manager)."""
    __slots__ = ("name", "attrs", "t0")

    def __init__(self, name: str, attrs):
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        _push(("X", self.name, self.t0, t1 - self.t0, _tid(), self.attrs))
        return False


class _NoopSpan:
    """Shared disabled-path span: enter/exit do nothing, allocate nothing."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_SPAN = _NoopSpan()


def _enabled() -> bool:
    from repro.telemetry._runtime import _state
    return _state.enabled


def span(name: str, **attrs):
    """Context manager timing a host-side region. ``attrs`` land in the
    exported event's ``args``."""
    if not _enabled():
        return _NOOP_SPAN
    return _Span(name, attrs or None)


def instant(name: str, **attrs) -> None:
    """A zero-duration marker event."""
    if not _enabled():
        return
    _push(("i", name, time.perf_counter(), 0.0, _tid(), attrs or None))


def async_begin(name: str, aid, **attrs) -> None:
    """Open an async span keyed by ``aid`` (e.g. a request id). Pairs with
    :func:`async_end`; overlapping ids get separate Perfetto tracks."""
    if not _enabled():
        return
    _push(("b", name, time.perf_counter(), 0.0, aid, attrs or None))


def async_end(name: str, aid, **attrs) -> None:
    if not _enabled():
        return
    _push(("e", name, time.perf_counter(), 0.0, aid, attrs or None))


def events() -> list:
    """The raw event buffer (tests)."""
    return list(_events)


def dropped() -> int:
    return _dropped


def reset() -> None:
    global _dropped
    with _lock:
        _events.clear()
        _dropped = 0


def to_chrome(extra_metadata: dict | None = None) -> dict:
    """Render the buffer as a Chrome-trace object (Perfetto-loadable)."""
    from repro.telemetry.schema import SCHEMA_VERSION, run_context
    pid = os.getpid()
    out = []
    for ph, name, t0, dur, tid_or_id, attrs in _events:
        ev = {"name": name, "ph": ph, "pid": pid,
              "ts": (t0 - _T0) * 1e6}
        if ph == "X":
            ev["tid"] = tid_or_id
            ev["dur"] = dur * 1e6
        elif ph in ("b", "e"):
            # async events share one "requests" track, separated by id
            ev["tid"] = 0
            ev["cat"] = "request"
            ev["id"] = tid_or_id
        else:
            ev["tid"] = tid_or_id
            ev["s"] = "t"
        if attrs:
            ev["args"] = {k: v for k, v in attrs.items()}
        out.append(ev)
    meta = {"schema_version": SCHEMA_VERSION, "run": run_context(),
            "trace_epoch_unix": _T0_UNIX, "dropped_events": _dropped}
    if extra_metadata:
        meta.update(extra_metadata)
    return {"traceEvents": out, "displayTimeUnit": "ms", "otherData": meta}


def export(path: str, **extra_metadata) -> str:
    """Write the Chrome-trace JSON; returns ``path``."""
    with open(path, "w") as f:
        json.dump(to_chrome(extra_metadata or None), f)
    return path
