"""``python -m repro.telemetry.report`` — render a one-page markdown run
health report from telemetry artifacts.

Joins up to three files from one run:

- a ``--metrics-out`` JSONL (required positional) — train / exchange /
  serve / fault counters+gauges+histograms, the ``profile/*`` program
  attribution gauges, ``compile/*`` compile times, ``anomaly/*`` firings;
- ``--trace`` Chrome-trace JSON — top spans by total wall time;
- ``--bench`` a ``BENCH_*.json`` — the bench rows of the same commit.

The report is the human view of the same schema the validators check: a
``Programs`` table (flops, bytes, achieved rates, MFU, roofline bound per
jitted program), per-area metric tables with interpolated histogram
percentiles, the anomaly/fault tallies, and run attribution (host,
backend, jax) from the leading run record. CI uploads the rendered page
as the ``bench-regression`` job's artifact.
"""
from __future__ import annotations

import argparse
import json
import sys


def _fmt(v, unit: str = "") -> str:
    """Engineering-format a number for the tables."""
    if isinstance(v, str):
        return v
    try:
        x = float(v)
    except (TypeError, ValueError):
        return str(v)
    if x == 0:
        return f"0{unit}"
    ax = abs(x)
    for scale, suffix in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if ax >= scale:
            return f"{x / scale:.2f}{suffix}{unit}"
    if ax < 1e-3:
        return f"{x * 1e6:.1f}u{unit}"
    if ax < 1:
        return f"{x * 1e3:.2f}m{unit}"
    if x == int(x) and ax < 1e15:
        return f"{int(x)}{unit}"
    return f"{x:.3f}{unit}"


def _hist_percentile(rec: dict, q: float) -> float:
    """Interpolated percentile from a histogram *snapshot record* — the
    same bucket interpolation ``Histogram.percentile`` does live."""
    count, counts = rec.get("count", 0), rec.get("counts", [])
    bounds = rec.get("bounds", [])
    if not count or not counts:
        return 0.0
    lo_min, hi_max = rec.get("min", 0.0), rec.get("max", 0.0)
    rank = q / 100.0 * count
    cum = 0
    for i, c in enumerate(counts):
        if c and cum + c >= rank:
            lo = bounds[i - 1] if i > 0 else lo_min
            hi = bounds[i] if i < len(bounds) else hi_max
            lo = max(lo, lo_min)
            hi = min(hi, hi_max)
            if hi <= lo:
                return lo
            return lo + (hi - lo) * max(rank - cum, 0.0) / c
        cum += c
    return hi_max


def load_metrics(path: str) -> tuple:
    """Last-write-wins record per metric name, plus the run context."""
    run, records = {}, {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue       # validate CLI reports these; report renders
            if not isinstance(rec, dict):
                continue
            if rec.get("kind") == "run":
                run = rec.get("run", {}) or run
            elif isinstance(rec.get("name"), str):
                records[rec["name"]] = rec
    return run, records


def _by_area(records: dict) -> dict:
    areas: dict = {}
    for name, rec in sorted(records.items()):
        area = name.split("/", 1)[0]
        areas.setdefault(area, []).append(rec)
    return areas


def _metric_rows(recs: list) -> list:
    rows = []
    for rec in recs:
        kind, name = rec.get("kind"), rec.get("name")
        if kind in ("counter", "gauge"):
            rows.append((name, kind, _fmt(rec.get("value"))))
        elif kind == "histogram":
            rows.append((name, "histogram",
                         f"n={rec.get('count', 0)} "
                         f"p50={_fmt(_hist_percentile(rec, 50), 's')} "
                         f"p99={_fmt(_hist_percentile(rec, 99), 's')} "
                         f"max={_fmt(rec.get('max', 0.0), 's')}"))
        elif kind == "info":
            labels = rec.get("labels", {})
            rows.append((name, "info",
                         " ".join(f"{k}={v}" for k, v in labels.items())))
    return rows


def _programs_table(records: dict) -> list:
    """Reassemble the ``profile/<program>/<quantity>`` gauges into one row
    per program."""
    progs: dict = {}
    for name, rec in records.items():
        if not name.startswith("profile/") or rec.get("kind") != "gauge":
            continue
        parts = name.split("/")
        if len(parts) != 3:
            continue
        progs.setdefault(parts[1], {})[parts[2]] = rec.get("value", 0.0)
    lines = []
    if progs:
        lines.append("| program | calls | mean | flops | hbm B | coll B |"
                     " FLOP/s | MFU | HBM B/s |")
        lines.append("|---|---|---|---|---|---|---|---|---|")
        for prog in sorted(progs):
            q = progs[prog]
            lines.append(
                f"| {prog} | {int(q.get('calls', 0))} "
                f"| {_fmt(q.get('mean_time_s', 0.0), 's')} "
                f"| {_fmt(q.get('flops', 0.0))} "
                f"| {_fmt(q.get('hbm_bytes', 0.0))} "
                f"| {_fmt(q.get('coll_bytes', 0.0))} "
                f"| {_fmt(q.get('achieved_flops_s', 0.0))} "
                f"| {q.get('mfu', 0.0):.4f} "
                f"| {_fmt(q.get('achieved_hbm_bw', 0.0))} |")
    return lines


def _serve_cache_line(records: dict) -> list:
    """Paged-KV-cache health next to the program attribution: page-pool
    occupancy, prefix hit-rate, and copy-on-write copies explain *why* the
    serve programs ran the token counts they did (a hot prefix cache cuts
    prefill calls; high occupancy explains admission gating)."""
    keys = ("serve/page_occupancy", "serve/prefix_hit_rate",
            "serve/cow_copies")
    if not any(k in records for k in keys):
        return []
    occ = records.get(keys[0], {}).get("value", 0.0)
    hit = records.get(keys[1], {}).get("value", 0.0)
    cow = records.get(keys[2], {}).get("value", 0.0)
    return [f"KV cache: page occupancy {occ:.2f}, "
            f"prefix hit-rate {hit:.2f}, COW copies {int(cow)}"]


def _top_spans(trace_path: str, n: int = 12) -> list:
    with open(trace_path) as f:
        try:
            obj = json.load(f)
        except json.JSONDecodeError:
            return ["(trace file unreadable)"]
    events = obj.get("traceEvents", [])
    if not isinstance(events, list):
        return ["(traceEvents is not a list)"]
    total: dict = {}
    count: dict = {}
    for ev in events:
        if isinstance(ev, dict) and ev.get("ph") == "X" \
                and isinstance(ev.get("dur"), (int, float)):
            name = ev.get("name", "?")
            total[name] = total.get(name, 0.0) + ev["dur"]
            count[name] = count.get(name, 0) + 1
    if not total:
        return ["(no complete spans)"]
    lines = ["| span | calls | total | mean |", "|---|---|---|---|"]
    for name in sorted(total, key=total.get, reverse=True)[:n]:
        t_us, c = total[name], count[name]
        lines.append(f"| {name} | {c} | {_fmt(t_us / 1e6, 's')} "
                     f"| {_fmt(t_us / c / 1e6, 's')} |")
    return lines


def _bench_table(bench_path: str) -> list:
    with open(bench_path) as f:
        try:
            obj = json.load(f)
        except json.JSONDecodeError:
            return ["(bench file unreadable)"]
    rows = obj.get("rows", [])
    lines = ["| bench | us/call | derived |", "|---|---|---|"]
    for r in rows:
        if not isinstance(r, dict):
            continue
        lines.append(f"| {r.get('name', '?')} "
                     f"| {_fmt(r.get('us_per_call', 0))} "
                     f"| {r.get('derived', '')} |")
    return lines


# metric areas rendered as their own sections, in report order
_AREAS = ("train", "exchange", "serve", "fault", "anomaly", "compile",
          "elastic", "ckpt")


def render(metrics_path: str, trace_path: str | None = None,
           bench_path: str | None = None) -> str:
    run, records = load_metrics(metrics_path)
    out = ["# Run health report", ""]
    out.append(f"Source: `{metrics_path}`")
    out.append("")
    out.append("## Run")
    out.append("")
    for k in ("host", "backend", "jax", "device_kind", "device_count",
              "platform", "python"):
        if k in run:
            out.append(f"- **{k}**: {run[k]}")
    out.append("")

    prog_lines = _programs_table(records)
    if prog_lines:
        out += ["## Programs (per-program attribution)", ""]
        out += prog_lines
        out.append("")
        cache_lines = _serve_cache_line(records)
        if cache_lines:
            out += cache_lines
            out.append("")

    areas = _by_area(records)
    for area in _AREAS:
        recs = [r for r in areas.get(area, [])
                if not r.get("name", "").startswith("profile/")]
        if not recs:
            continue
        out += [f"## {area}", "", "| metric | kind | value |",
                "|---|---|---|"]
        for name, kind, val in _metric_rows(recs):
            out.append(f"| {name} | {kind} | {val} |")
        out.append("")
    leftovers = [r for a, recs in sorted(areas.items()) if a not in _AREAS
                 for r in recs if not r.get("name", "").startswith("profile/")]
    if leftovers:
        out += ["## other", "", "| metric | kind | value |", "|---|---|---|"]
        for name, kind, val in _metric_rows(leftovers):
            out.append(f"| {name} | {kind} | {val} |")
        out.append("")

    if trace_path:
        out += ["## Top spans", ""]
        out += _top_spans(trace_path)
        out.append("")
    if bench_path:
        out += ["## Bench rows", ""]
        out += _bench_table(bench_path)
        out.append("")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report",
        description="render a markdown run health report from telemetry "
                    "artifacts")
    ap.add_argument("metrics", help="--metrics-out JSONL file")
    ap.add_argument("--trace", default=None, help="--trace-out JSON file")
    ap.add_argument("--bench", default=None, help="BENCH_*.json artifact")
    ap.add_argument("--out", default=None,
                    help="write markdown here (default: stdout)")
    args = ap.parse_args(argv)
    md = render(args.metrics, args.trace, args.bench)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        print(f"wrote {args.out}")
    else:
        print(md)
    return 0


if __name__ == "__main__":
    sys.exit(main())
