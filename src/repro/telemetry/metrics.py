"""Module-level metric accessors against the default registry.

    from repro.telemetry import metrics
    metrics.counter("exchange/bytes_wire").inc(n)
    metrics.histogram("train/step_time_s").observe(dt)
    metrics.gauge("serve/page_occupancy").set(k)

When telemetry is disabled every accessor returns the shared
:data:`~repro.telemetry.registry.NOOP` object — the hot path then costs
one function call and one flag test, and allocates nothing. Instrument
sites may cache handles, but a handle fetched while disabled stays a
no-op; fetch at use or after enabling.
"""
from __future__ import annotations

from repro.telemetry import _runtime
from repro.telemetry.registry import NOOP


def counter(name: str):
    if not _runtime._state.enabled:
        return NOOP
    return _runtime._state.registry.counter(name)


def gauge(name: str):
    if not _runtime._state.enabled:
        return NOOP
    return _runtime._state.registry.gauge(name)


def histogram(name: str, buckets=None):
    if not _runtime._state.enabled:
        return NOOP
    return _runtime._state.registry.histogram(name, buckets=buckets)


def info(name: str, **labels):
    if not _runtime._state.enabled:
        return NOOP
    return _runtime._state.registry.info(name, **labels)


def get(name: str):
    """Read back a recorded metric (None if absent)."""
    reg = _runtime._state.registry
    return reg[name] if name in reg else None
