"""`repro.telemetry` — unified metrics + span tracing for train, exchange,
and serve.

Three pieces (see DESIGN.md "Telemetry"):

- a process-local **metrics registry** (counters / gauges / fixed-bucket
  histograms; bounded memory) with pluggable sinks — JSONL file,
  in-memory (tests), periodic console summary;
- a low-overhead **span API** (``with trace.span("exchange/rs",
  bytes=n):``) exporting Chrome-trace/Perfetto JSON;
- one **schema** (versioned, with host/device/backend run context) shared
  by live runs and ``BENCH_*.json`` artifacts.

Host-side only: instrumentation never adds an op to a jitted program
(grad-norm is the single, explicit opt-in exception). Default-on;
``REPRO_TELEMETRY=0`` switches every accessor to a shared no-op whose
cost is one flag test (pinned <1% step time by
``benchmarks/bench_telemetry.py``).
"""
from repro.telemetry import metrics, trace
from repro.telemetry import anomaly, profile
from repro.telemetry._runtime import (TelemetryConfig, add_sink,
                                      attach_registry, config, configure,
                                      default_registry, detach_registry,
                                      dump_metrics, enabled, flush, reset,
                                      set_enabled)
from repro.telemetry.registry import (ConsoleSink, Counter, Gauge,
                                      Histogram, Info, JsonlSink,
                                      MemorySink, NOOP, Registry,
                                      TIME_BUCKETS, exp_buckets)
from repro.telemetry.schema import (SCHEMA_VERSION, run_context, run_record,
                                    validate_bench_json, validate_bench_obj,
                                    validate_metrics_jsonl, validate_record,
                                    validate_trace)

__all__ = [
    "metrics", "trace", "anomaly", "profile",
    "TelemetryConfig", "add_sink", "attach_registry", "config", "configure",
    "default_registry", "detach_registry", "dump_metrics", "enabled",
    "flush", "reset", "set_enabled",
    "ConsoleSink", "Counter", "Gauge", "Histogram", "Info", "JsonlSink",
    "MemorySink", "NOOP", "Registry", "TIME_BUCKETS", "exp_buckets",
    "SCHEMA_VERSION", "run_context", "run_record", "validate_bench_json",
    "validate_bench_obj", "validate_metrics_jsonl", "validate_record",
    "validate_trace",
]
