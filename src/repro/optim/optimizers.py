"""Optimizers in pure JAX (no optax): momentum SGD (the paper's optimizer,
supporting both AWAGD and SUBGD parallel-SGD schemes) and AdamW.

An ``Optimizer`` is (init, update):
    state = init(params)
    new_params, new_state = update(params, grads, state, lr)

This pair is the update contract for every plan of the training engine
(``repro.train.engine``): BSP and GSPMD call it on the (replicated or
FSDP-sharded) state, and the async plans (EASGD/ASGD) call it per worker
replica — the engine stacks the *full* ``init`` tree along a leading
worker dim, so any optimizer expressible here (momentum-SGD, AdamW with
its ``t`` counter, ...) is automatically a valid per-worker update.

The optional **flat hooks** power the ZeRO-1-style RS->update->AG path in
``core/bsp.py``, where each data rank owns only the local 1/k shard of the
optimizer state and updates flat fp32 bucket shards between the exchange
halves:

    st = flat_init(n)                      # flat state for an n-extent shard
    p', st' = flat_update(p, g, st, lr, wd_mask)

``wd_mask`` is a 0/1 fp32 array marking elements whose *original* leaf is
>=2-D (weight decay never applies to biases/norms; the flat shard has lost
that rank information, so the caller supplies it) — or ``None`` for no
decay. ``rs_fused_update`` additionally fuses the k-way chunk summation
with the update (the Pallas ``fused_rs_update`` kernel): it consumes the
*un-summed* alltoall receives.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable
    flat_init: Callable | None = None
    flat_update: Callable | None = None
    rs_fused_update: Callable | None = None


def sgd_momentum(momentum: float = 0.9, weight_decay: float = 5e-4,
                 nesterov: bool = False, fused_kernel=None) -> Optimizer:
    """The paper's momentum SGD.

    ``fused_kernel``: optional Pallas fused update (ops.fused_sgd) applied to
    2D-reshapeable fp32 leaves; falls back to pure-jnp elsewhere.
    """

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                  params)}

    def update(params, grads, state, lr):
        def leaf(p, g, m):
            g32 = g.astype(jnp.float32)
            if weight_decay and p.ndim > 1:
                g32 = g32 + weight_decay * p.astype(jnp.float32)
            if fused_kernel is not None and p.ndim >= 1:
                p_new, m_new = fused_kernel(p.astype(jnp.float32), g32, m,
                                            lr, momentum, nesterov)
                return p_new.astype(p.dtype), m_new
            m_new = momentum * m + g32
            step = (g32 + momentum * m_new) if nesterov else m_new
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m_new

        out = jax.tree.map(leaf, params, grads, state["m"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m}

    def flat_init(n: int):
        return {"m": jnp.zeros((n,), jnp.float32)}

    def flat_update(p, g, state, lr, wd_mask):
        g32 = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        if weight_decay and wd_mask is not None:
            g32 = g32 + weight_decay * wd_mask * p32
        if fused_kernel is not None:
            p_new, m_new = fused_kernel(p32, g32, state["m"], lr,
                                        momentum, nesterov)
        else:
            m_new = momentum * state["m"] + g32
            step = (g32 + momentum * m_new) if nesterov else m_new
            p_new = p32 - lr * step
        return p_new, {"m": m_new}

    def rs_fused_update(recv, p, state, lr, wd_mask, scale, scales=None):
        from repro.kernels import ops
        p_new, m_new = ops.fused_rs_update(
            recv, p.astype(jnp.float32), state["m"], lr,
            wd_mask=wd_mask, scale=scale, momentum=momentum,
            nesterov=nesterov, weight_decay=weight_decay, scales=scales)
        return p_new, {"m": m_new}

    return Optimizer("sgd", init, update, flat_init, flat_update,
                     rs_fused_update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, lr):
        t = state["t"] + 1
        bc1 = 1.0 - b1 ** t.astype(jnp.float32)
        bc2 = 1.0 - b2 ** t.astype(jnp.float32)

        def leaf(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * jnp.square(g32)
            step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
            p32 = p.astype(jnp.float32)
            if weight_decay and p.ndim > 1:
                step = step + weight_decay * p32
            return (p32 - lr * step).astype(p.dtype), m_new, v_new

        out = jax.tree.map(leaf, params, grads, state["m"], state["v"])
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), {"m": pick(1), "v": pick(2), "t": t}

    def flat_init(n: int):
        return {"m": jnp.zeros((n,), jnp.float32),
                "v": jnp.zeros((n,), jnp.float32),
                "t": jnp.zeros((), jnp.int32)}

    def flat_update(p, g, state, lr, wd_mask):
        t = state["t"] + 1
        bc1 = 1.0 - b1 ** t.astype(jnp.float32)
        bc2 = 1.0 - b2 ** t.astype(jnp.float32)
        g32 = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        m_new = b1 * state["m"] + (1 - b1) * g32
        v_new = b2 * state["v"] + (1 - b2) * jnp.square(g32)
        step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        if weight_decay and wd_mask is not None:
            step = step + weight_decay * wd_mask * p32
        return p32 - lr * step, {"m": m_new, "v": v_new, "t": t}

    return Optimizer("adamw", init, update, flat_init, flat_update)


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd":
        return sgd_momentum(**kw)
    if name == "adamw":
        return adamw(**kw)
    raise KeyError(name)
