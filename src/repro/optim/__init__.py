from repro.optim.optimizers import (Optimizer, get_optimizer, sgd_momentum,
                                    adamw)
from repro.optim.schedule import (step_decay, poly_decay, warmup_cosine,
                                  constant)
