"""LR schedules, including the paper's two policies:

- AlexNet: "scaling down by a factor of 10 every 20 epochs"  -> step_decay
- GoogLeNet: eta = eta0 * (1 - iter/max_iter)^0.5            -> poly_decay
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_decay(lr0: float, steps_per_drop: int, factor: float = 0.1):
    def f(step):
        drops = jnp.floor(step / steps_per_drop)
        return jnp.asarray(lr0, jnp.float32) * factor ** drops
    return f


def poly_decay(lr0: float, max_steps: int, power: float = 0.5):
    def f(step):
        frac = jnp.clip(jnp.asarray(step, jnp.float32) / max_steps, 0.0, 1.0)
        return jnp.asarray(lr0, jnp.float32) * (1.0 - frac) ** power
    return f


def warmup_cosine(lr0: float, warmup: int, max_steps: int,
                  min_frac: float = 0.1):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        wu = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / jnp.maximum(max_steps - warmup, 1),
                        0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.asarray(lr0, jnp.float32) * wu * cos
    return f
