"""Deterministic fault injection: a declarative, seeded ``FaultPlan``.

Every chaos run must be exactly reproducible — a flaky chaos test is
worse than no chaos test. A :class:`FaultPlan` is a list of
:class:`FaultEvent` keyed by *global step index*, plus a seed; anything
stochastic inside an event (which bit a corruption flips) draws from a
per-event generator derived from ``(seed, event index)``, so replaying
the same plan against the same run is bit-identical regardless of how
many other events fired.

Event kinds (all take effect through the membership controller /
elastic loop — see DESIGN.md "Fault tolerance & elasticity"):

``kill:W@S``        worker W dies at step S (leaves at the next round
                    boundary; its delta never reports again).
``join:W@S``        worker W joins at step S (admitted at the next round
                    boundary, starting from the center).
``straggle:W@SxD``  worker W misses the next D averaging rounds starting
                    at step S; its delta is absorbed late with
                    staleness-scaled alpha.
``drop:W@S``        worker W's exchange payload for the round containing
                    step S is lost on the wire (absorbed next round,
                    staleness-scaled).
``corrupt:W@S``     worker W's payload for that round is bit-corrupted on
                    the wire; the integrity check (crc32) detects it and
                    the round excludes the payload (equivalent to a drop,
                    plus a detection counter).
``slow:W@SxD``      worker W runs slow (x ``factor``, default 8) for the D
                    rounds starting at step S. Unlike ``straggle`` the
                    membership controller is *not* told — the anomaly
                    detector must discover the straggler from observed
                    per-worker timing and mark it itself. The slowdown is
                    modeled deterministically (the worker's observed step
                    time is the shared measurement times ``factor``), so
                    replay stays bit-identical.

Serve-side kinds (consumed by ``repro.serve.chaos``, where "worker" is
reinterpreted as the event's magnitude knob and "step" is the engine
decode-step index — see DESIGN.md "Serve robustness"):

``qflood:N@S``      N extra requests burst-arrive at step S (prompt
                    lengths/budgets drawn from the per-event generator).
``stall:F@SxD``     decode dispatches run F× slower for the D steps
                    starting at S (modeled through the engine's virtual
                    cost model, so replay stays bit-identical).
``cancel:K@S``      the K-th live request (by rid order; modulo live
                    count) is cancelled at step S.
``pagepress:N@SxD`` N pages are withheld from the allocator's free list
                    at step S and released D steps later — the page-pool
                    squeeze that drives brownout.

The spec grammar above round-trips through :meth:`FaultPlan.from_spec` /
:meth:`FaultPlan.to_spec` — it is what ``--fault-plan`` on the train
launcher (train kinds) and serve launcher (serve kinds) take.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np

KINDS = ("kill", "join", "straggle", "drop", "corrupt", "slow",
         # serve-side kinds (repro.serve.chaos)
         "qflood", "stall", "cancel", "pagepress")
SERVE_KINDS = ("qflood", "stall", "cancel", "pagepress")


@dataclass(frozen=True)
class FaultEvent:
    kind: str
    worker: int
    step: int
    # straggle/slow only: how many averaging rounds the fault spans
    rounds: int = 1
    # slow only: multiplier on the worker's observed step time
    factor: float = 8.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {KINDS}")
        if self.step < 0 or self.worker < 0:
            raise ValueError(f"worker/step must be >= 0 ({self})")
        if self.rounds < 1:
            raise ValueError(f"straggle rounds must be >= 1 ({self})")
        if self.kind == "slow" and self.factor <= 1.0:
            raise ValueError(f"slow factor must be > 1 ({self})")

    def to_spec(self) -> str:
        s = f"{self.kind}:{self.worker}@{self.step}"
        if self.kind in ("straggle", "slow", "stall", "pagepress"):
            s += f"x{self.rounds}"
        return s


@dataclass(frozen=True)
class FaultPlan:
    """An ordered, seeded schedule of fault events.

    ``seed`` only feeds the per-event generators (corruption bit choice);
    the *schedule* itself is fully declarative. Two plans with equal
    events and seed replay identically.
    """
    events: tuple = ()
    seed: int = 0

    def __post_init__(self):
        evs = tuple(sorted(self.events, key=lambda e: (e.step, e.worker)))
        object.__setattr__(self, "events", evs)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_spec(cls, spec: str, seed: int = 0) -> "FaultPlan":
        """Parse ``"kill:1@9,straggle:2@5x3,corrupt:0@13"``."""
        events = []
        for part in filter(None, (p.strip() for p in spec.split(","))):
            try:
                kind, rest = part.split(":", 1)
                worker, at = rest.split("@", 1)
                rounds = 1
                if "x" in at:
                    at, d = at.split("x", 1)
                    rounds = int(d)     # straggle/slow duration
                events.append(FaultEvent(kind.strip(), int(worker),
                                         int(at), rounds))
            except ValueError as e:
                raise ValueError(
                    f"bad fault spec {part!r} (grammar: kind:worker@step"
                    f"[xrounds], kinds={KINDS}): {e}") from e
        return cls(tuple(events), seed)

    def to_spec(self) -> str:
        return ",".join(e.to_spec() for e in self.events)

    @classmethod
    def random(cls, seed: int, *, num_workers: int, num_steps: int,
               n_events: int = 4, kinds=("kill", "straggle", "drop",
                                         "corrupt")) -> "FaultPlan":
        """A reproducible random chaos schedule: same seed -> same plan.

        Kills are capped at ``num_workers - 1`` so the fleet never
        empties; straggles span 1..3 rounds."""
        rng = np.random.default_rng(seed)
        events, kills = [], 0
        for _ in range(n_events):
            kind = str(rng.choice(kinds))
            if kind == "kill":
                if kills >= num_workers - 1:
                    kind = "drop"
                else:
                    kills += 1
            events.append(FaultEvent(
                kind, int(rng.integers(0, num_workers)),
                int(rng.integers(1, max(2, num_steps - 1))),
                int(rng.integers(1, 4)) if kind == "straggle" else 1))
        return cls(tuple(events), seed)

    # -- queries ------------------------------------------------------------

    def events_at(self, step: int) -> list:
        return [e for e in self.events if e.step == step]

    def event_rng(self, event: FaultEvent) -> np.random.Generator:
        """The per-event generator: keyed by (plan seed, event index) so a
        replay draws identical bits no matter what else fired."""
        idx = self.events.index(event)
        return np.random.default_rng([int(self.seed), idx])

    def __len__(self) -> int:
        return len(self.events)


# ---------------------------------------------------------------------------
# payload integrity: checksum + deterministic corruption
# ---------------------------------------------------------------------------

def payload_checksum(payload) -> int:
    """crc32 over the raw bytes of an array (or list of arrays) — the
    integrity stamp a worker attaches to its exchange payload. crc32
    detects every single-bit error, so a ``corrupt`` injection is always
    caught."""
    if isinstance(payload, (list, tuple)):
        crc = 0
        for a in payload:
            crc = zlib.crc32(np.asarray(a).tobytes(), crc)
        return crc
    return zlib.crc32(np.asarray(payload).tobytes())


def bitflip(arr, rng: np.random.Generator):
    """Flip one deterministic (per ``rng``) bit of ``arr``'s raw bytes —
    the wire-corruption model. Dtype-agnostic (works on bf16 via bytes);
    returns a new array, input untouched."""
    a = np.asarray(arr)
    raw = bytearray(a.tobytes())
    if not raw:
        return a.copy()
    byte = int(rng.integers(0, len(raw)))
    bit = int(rng.integers(0, 8))
    raw[byte] ^= 1 << bit
    return np.frombuffer(bytes(raw), dtype=a.dtype).reshape(a.shape).copy()
