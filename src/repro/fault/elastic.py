"""``elastic_train`` — the fault-tolerant async training loop.

Drives the engine's quorum-sync programs (``build_elastic_programs``)
under a :class:`~repro.fault.membership.MembershipController` and an
optional seeded :class:`~repro.fault.inject.FaultPlan`:

- every step is a local per-worker descent; every tau-th step is a round
  boundary where live, non-straggling workers report;
- the averaging round proceeds iff >= quorum workers report — each
  reporting delta is absorbed with the staleness-scaled coefficient
  ``alpha / (1 + staleness)``; below quorum the round degrades to a
  local step and every delta ages one round;
- ``corrupt`` injections flip a real bit in the worker's wire payload;
  the crc32 integrity check detects it and the round excludes that
  payload (detection is asserted — crc32 catches all single-bit errors);
- membership changes (kill/join) land at round boundaries: the loop
  rebuilds its jitted programs for the new k on a mesh of the surviving
  devices and reshards params/opt rows (survivors keep their momentum,
  joiners start at the center) — center and step pass through;
- checkpoints are crash-safe (``checkpoint.ckpt``) and record the
  membership, so a preempted run resumes onto the checkpoint's fleet and
  re-forms membership from there.

Determinism contract: with the same seed, batch function, and
``FaultPlan``, two runs are bit-identical — batches are step-keyed, the
rng folds the global step, fault events are step-keyed, and everything
stochastic inside an event draws from a per-event generator. Membership
soft state (staleness, in-flight straggles) is intentionally *not*
checkpointed: on resume it re-forms, the same way a real fleet's gossip
state does; staleness re-accrues within at most one tau window.

Telemetry (through ``repro.telemetry`` — captured by ``--metrics-out``):
counters ``fault/kills``, ``fault/joins``, ``fault/joins_rejected``,
``fault/straggles``, ``fault/payloads_dropped``,
``fault/payloads_corrupt``, ``fault/rounds_synced``,
``fault/rounds_skipped_quorum``, ``fault/rebuilds``,
``fault/ckpt_fallbacks``, ``anomaly/stragglers_flagged``; gauges
``fault/live_workers``,
``fault/quorum``, ``fault/round_staleness_max``,
``fault/round_staleness_mean``, ``fault/absorbed_weight_sum``; spans
``fault/round`` (with membership attrs), ``fault/rebuild``,
``fault/reshard``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.checkpoint.ckpt import load_meta, restore_for_resume, \
    save_checkpoint
from repro.core.easgd import reshard_async_state
from repro.fault.inject import FaultPlan, bitflip, payload_checksum
from repro.fault.membership import MembershipController, WorkerState
from repro.telemetry import anomaly, metrics, profile, trace
from repro.train.engine import TrainPlan, build_elastic_programs


class Preempted(RuntimeError):
    """Raised when ``stop_at_step`` preempts the run mid-flight (the
    whole-process kill the resume property test injects). The partially
    trained state survives only through checkpoints — exactly like a
    real preemption."""

    def __init__(self, step: int):
        super().__init__(f"preempted after step {step}")
        self.step = step


@dataclass
class ElasticReport:
    steps: int = 0
    losses: list = field(default_factory=list)
    wall_time: float = 0.0
    rounds: int = 0
    rounds_synced: int = 0
    rounds_skipped_quorum: int = 0
    kills: int = 0
    joins: int = 0
    joins_rejected: int = 0
    straggles: int = 0
    payloads_dropped: int = 0
    payloads_corrupt: int = 0
    rebuilds: int = 0
    slows: int = 0                 # injected slowdowns ("slow" events)
    stragglers_detected: int = 0   # detector -> mark_straggling calls
    final_workers: tuple = ()
    # per synced round: (step, reporting ids, absorb weights) — the
    # audit trail the staleness tests hand-check
    round_log: list = field(default_factory=list)


def _mesh_for(controller: MembershipController, devices):
    """A data-axis mesh over the live workers' device slots, in stack-row
    order (worker i's replica row lives on its own device)."""
    devs = [devices[controller.slot_of(w)] for w in controller.workers]
    return jax.sharding.Mesh(np.asarray(devs), ("data",))


def _first_param_row(state, row: int):
    """One worker's wire payload proxy: the row of the first params leaf.
    Used by the corruption check — checksumming the full tree would be
    exact too, but one leaf suffices to model detect-and-exclude."""
    leaf = jax.tree.leaves(state["params"])[0]
    return np.asarray(leaf[row])


def elastic_train(model, optimizer, lr_fn, batch_fn, *,
                  plan: TrainPlan, num_workers: int | None = None,
                  num_steps: int = 100, seed: int = 0,
                  fault_plan: FaultPlan | str | None = None,
                  log_every: int = 10, ckpt_path: str | None = None,
                  ckpt_every: int = 0, ckpt_keep: int = 3,
                  resume_from: str | None = None,
                  stop_at_step: int | None = None,
                  devices=None, print_fn=print):
    """Elastic, fault-injected training to ``num_steps``.

    ``batch_fn(step, k) -> batch`` must be deterministic in ``step`` and
    produce a global batch whose leading dim divides by ``k`` (the live
    worker count *at that step*) — index-keyed synthetic sources qualify.
    ``plan`` must be async (easgd/asgd); ``plan.quorum`` (or the majority
    default) gates averaging rounds. Returns ``(state, ElasticReport)``.

    ``stop_at_step`` simulates whole-process preemption: the loop raises
    :class:`Preempted` after that step completes, without a final
    checkpoint — resume with ``resume_from`` pointing at ``ckpt_path``.
    """
    if not plan.is_async:
        raise ValueError(f"elastic_train drives easgd/asgd plans "
                         f"(algo={plan.algo!r}); bsp/gspmd fault "
                         f"tolerance is checkpoint restart — use "
                         f"train() with resume_from")
    if tuple(plan.data_axes) != ("data",):
        raise ValueError("elastic membership reshards over a single "
                         f"'data' axis (got data_axes={plan.data_axes})")
    if isinstance(fault_plan, str):
        fault_plan = FaultPlan.from_spec(fault_plan)
    fault_plan = fault_plan or FaultPlan()
    devices = list(devices if devices is not None else jax.devices())
    k0 = num_workers or len(devices)
    if k0 > len(devices):
        raise ValueError(
            f"{k0} workers need {k0} distinct devices but only "
            f"{len(devices)} are visible — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={k0} (CPU) or "
            f"lower --workers")

    # -- membership + (possibly resumed) state ------------------------------
    start_step = 0
    if resume_from:
        meta = load_meta(resume_from)
        workers = meta.get("workers")
        if workers is None:
            workers = list(range(k0))
        controller = MembershipController(workers, alpha=plan.alpha,
                                          quorum=plan.quorum,
                                          num_slots=len(devices))
    else:
        controller = MembershipController(range(k0), alpha=plan.alpha,
                                          quorum=plan.quorum,
                                          num_slots=len(devices))
    mesh = _mesh_for(controller, devices)
    progs = build_elastic_programs(plan, model, optimizer, lr_fn, mesh)
    state = progs.init_state(jax.random.key(seed))
    if resume_from:
        state, start_step = restore_for_resume(resume_from, state,
                                               expect_algo=plan.algo)
    rng = jax.random.key(seed + 1)

    # -- telemetry handles --------------------------------------------------
    c_kills = metrics.counter("fault/kills")
    c_joins = metrics.counter("fault/joins")
    c_joins_rej = metrics.counter("fault/joins_rejected")
    c_straggles = metrics.counter("fault/straggles")
    c_dropped = metrics.counter("fault/payloads_dropped")
    c_corrupt = metrics.counter("fault/payloads_corrupt")
    c_synced = metrics.counter("fault/rounds_synced")
    c_skipped = metrics.counter("fault/rounds_skipped_quorum")
    c_rebuilds = metrics.counter("fault/rebuilds")
    c_stragglers = metrics.counter("anomaly/stragglers_flagged")
    g_live = metrics.gauge("fault/live_workers")
    g_quorum = metrics.gauge("fault/quorum")
    g_stale_max = metrics.gauge("fault/round_staleness_max")
    g_stale_mean = metrics.gauge("fault/round_staleness_mean")
    g_absorbed = metrics.gauge("fault/absorbed_weight_sum")
    metrics.info("fault/plan", algo=plan.algo, tau=str(plan.tau),
                 quorum=str(plan.quorum or "majority"),
                 fault_spec=fault_plan.to_spec(), workers=str(k0))
    g_live.set(controller.k)
    g_quorum.set(controller.quorum_count)

    report = ElasticReport()
    report.steps = start_step
    # payload exclusions scoped to the current round
    round_drops: set = set()
    round_corrupt: set = set()
    # injected slowdowns: worker -> (rounds left, timing factor). The
    # controller is NOT told — the fleet detector below must discover the
    # straggler from the observed per-worker step durations.
    slow_left: dict = {}
    det_fleet = anomaly.FleetDetector()
    # programs whose compiling first dispatch has already happened —
    # only warm dispatches feed the per-program attribution means
    seen_progs: set = set()
    rebuilt_now = False
    t0 = time.perf_counter()
    try:
        for i in range(start_step, num_steps):
            batch = batch_fn(i, controller.k)
            rng_i = jax.random.fold_in(rng, i)

            # -- injected faults scheduled at this step ---------------------
            for ev in fault_plan.events_at(i):
                if ev.kind == "kill":
                    if controller.kill(ev.worker):
                        report.kills += 1
                        c_kills.inc()
                        trace.instant("fault/kill", worker=ev.worker,
                                      step=i)
                elif ev.kind == "join":
                    if controller.request_join(ev.worker):
                        trace.instant("fault/join_request",
                                      worker=ev.worker, step=i)
                elif ev.kind == "straggle":
                    if controller.straggle(ev.worker, ev.rounds):
                        report.straggles += 1
                        c_straggles.inc()
                elif ev.kind == "drop":
                    round_drops.add(ev.worker)
                elif ev.kind == "corrupt":
                    round_corrupt.add((ev.worker, ev))
                elif ev.kind == "slow":
                    if ev.worker in controller.workers:
                        slow_left[ev.worker] = (
                            max(ev.rounds,
                                slow_left.get(ev.worker, (0, 0.0))[0]),
                            float(ev.factor))
                        report.slows += 1
                        trace.instant("fault/slow", worker=ev.worker,
                                      step=i, factor=ev.factor)

            is_round = (i + 1) % plan.tau == 0
            prog_name = "train/local"
            t_step = time.perf_counter()
            if not is_round:
                state, m = progs.local(state, batch, rng_i)
            else:
                report.rounds += 1
                # corrupted payloads: flip a real bit in the worker's wire
                # payload copy; crc32 must catch it -> exclude like a drop
                detected = set()
                for w, ev in round_corrupt:
                    if w not in controller.workers:
                        continue
                    row = controller.workers.index(w)
                    payload = _first_param_row(state, row)
                    good = payload_checksum(payload)
                    bad = bitflip(payload, fault_plan.event_rng(ev))
                    if payload_checksum(bad) == good:  # pragma: no cover
                        raise AssertionError(
                            "crc32 missed a single-bit corruption")
                    detected.add(w)
                    report.payloads_corrupt += 1
                    c_corrupt.inc()
                dropped = {w for w in round_drops
                           if w in controller.workers}
                report.payloads_dropped += len(dropped)
                c_dropped.inc(len(dropped))
                reporting = controller.reporting(exclude=dropped | detected)
                g_stale_max.set(controller.max_staleness())
                g_stale_mean.set(controller.mean_staleness())
                if controller.has_quorum(reporting):
                    absorb, attract = controller.round_weights(reporting)
                    with trace.span("fault/round", step=i,
                                    k=controller.k,
                                    reporting=len(reporting),
                                    stale_max=controller.max_staleness()):
                        state, m = progs.sync(state, batch, rng_i,
                                              absorb, attract)
                    prog_name = "train/sync"
                    report.rounds_synced += 1
                    report.round_log.append(
                        (i, tuple(reporting), absorb.tolist()))
                    c_synced.inc()
                    g_absorbed.set(float(absorb.sum()))
                    controller.commit_round(reporting)
                else:
                    # below quorum: degrade to a local step; deltas age
                    state, m = progs.local(state, batch, rng_i)
                    report.rounds_skipped_quorum += 1
                    c_skipped.inc()
                    trace.instant("fault/quorum_skip", step=i,
                                  reporting=len(reporting),
                                  quorum=controller.quorum_count)
                    controller.skip_round()
                round_drops.clear()
                round_corrupt.clear()

                # -- membership changes land at the round boundary ----------
                old, new, left, joined = controller.apply_pending()
                if old != new:
                    with trace.span("fault/rebuild", k_old=len(old),
                                    k_new=len(new)):
                        mesh = _mesh_for(controller, devices)
                        progs = build_elastic_programs(
                            plan, model, optimizer, lr_fn, mesh)
                        rebuilt_now = True
                        with trace.span("fault/reshard"):
                            state = reshard_async_state(
                                state, old, new, optimizer, mesh=mesh,
                                data_axes=plan.data_axes)
                    report.rebuilds += 1
                    report.joins += len(joined)
                    c_rebuilds.inc()
                    c_joins.inc(len(joined))
                    g_live.set(controller.k)
                    g_quorum.set(controller.quorum_count)
                    if print_fn:
                        print_fn(f"step {i:5d}  membership {len(old)} -> "
                                 f"{len(new)} (left={list(left)}, "
                                 f"joined={list(joined)})")
                if controller.rejected_joins > report.joins_rejected:
                    c_joins_rej.inc(controller.rejected_joins
                                    - report.joins_rejected)
                    report.joins_rejected = controller.rejected_joins

            # -- observed per-worker timing -> straggler detection ----------
            # One shared host measurement per step; an injected slowdown
            # inflates the affected worker's observed duration by its
            # deterministic factor, so the flag decision (a *relative*
            # robust-stats comparison) replays bit-identically no matter
            # what the wall clock did.
            dt_step = time.perf_counter() - t_step
            # each program's first dispatch after a (re)build is its
            # compiling call — instrument() records that as compile/*;
            # only warm dispatches feed the attribution mean. The step
            # that triggered a rebuild still ran the old (warm) programs,
            # so it is observed first and the seen-set reset after.
            if prog_name in seen_progs:
                profile.observe(prog_name, dt_step)
            else:
                seen_progs.add(prog_name)
            if rebuilt_now:
                seen_progs.clear()
                rebuilt_now = False
            durations = {
                w: dt_step * (slow_left[w][1] if w in slow_left else 1.0)
                for w in controller.workers}
            for w in det_fleet.observe(durations):
                if controller.state_of(w) == WorkerState.STRAGGLING:
                    continue       # already sitting out; don't re-count
                if controller.mark_straggling(w, 1):
                    report.stragglers_detected += 1
                    c_stragglers.inc()
                    trace.instant("anomaly/straggler", worker=w, step=i,
                                  k=controller.k)
            if is_round and slow_left:
                slow_left = {w: (r - 1, f)
                             for w, (r, f) in slow_left.items() if r > 1}

            report.losses.append(float(m["loss"]))
            report.steps = i + 1
            if log_every and print_fn and (i % log_every == 0
                                           or i == num_steps - 1):
                print_fn(f"step {i:5d}  loss {report.losses[-1]:.4f}  "
                         f"k={controller.k}")
            if ckpt_path and ckpt_every and (i + 1) % ckpt_every == 0:
                save_checkpoint(ckpt_path, state, step=i + 1,
                                algo=plan.algo,
                                workers=controller.workers,
                                keep=ckpt_keep)
            if stop_at_step is not None and i + 1 >= stop_at_step:
                raise Preempted(i + 1)
    finally:
        report.wall_time = time.perf_counter() - t0
        report.final_workers = controller.workers
    if ckpt_path and not (ckpt_every and report.steps
                          and report.steps % ckpt_every == 0):
        save_checkpoint(ckpt_path, state, step=report.steps,
                        algo=plan.algo, workers=controller.workers,
                        keep=ckpt_keep)
    return state, report
