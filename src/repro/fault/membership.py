"""The membership controller: who is in the fleet, and at what staleness.

Host-side, pure-python state machine (no jax) — the elastic loop consults
it every step and the jitted programs only ever see its *outputs*: the
per-worker absorb/attract weight vectors and the (rebuilt-on-change)
worker count k.

Worker lifecycle (the GroundHog READY/TRAIN/DONE/EXIT shape, adapted to
round-boundary membership):

    JOINING --admit@boundary--> LIVE --kill--> LEAVING --boundary--> DEAD
                                 |  ^
                         straggle|  | rounds elapse / delta absorbed
                                 v  |
                              STRAGGLING

- Membership changes (kill/join) are *deferred to round boundaries*: the
  replica-stack layout (leading worker dim of extent k) is baked into the
  jitted programs, so k only changes where the engine rebuilds anyway.
- STRAGGLING workers stay in the stack (they keep taking local steps) but
  do not report at averaging rounds; their staleness accrues.
- **Staleness** of worker i = number of averaging rounds since its delta
  was last absorbed into the center. A reporting worker's delta lands
  with the staleness-scaled coefficient ``alpha / (1 + staleness)`` —
  the late-absorption rule that keeps tau-bounded-staleness semantics:
  a delta that aged s rounds moves the center 1/(1+s) as far.
- **Quorum**: an averaging round proceeds iff at least ``quorum`` live
  workers report (default: majority of the live fleet). Below quorum the
  round degrades to a local step for everyone and staleness accrues.
"""
from __future__ import annotations

import numpy as np


class WorkerState:
    LIVE = "live"
    STRAGGLING = "straggling"
    LEAVING = "leaving"      # killed; drops out at the next round boundary
    JOINING = "joining"      # admitted at the next round boundary
    DEAD = "dead"


class MembershipController:
    """Tracks the live fleet between tau-step rounds.

    ``workers`` (the ordered tuple of live worker ids) defines the row
    order of the engine's replica stacks; ``apply_pending`` is the only
    place that order changes, and it reports the old/new orders so the
    caller can reshard state rows accordingly.
    """

    def __init__(self, worker_ids, *, alpha: float, quorum: int | None = None,
                 num_slots: int | None = None):
        ids = list(worker_ids)
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate worker ids: {ids}")
        if not ids:
            raise ValueError("need at least one worker")
        if quorum is not None and quorum < 1:
            raise ValueError(f"quorum must be >= 1 (got {quorum})")
        self.alpha = float(alpha)
        self._quorum = quorum
        self.num_slots = len(ids) if num_slots is None else int(num_slots)
        if len(ids) > self.num_slots:
            raise ValueError(f"{len(ids)} workers > {self.num_slots} slots")
        self._workers: list[int] = ids            # row order of the stacks
        self._staleness = {w: 0 for w in ids}
        self._straggle = {w: 0 for w in ids}      # rounds left to miss
        self._slots = {w: i for i, w in enumerate(ids)}
        self._pending_leave: list[int] = []
        self._pending_join: list[int] = []
        self.rounds = 0                            # boundaries seen
        self.rejected_joins = 0
        self.observed_straggles = 0   # detection-driven (mark_straggling)

    # -- introspection ------------------------------------------------------

    @property
    def workers(self) -> tuple:
        return tuple(self._workers)

    @property
    def k(self) -> int:
        return len(self._workers)

    @property
    def quorum_count(self) -> int:
        """Explicit quorum, or a majority of the live fleet."""
        if self._quorum is not None:
            return self._quorum
        return self.k // 2 + 1

    def slot_of(self, worker: int) -> int:
        return self._slots[worker]

    def state_of(self, worker: int) -> str:
        if worker in self._pending_join:
            return WorkerState.JOINING
        if worker not in self._workers:
            return WorkerState.DEAD
        if worker in self._pending_leave:
            return WorkerState.LEAVING
        if self._straggle.get(worker, 0) > 0:
            return WorkerState.STRAGGLING
        return WorkerState.LIVE

    def staleness_of(self, worker: int) -> int:
        return self._staleness.get(worker, 0)

    def max_staleness(self) -> int:
        return max(self._staleness.values(), default=0)

    def mean_staleness(self) -> float:
        if not self._staleness:
            return 0.0
        return float(np.mean(list(self._staleness.values())))

    # -- fault/inject entry points ------------------------------------------

    def kill(self, worker: int) -> bool:
        """Worker dies; it leaves the stack at the next round boundary (and
        never reports in the meantime)."""
        if worker not in self._workers or worker in self._pending_leave:
            return False
        self._pending_leave.append(worker)
        return True

    def request_join(self, worker: int) -> bool:
        """Worker asks to join; admitted at the next round boundary if a
        device slot is free then."""
        if worker in self._workers or worker in self._pending_join:
            return False
        self._pending_join.append(worker)
        return True

    def straggle(self, worker: int, rounds: int = 1) -> bool:
        if worker not in self._workers:
            return False
        self._straggle[worker] = max(self._straggle.get(worker, 0),
                                     int(rounds))
        return True

    def mark_straggling(self, worker: int, rounds: int = 1) -> bool:
        """Detection-driven straggle: the anomaly detector *observed* this
        worker running slow (as opposed to an injected/announced
        ``straggle``). Same mechanics — the worker keeps taking local
        steps but skips the next ``rounds`` averaging rounds — tallied
        separately so reports can distinguish announced from discovered
        stragglers."""
        if self.straggle(worker, rounds):
            self.observed_straggles += 1
            return True
        return False

    # -- round protocol -----------------------------------------------------

    def reporting(self, exclude=()) -> list:
        """Who reports this round: live, not straggling, not killed, not in
        ``exclude`` (dropped/corrupted payloads)."""
        ex = set(exclude)
        return [w for w in self._workers
                if w not in ex
                and w not in self._pending_leave
                and self._straggle.get(w, 0) == 0]

    def has_quorum(self, reporting) -> bool:
        return len(reporting) >= self.quorum_count

    def round_weights(self, reporting) -> tuple:
        """Per-worker (absorb, attract) fp32 vectors in stack-row order.

        A reporting worker at staleness s gets ``alpha / (1 + s)`` — the
        late-delta absorption rule; non-reporting rows get 0 (their
        params and the center ignore each other this round). The elastic
        attraction uses the same staleness-scaled coefficient, so a stale
        worker is pulled toward the center exactly as hard as it pushes.
        """
        rep = set(reporting)
        absorb = np.zeros((self.k,), np.float32)
        for i, w in enumerate(self._workers):
            if w in rep:
                absorb[i] = self.alpha / (1.0 + self._staleness[w])
        return absorb, absorb.copy()

    def commit_round(self, reporting):
        """An averaging round ran with ``reporting`` absorbed: their
        staleness resets, everyone else's accrues."""
        rep = set(reporting)
        for w in self._workers:
            self._staleness[w] = 0 if w in rep else self._staleness[w] + 1
        self._end_round()

    def skip_round(self):
        """Below-quorum round: nothing absorbed, everyone's delta ages."""
        for w in self._workers:
            self._staleness[w] += 1
        self._end_round()

    def _end_round(self):
        self.rounds += 1
        for w in self._workers:
            if self._straggle.get(w, 0) > 0:
                self._straggle[w] -= 1

    # -- membership changes (round boundaries only) -------------------------

    def apply_pending(self) -> tuple:
        """Apply deferred leaves/joins; returns ``(old, new, left, joined)``
        worker-id tuples. ``old != new`` iff the caller must rebuild its
        programs and reshard replica-stack rows (survivor rows carry over
        by id; joiners start at the center)."""
        old = tuple(self._workers)
        left = tuple(self._pending_leave)
        for w in left:
            self._workers.remove(w)
            self._slots.pop(w, None)
            self._staleness.pop(w, None)
            self._straggle.pop(w, None)
        self._pending_leave.clear()
        joined = []
        used = set(self._slots.values())
        free = [s for s in range(self.num_slots) if s not in used]
        for w in self._pending_join:
            if not free:
                self.rejected_joins += 1
                continue
            self._slots[w] = free.pop(0)
            self._workers.append(w)
            self._staleness[w] = 0   # starts at the center: delta is fresh
            self._straggle[w] = 0
            joined.append(w)
        self._pending_join.clear()
        if not self._workers:
            raise RuntimeError("membership change emptied the fleet — "
                               "every worker was killed")
        return old, tuple(self._workers), left, tuple(joined)
