"""`repro.fault` — elastic fault-tolerant training (the paper's lineage,
production-grade).

Theano-MPI's whole point was sync+async data parallelism on clusters where
workers straggle, die, and rejoin (the GroundHog READY/TRAIN/DONE/EXIT
protocol is the ancestral shape). This package brings that to the unified
train engine:

- :mod:`repro.fault.membership` — the membership controller: live-worker
  tracking between tau-step rounds, quorum decisions, staleness
  accounting, device-slot allocation for joiners.
- :mod:`repro.fault.inject` — a declarative, seeded :class:`FaultPlan`
  (kill / join / straggle / drop / corrupt at named steps) so every chaos
  run is exactly reproducible.
- :mod:`repro.fault.elastic` — :func:`elastic_train`, the loop that drives
  the engine's quorum-sync programs, rebuilds jitted programs on
  membership change, and reshards center + optimizer state onto the
  surviving mesh.
- :mod:`repro.fault.smoke` — the chaos-harness CLI the CI ``fault-smoke``
  job runs (kill + straggle + corrupt schedule, convergence-band assert).

See DESIGN.md "Fault tolerance & elasticity".
"""
from repro.fault.inject import (FaultEvent, FaultPlan, bitflip,
                                payload_checksum)
from repro.fault.membership import MembershipController, WorkerState
from repro.fault.elastic import ElasticReport, Preempted, elastic_train

__all__ = [
    "FaultEvent", "FaultPlan", "bitflip", "payload_checksum",
    "MembershipController", "WorkerState",
    "ElasticReport", "Preempted", "elastic_train",
]
