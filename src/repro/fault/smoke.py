"""Chaos smoke harness — the CI gate for the fault-tolerance stack.

    PYTHONPATH=src python -m repro.fault.smoke --out /tmp/fault-smoke

Runs, on 8 virtual CPU devices:

1. a clean elastic run (full participation) — the convergence reference;
2. a chaos run under a kill + straggle + corrupt + drop + rejoin
   schedule with a quorum of 2 — must recover into the clean run's loss
   band;
3. the same chaos run again — must be bit-identical (seeded FaultPlan
   replay determinism, center params and round log compared);
4. a preempted run (process "dies" mid-flight after a kill) resumed from
   its latest crash-safe checkpoint — must land in the same band as the
   uninterrupted chaos run.

Exits nonzero on the first violated property. Telemetry goes to
``--out`` (metrics JSONL + Perfetto trace) for
``python -m repro.telemetry.validate``.
"""
from __future__ import annotations

import os

# must precede the first jax import: the harness simulates an 8-worker
# fleet as 8 virtual CPU devices
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse          # noqa: E402
import sys               # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro import telemetry                          # noqa: E402
from repro.configs import get_smoke_config           # noqa: E402
from repro.data.synthetic import LMTokenSource       # noqa: E402
from repro.models import build_model                 # noqa: E402
from repro.optim import constant, sgd_momentum       # noqa: E402
from repro.train.engine import TrainPlan             # noqa: E402
from repro.fault.elastic import Preempted, elastic_train  # noqa: E402

CHAOS = "kill:3@9,straggle:2@13x2,corrupt:1@21,drop:0@29,join:3@33"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="directory for metrics.jsonl + trace.json")
    ap.add_argument("--steps", type=int, default=48)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--quorum", type=int, default=2)
    ap.add_argument("--fault-plan", default=CHAOS)
    args = ap.parse_args(argv)

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        telemetry.configure(
            metrics_out=os.path.join(args.out, "metrics.jsonl"))

    cfg = get_smoke_config("llama3.2-1b").with_overrides(
        vocab_size=64, d_ff=128, num_layers=2, dtype="float32")
    model = build_model(cfg)
    opt = sgd_momentum(weight_decay=0.0)
    src = LMTokenSource(cfg.vocab_size, 16, seed=0)
    batch_fn = lambda step, k: src.batch(4 * k, step)
    plan = TrainPlan(algo="easgd", tau=args.tau, alpha=0.5,
                     exchanger="ar", quorum=args.quorum)

    def run(tag, **kw):
        print(f"-- {tag}")
        return elastic_train(model, opt, constant(0.05), batch_fn,
                             plan=plan, num_workers=args.workers,
                             num_steps=args.steps, seed=0, log_every=16,
                             **kw)

    failures = []

    def check(name, ok, detail):
        print(f"{'PASS' if ok else 'FAIL'}: {name} ({detail})")
        if not ok:
            failures.append(name)

    # 1+2: clean reference vs chaos run
    _, clean = run("clean (full participation)")
    s_chaos, chaos = run("chaos", fault_plan=args.fault_plan)
    check("chaos faults exercised",
          chaos.kills >= 1 and chaos.payloads_corrupt >= 1
          and chaos.payloads_dropped >= 1 and chaos.rebuilds >= 1,
          f"kills={chaos.kills} corrupt={chaos.payloads_corrupt} "
          f"dropped={chaos.payloads_dropped} rebuilds={chaos.rebuilds}")
    # convergence band: chaos must realize most of the clean run's loss
    # drop — membership churn costs a little progress, not convergence
    drop_clean = clean.losses[0] - clean.losses[-1]
    band = 0.35 * drop_clean + 0.05
    check("chaos converges into the clean loss band",
          chaos.losses[-1] < clean.losses[0]
          and abs(chaos.losses[-1] - clean.losses[-1]) <= band,
          f"chaos {chaos.losses[-1]:.4f} vs clean {clean.losses[-1]:.4f} "
          f"(band {band:.4f})")

    # 3: seeded replay is bit-identical
    s_replay, replay = run("chaos replay", fault_plan=args.fault_plan)
    bitwise = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(s_chaos["center"]),
                        jax.tree.leaves(s_replay["center"])))
    check("fault replay bit-identical",
          bitwise and chaos.round_log == replay.round_log,
          f"center equal={bitwise}, "
          f"round_log equal={chaos.round_log == replay.round_log}")

    # 4: preempt mid-chaos, resume from the crash-safe checkpoint
    ck = os.path.join(args.out or "/tmp", "fault-smoke-ck")
    try:
        run("chaos preempted", fault_plan=args.fault_plan, ckpt_path=ck,
            ckpt_every=args.steps // 6, stop_at_step=args.steps // 2 + 2)
        check("preemption fired", False, "Preempted was not raised")
    except Preempted as e:
        print(f"   preempted at step {e.step}")
    _, resumed = run("chaos resumed", fault_plan=args.fault_plan,
                     resume_from=ck)
    check("preempt+resume lands in the chaos band",
          resumed.steps == args.steps
          and abs(resumed.losses[-1] - chaos.losses[-1]) <= band,
          f"resumed {resumed.losses[-1]:.4f} vs chaos "
          f"{chaos.losses[-1]:.4f} (band {band:.4f})")

    telemetry.flush(force=True)
    if args.out:
        telemetry.trace.export(os.path.join(args.out, "trace.json"))
        print(f"telemetry -> {args.out}")
    if failures:
        print(f"fault-smoke: {len(failures)} FAILED: {failures}")
        return 1
    print("fault-smoke: all properties hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
