from repro.core.exchanger import (Exchanger, EXCHANGERS, get_exchanger,
                                  default_chunk_sum, make_rs_plan,
                                  param_wire_dtype)
from repro.core.bsp import (make_bsp_step, make_loss_grad_step,
                            init_train_state, init_sharded_train_state)
from repro.core.easgd import make_async_step, init_async_state
from repro.core.gspmd import make_gspmd_step, fsdp_state_shardings
