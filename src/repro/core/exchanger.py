"""Parameter-exchange strategies — the paper's core contribution (§3.2).

Theano-MPI exchanges gradients/parameters between data-parallel workers with
one of several strategies; this module reimplements them as explicit JAX
collectives that run inside ``jax.shard_map`` over the *data* (and *pod*)
mesh axes, leaving any model-parallel axes to GSPMD ("auto" axes):

- ``ar``    : MPI_Allreduce analogue            -> ``lax.psum``
- ``asa``   : Alltoall-sum-Allgather (Fig 2)    -> ``lax.all_to_all`` +
              local fp32 sum + ``lax.all_gather``  (== reduce-scatter + AG,
              transfer separated from arithmetic exactly as in the paper)
- ``asa16`` : ASA with half-precision transfer, fp32 summation (§3.2)
- ``asa8``  : beyond-paper int8 + per-shard scale transfer
- ``ring``  : beyond-paper ring reduce-scatter/all-gather via
              ``lax.ppermute`` (bandwidth-optimal on a torus link)
- ``hier``  : beyond-paper pod-hierarchical exchange — intra-pod
              reduce-scatter, cross-pod (DCN) allreduce of the 1/k shard,
              intra-pod all-gather. The TPU analogue of the paper's
              "QPI-aware" staging concern.

All strategies split each gradient leaf along **axis 0** (padding as needed)
so that model-parallel shardings on other axes are untouched.

Every strategy computes the *mean* over the data axis and is numerically
interchangeable (up to its transfer precision) — property-tested in
``tests/test_exchangers.py``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


# leaves smaller than this are psum'd directly (chunking overhead dominates)
_SMALL_LEAF = 1024


def _axis_size(axis) -> int:
    if isinstance(axis, (tuple, list)):
        return int(np.prod([jax.lax.axis_size(a) for a in axis]))
    return jax.lax.axis_size(axis)


def _pad_to(g, k: int):
    n = g.shape[0]
    pad = (-n) % k
    if pad:
        g = jnp.pad(g, ((0, pad),) + ((0, 0),) * (g.ndim - 1))
    return g, n


def default_chunk_sum(chunks):
    """fp32-accumulating sum over the leading (worker) axis.

    The Pallas `chunk_sum` kernel implements the same contract on TPU; the
    exchanger takes it as a plug-in (see ``ops.chunk_sum``)."""
    return jnp.sum(chunks.astype(jnp.float32), axis=0)


# ---------------------------------------------------------------------------
# strategies (per-leaf, inside shard_map)
# ---------------------------------------------------------------------------

def ar_leaf(g, axis, **_):
    """MPI_Allreduce analogue."""
    k = _axis_size(axis)
    return (jax.lax.psum(g.astype(jnp.float32), axis) / k).astype(g.dtype)


def asa_leaf(g, axis, transfer_dtype=None, sum_fn=default_chunk_sum, **_):
    """Alltoall -> local sum (fp32) -> Allgather.  Paper Fig 2.

    ``transfer_dtype``: dtype used on the wire (fp16/bf16/int8 variants);
    summation always accumulates in fp32 (paper: "transfer of parameters at
    half-precision while summing them at full precision").
    """
    if isinstance(axis, (tuple, list)) and len(axis) == 1:
        axis = axis[0]
    if isinstance(axis, (tuple, list)):
        # multi-axis (pod,data): treat hierarchically
        return hier_leaf(g, axis, transfer_dtype=transfer_dtype,
                         sum_fn=sum_fn)
    k = jax.lax.axis_size(axis)
    dtype = g.dtype
    if g.size <= _SMALL_LEAF:
        return ar_leaf(g, axis)
    shape0 = g.shape
    if g.shape[0] < k:
        # leading dim too short to chunk (e.g. stacked-layer leaves at very
        # wide DP): chunk the flattened view instead. NOTE: only reached in
        # practice on pure-DP meshes; with model-parallel leaves dim0 (layer
        # stack) >= data-axis size on the production meshes.
        g = g.reshape(-1)
    gp, n = _pad_to(g, k)
    chunks = gp.reshape(k, -1, *gp.shape[1:])

    if transfer_dtype == jnp.int8:
        out = _asa_int8(chunks, g, n, k, axis, sum_fn, dtype)
        return out.reshape(shape0)

    if transfer_dtype is not None:
        chunks = chunks.astype(transfer_dtype)
    # transfer: scatter chunk i to rank i
    recv = jax.lax.all_to_all(chunks, axis, split_axis=0, concat_axis=0,
                              tiled=False)
    # arithmetic: local summation at full precision (the paper's GPU kernel)
    s = sum_fn(recv) / k                                  # fp32
    if transfer_dtype is not None:
        s = s.astype(transfer_dtype)
    out = jax.lax.all_gather(s, axis, axis=0, tiled=True)
    out = out.reshape(gp.shape)[:n] if out.shape[0] != n else out
    return out.astype(dtype).reshape(shape0)


def _asa_int8(chunks, g, n, k, axis, sum_fn, dtype):
    """int8 transfer with one fp32 scale per (rank-)chunk."""
    cf = chunks.astype(jnp.float32)
    scale = jnp.max(jnp.abs(cf), axis=tuple(range(1, cf.ndim)),
                    keepdims=True) / 127.0 + 1e-12        # (k,1,..)
    q = jnp.clip(jnp.round(cf / scale), -127, 127).astype(jnp.int8)
    recv = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0)
    rscale = jax.lax.all_to_all(scale, axis, split_axis=0, concat_axis=0)
    deq = recv.astype(jnp.float32) * rscale
    s = jnp.sum(deq, axis=0) / k                          # fp32 (1/k,...)
    # requantize the reduced shard for the gather leg
    s_scale = jnp.max(jnp.abs(s)) / 127.0 + 1e-12
    sq = jnp.clip(jnp.round(s / s_scale), -127, 127).astype(jnp.int8)
    out_q = jax.lax.all_gather(sq, axis, axis=0, tiled=True)
    out_s = jax.lax.all_gather(s_scale[None], axis, axis=0, tiled=True)
    c = out_q.shape[0] // k
    out = out_q.astype(jnp.float32) * jnp.repeat(out_s, c, axis=0).reshape(
        (-1,) + (1,) * (out_q.ndim - 1))
    out = out.reshape(k * c, *out_q.shape[1:])[:n]
    return out.astype(dtype)


def ring_leaf(g, axis, transfer_dtype=None, **_):
    """Ring reduce-scatter + ring all-gather via collective_permute."""
    if isinstance(axis, (tuple, list)):
        if len(axis) == 1:
            axis = axis[0]
        else:
            return hier_leaf(g, axis, transfer_dtype=transfer_dtype,
                             inner=ring_leaf)
    k = jax.lax.axis_size(axis)
    dtype = g.dtype
    if g.size <= _SMALL_LEAF or g.shape[0] < k or k == 1:
        return ar_leaf(g, axis)
    gp, n = _pad_to(g, k)
    x = gp.reshape(k, -1, *gp.shape[1:]).astype(jnp.float32)
    idx = jax.lax.axis_index(axis)
    fwd = [(i, (i + 1) % k) for i in range(k)]

    # ring reduce-scatter (textbook): at step s rank i sends its partial of
    # chunk (i-s)%k and receives chunk (i-s-1)%k, adding its local copy.
    # After k-1 steps rank i holds chunk (i+1)%k fully reduced.
    acc = jnp.take(x, idx % k, axis=0)
    for s in range(k - 1):
        acc_t = acc.astype(transfer_dtype) if transfer_dtype is not None else acc
        recv = jax.lax.ppermute(acc_t, axis, fwd).astype(jnp.float32)
        acc = recv + jnp.take(x, (idx - s - 1) % k, axis=0)
    acc = acc / k

    # ring all-gather: after s permutes rank i holds rank (i-s)'s chunk,
    # i.e. chunk (i-s+1)%k.
    buf = jnp.zeros_like(x)
    cur = acc
    buf = jax.lax.dynamic_update_index_in_dim(buf, cur, (idx + 1) % k, axis=0)
    for s in range(1, k):
        cur_t = cur.astype(transfer_dtype) if transfer_dtype is not None else cur
        cur = jax.lax.ppermute(cur_t, axis, fwd).astype(jnp.float32)
        buf = jax.lax.dynamic_update_index_in_dim(
            buf, cur, (idx - s + 1) % k, axis=0)
    out = buf.reshape(gp.shape)[:n]
    return out.astype(dtype)


def hier_leaf(g, axis, transfer_dtype=None, sum_fn=default_chunk_sum,
              inner=None, **_):
    axes = axis
    """Pod-hierarchical exchange over ('pod', 'data').

    intra-pod reduce-scatter (ICI) -> cross-pod allreduce of the shard
    (DCN, 1/k_data of the bytes) -> intra-pod all-gather.
    """
    if not isinstance(axes, (tuple, list)) or len(axes) == 1:
        ax = axes[0] if isinstance(axes, (tuple, list)) else axes
        return asa_leaf(g, ax, transfer_dtype=transfer_dtype, sum_fn=sum_fn)
    pod_axis, data_axis = axes[0], axes[-1]
    k = jax.lax.axis_size(data_axis)
    kp = jax.lax.axis_size(pod_axis)
    dtype = g.dtype
    if g.size <= _SMALL_LEAF or g.shape[0] < k:
        return ar_leaf(g, tuple(axes))
    if transfer_dtype == jnp.int8:
        transfer_dtype = jnp.float16  # int8 scaling not plumbed across pods
    gp, n = _pad_to(g, k)
    chunks = gp.reshape(k, -1, *gp.shape[1:])
    if transfer_dtype is not None:
        chunks = chunks.astype(transfer_dtype)
    recv = jax.lax.all_to_all(chunks, data_axis, split_axis=0, concat_axis=0)
    s = sum_fn(recv)                                      # fp32 shard
    # cross-pod: only 1/k of the gradient crosses the DCN
    s = jax.lax.psum(s, pod_axis) / (k * kp)
    if transfer_dtype is not None:
        s = s.astype(transfer_dtype)
    out = jax.lax.all_gather(s, data_axis, axis=0, tiled=True)
    out = out.reshape(gp.shape)[:n]
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# pytree-level exchanger
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Exchanger:
    """Named strategy applied leaf-wise to a gradient pytree."""
    name: str
    leaf_fn: Callable
    transfer_dtype: object = None

    def exchange(self, grads, axis, sum_fn=default_chunk_sum,
                 bucket_bytes: int = 0):
        """Mean-reduce ``grads`` across ``axis`` (str or tuple of axes).

        ``bucket_bytes`` > 0 packs leaves into flat fp32 buckets of up to
        that size before exchanging (DDP-style bucketing: fewer, larger
        collectives — a latency win when leaves are many/small). Only valid
        for data-parallel-only setups: flattening would destroy
        model-parallel shardings.
        """
        fn = functools.partial(self.leaf_fn, axis=axis,
                               transfer_dtype=self.transfer_dtype,
                               sum_fn=sum_fn)
        if not bucket_bytes:
            return jax.tree.map(fn, grads)
        leaves, treedef = jax.tree.flatten(grads)
        flats = [l.astype(jnp.float32).reshape(-1) for l in leaves]
        buckets, cur, cur_b = [], [], 0
        for i, f in enumerate(flats):
            if cur and cur_b + f.size * 4 > bucket_bytes:
                buckets.append(cur)
                cur, cur_b = [], 0
            cur.append(i)
            cur_b += f.size * 4
        if cur:
            buckets.append(cur)
        out_flats = [None] * len(flats)
        for idxs in buckets:
            packed = jnp.concatenate([flats[i] for i in idxs])
            red = fn(packed)
            off = 0
            for i in idxs:
                n = flats[i].size
                out_flats[i] = red[off:off + n]
                off += n
        outs = [of.reshape(l.shape).astype(l.dtype)
                for of, l in zip(out_flats, leaves)]
        return jax.tree.unflatten(treedef, outs)


EXCHANGERS: dict[str, Exchanger] = {
    "ar": Exchanger("ar", ar_leaf),
    "asa": Exchanger("asa", asa_leaf),
    "asa16": Exchanger("asa16", asa_leaf, jnp.float16),
    "asabf16": Exchanger("asabf16", asa_leaf, jnp.bfloat16),
    "asa8": Exchanger("asa8", asa_leaf, jnp.int8),
    "ring": Exchanger("ring", ring_leaf),
    "ring16": Exchanger("ring16", ring_leaf, jnp.float16),
    "hier": Exchanger("hier", hier_leaf),
    "hier16": Exchanger("hier16", hier_leaf, jnp.float16),
}


def get_exchanger(name: str) -> Exchanger:
    if name not in EXCHANGERS:
        raise KeyError(f"unknown exchanger {name!r}; known: {sorted(EXCHANGERS)}")
    return EXCHANGERS[name]
