"""Parameter-exchange strategies — the paper's core contribution (§3.2).

Theano-MPI exchanges gradients/parameters between data-parallel workers with
one of several strategies; this module reimplements them as explicit JAX
collectives that run inside ``jax.shard_map`` over the *data* (and *pod*)
mesh axes, leaving any model-parallel axes to GSPMD ("auto" axes):

- ``ar``    : MPI_Allreduce analogue            -> ``lax.psum``
- ``asa``   : Alltoall-sum-Allgather (Fig 2)    -> ``lax.all_to_all`` +
              local fp32 sum + ``lax.all_gather``  (== reduce-scatter + AG,
              transfer separated from arithmetic exactly as in the paper)
- ``asa16`` : ASA with half-precision transfer, fp32 summation (§3.2)
- ``asa8``  : beyond-paper int8 + per-shard scale transfer
- ``ring``  : beyond-paper ring reduce-scatter/all-gather via
              ``lax.ppermute`` (bandwidth-optimal on a torus link)
- ``hier``  : beyond-paper pod-hierarchical exchange — intra-pod
              reduce-scatter, cross-pod (DCN) allreduce of the 1/k shard,
              intra-pod all-gather. The TPU analogue of the paper's
              "QPI-aware" staging concern.
- ``none``  : identity (benchmark baseline: isolates compute from exchange)

Every strategy is split into composable **halves**:

    reduce_scatter(grads) -> 1/k shard     all_gather(shard) -> full tree

and ``exchange`` is their composition (``ar`` keeps the single fused
``psum`` so the MPI_Allreduce baseline of the paper's Table 3 stays one
collective; its halves are ``psum_scatter``/``all_gather``). The split is
what lets the optimizer update only the local shard between the halves
(ZeRO-1-style RS -> update -> AG, see ``core/bsp.py``): the full reduced
gradient is never materialized and the fp16/int8 wire precision applies to
both directions (gradients in, updated parameters out).

Leaves are packed into flat fp32 **buckets** (``make_rs_plan``): one bucket
per leaf by default, or DDP-style multi-leaf buckets of up to
``bucket_bytes``. Leaves smaller than ``_SMALL_LEAF`` elements are psum'd
whole and updated replicated — chunking overhead dominates there.

NOTE: flattening assumes gradient leaves are *replicated* over any
model-parallel mesh axes inside the shard_map body — the invariant the
BSP path maintains (``repro.dist.state_shardings`` replicates train state;
on jax 0.4.x shard_map is fully manual, see ``repro/_compat.py``). Under
a future partial-auto shard_map with model-sharded gradient leaves, the
reshape/concat would force GSPMD to regather each leaf — the GSPMD/ZeRO-1
path (``core/gspmd.py``) is the right tool there, not this module.

Every strategy computes the *mean* over the data axes and is numerically
interchangeable (up to its transfer precision) — property-tested in
``tests/test_exchangers.py`` / ``tests/test_rs_update.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


# leaves smaller than this are psum'd directly (chunking overhead dominates)
_SMALL_LEAF = 1024


def norm_axes(data_axes):
    """Collapse a data-axes tuple to the form the collectives take: the
    bare name for a single axis, the tuple itself otherwise."""
    axes = tuple(data_axes)
    return axes[0] if len(axes) == 1 else axes


def _axis_size(axis) -> int:
    if isinstance(axis, (tuple, list)):
        return int(np.prod([jax.lax.axis_size(a) for a in axis]))
    return jax.lax.axis_size(axis)


def _split_axes(axis):
    """(lead_axes, rs_axis): the reduce-scatter/all-gather legs run over the
    *last* axis (intra-pod ICI); any leading axes (cross-pod DCN) see only a
    psum of the 1/k shard."""
    if isinstance(axis, (tuple, list)):
        axes = tuple(axis)
        return axes[:-1], axes[-1]
    return (), axis


def _pad_to(g, k: int):
    n = g.shape[0]
    pad = (-n) % k
    if pad:
        g = jnp.pad(g, ((0, pad),) + ((0, 0),) * (g.ndim - 1))
    return g, n


def default_chunk_sum(chunks):
    """fp32-accumulating sum over the leading (worker) axis.

    The Pallas `chunk_sum` kernel implements the same contract on TPU; the
    exchanger takes it as a plug-in (see ``ops.chunk_sum``)."""
    return jnp.sum(chunks.astype(jnp.float32), axis=0)


# ---------------------------------------------------------------------------
# bucket plan: the static layout shared by RS, update, and AG
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BucketSpec:
    """One flat fp32 bucket: which leaves it packs and its padded extent."""
    leaves: tuple[int, ...]      # leaf indices (tree.flatten order)
    sizes: tuple[int, ...]       # flat element counts, same order
    shard_len: int               # per-rank shard extent
    padded: int                  # k * shard_len


@dataclass(frozen=True)
class RSPlan:
    """Static reduce-scatter plan for one gradient/parameter pytree.

    Derived deterministically from (leaf shapes, k, bucket_bytes) so the
    optimizer-state layout built at init time and the step built at trace
    time always agree."""
    k: int                       # rs-axis worker count (shard denominator)
    buckets: tuple[BucketSpec, ...]
    small: tuple[int, ...]       # leaf indices exchanged whole (psum)
    treedef: Any
    shapes: tuple
    dtypes: tuple

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)


def _leaf_size(leaf) -> int:
    return int(np.prod(leaf.shape)) if leaf.shape else 1


def make_rs_plan(tree, k: int, bucket_bytes: int = 0,
                 small_leaf: int = _SMALL_LEAF) -> RSPlan:
    """Pack a pytree's leaves into reduce-scatter buckets.

    ``tree`` may hold arrays or ``ShapeDtypeStruct``s (the plan only reads
    shapes/dtypes). ``bucket_bytes=0`` gives one bucket per big leaf;
    ``bucket_bytes>0`` greedily packs consecutive big leaves into flat fp32
    buckets of up to that size (fewer, larger collectives)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    small, groups, cur, cur_b = [], [], [], 0
    for i, l in enumerate(leaves):
        n = _leaf_size(l)
        if n <= small_leaf:
            small.append(i)
            continue
        if bucket_bytes and cur and cur_b + n * 4 > bucket_bytes:
            groups.append(cur)
            cur, cur_b = [], 0
        cur.append(i)
        cur_b += n * 4
        if not bucket_bytes:
            groups.append(cur)
            cur, cur_b = [], 0
    if cur:
        groups.append(cur)
    buckets = []
    for g in groups:
        sizes = tuple(_leaf_size(leaves[i]) for i in g)
        total = sum(sizes)
        shard_len = -(-total // k)
        buckets.append(BucketSpec(tuple(g), sizes, shard_len, shard_len * k))
    return RSPlan(k, tuple(buckets), tuple(small), treedef, shapes, dtypes)


# ---------------------------------------------------------------------------
# per-bucket halves on flat fp32 arrays (inside shard_map)
# ---------------------------------------------------------------------------

def _quant_rows(cf):
    """Per-row absmax int8 quantization: (k, s) fp32 -> (q int8, scale (k,1))."""
    scale = jnp.max(jnp.abs(cf), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(cf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _rs_ar(flat, axes, inv_k, sum_fn, transfer_dtype):
    """psum_scatter over the rs axis (+ psum over lead axes): true HLO
    reduce-scatter, fp32 on the wire."""
    lead, ax = _split_axes(axes)
    s = jax.lax.psum_scatter(flat, ax, scatter_dimension=0, tiled=True)
    if lead:
        s = jax.lax.psum(s, tuple(lead))
    return s * inv_k


def _rs_asa(flat, axes, inv_k, sum_fn, transfer_dtype):
    """Alltoall -> local fp32 sum (paper Fig 2), optional lead-axes psum of
    the 1/k shard (the hierarchical/DCN leg)."""
    lead, ax = _split_axes(axes)
    k = jax.lax.axis_size(ax)
    chunks = flat.reshape(k, -1)
    if transfer_dtype == jnp.int8 and lead:
        transfer_dtype = jnp.float16   # int8 scaling not plumbed across pods
    if transfer_dtype == jnp.int8:
        q, scale = _quant_rows(chunks)
        recv = jax.lax.all_to_all(q, ax, split_axis=0, concat_axis=0)
        rscale = jax.lax.all_to_all(scale, ax, split_axis=0, concat_axis=0)
        s = jnp.sum(recv.astype(jnp.float32) * rscale, axis=0)
    else:
        if transfer_dtype is not None:
            chunks = chunks.astype(transfer_dtype)
        recv = jax.lax.all_to_all(chunks, ax, split_axis=0, concat_axis=0)
        s = sum_fn(recv)
    if lead:
        s = jax.lax.psum(s, tuple(lead))
    return s * inv_k


def _rs_asa_raw(flat, axes, sum_fn, transfer_dtype):
    """Transfer-only RS half: the received per-rank chunks BEFORE summation,
    so a fused kernel can do dequant + fp32 sum + update in one VMEM pass.

    Returns ``(recv (k, s) wire-dtype, scales (k, 1) | None)``; the caller
    owns the mean divisor. Single-axis only."""
    lead, ax = _split_axes(axes)
    assert not lead, "raw reduce-scatter is single-axis (intra-pod) only"
    k = jax.lax.axis_size(ax)
    chunks = flat.reshape(k, -1)
    if transfer_dtype == jnp.int8:
        q, scale = _quant_rows(chunks)
        recv = jax.lax.all_to_all(q, ax, split_axis=0, concat_axis=0)
        rscale = jax.lax.all_to_all(scale, ax, split_axis=0, concat_axis=0)
        return recv, rscale
    if transfer_dtype is not None:
        chunks = chunks.astype(transfer_dtype)
    recv = jax.lax.all_to_all(chunks, ax, split_axis=0, concat_axis=0)
    return recv, None


def _rs_ring(flat, axes, inv_k, sum_fn, transfer_dtype):
    """Ring reduce-scatter via collective_permute; rank i ends holding
    chunk i fully reduced (aligned with the AG/update shard layout)."""
    lead, ax = _split_axes(axes)
    if lead:   # cross-pod: stage hierarchically like asa/hier
        return _rs_asa(flat, axes, inv_k, sum_fn, transfer_dtype)
    k = jax.lax.axis_size(ax)
    if k == 1:
        return flat * inv_k
    x = flat.reshape(k, -1)
    idx = jax.lax.axis_index(ax)
    fwd = [(i, (i + 1) % k) for i in range(k)]
    # at step s rank i sends its partial of chunk (i-s-1)%k and receives
    # chunk (i-s-2)%k, adding its local copy; after k-1 steps rank i holds
    # chunk i fully reduced.
    acc = jnp.take(x, (idx - 1) % k, axis=0)
    for s in range(k - 1):
        acc_t = acc.astype(transfer_dtype) if transfer_dtype is not None else acc
        recv = jax.lax.ppermute(acc_t, ax, fwd).astype(jnp.float32)
        acc = recv + jnp.take(x, (idx - s - 2) % k, axis=0)
    return acc * inv_k


def _ag_ring(shard, axes, transfer_dtype):
    """Ring all-gather: after s permutes rank i holds rank (i-s)'s chunk."""
    lead, ax = _split_axes(axes)
    if lead:
        return _ag_flat(shard, axes, transfer_dtype)
    k = jax.lax.axis_size(ax)
    if k == 1:
        return shard
    idx = jax.lax.axis_index(ax)
    fwd = [(i, (i + 1) % k) for i in range(k)]
    buf = jnp.zeros((k, shard.shape[0]), jnp.float32)
    cur = shard
    buf = jax.lax.dynamic_update_index_in_dim(buf, cur, idx, axis=0)
    for s in range(1, k):
        cur_t = cur.astype(transfer_dtype) if transfer_dtype is not None else cur
        cur = jax.lax.ppermute(cur_t, ax, fwd).astype(jnp.float32)
        buf = jax.lax.dynamic_update_index_in_dim(buf, cur, (idx - s) % k,
                                                  axis=0)
    return buf.reshape(-1)


def _ag_flat(shard, axes, transfer_dtype):
    """All-gather the (s,) fp32 shard back to (k*s,) over the rs axis, at
    the wire dtype (int8 requantizes with one fp32 scale per shard)."""
    lead, ax = _split_axes(axes)
    del lead   # lead axes already hold identical shards (post cross-pod psum)
    if transfer_dtype == jnp.int8:
        scale = jnp.max(jnp.abs(shard)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(shard / scale), -127, 127).astype(jnp.int8)
        out_q = jax.lax.all_gather(q, ax, axis=0, tiled=True)
        out_s = jax.lax.all_gather(scale[None], ax, axis=0, tiled=True)
        s_len = shard.shape[0]
        return out_q.astype(jnp.float32) * jnp.repeat(out_s, s_len, axis=0)
    if transfer_dtype is not None:
        shard = shard.astype(transfer_dtype)
    return jax.lax.all_gather(shard, ax, axis=0, tiled=True).astype(
        jnp.float32)


_RS_FNS = {"ar": _rs_ar, "asa": _rs_asa, "ring": _rs_ring}
_AG_FNS = {"ar": _ag_flat, "asa": _ag_flat, "ring": _ag_ring}


# ---------------------------------------------------------------------------
# pytree-level exchanger
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Exchanger:
    """Named strategy applied bucket-wise to a gradient pytree.

    ``kind`` picks the collective family (``ar`` | ``asa`` | ``ring`` |
    ``none``); ``hier`` is the ``asa`` family over a ('pod', 'data') axis
    tuple. ``transfer_dtype`` is the wire format of both halves."""
    name: str
    kind: str
    transfer_dtype: object = None

    # -- plan / packing helpers (static) ----------------------------------

    def plan_for(self, tree, axis_or_k, bucket_bytes: int = 0) -> RSPlan:
        k = axis_or_k if isinstance(axis_or_k, int) else _axis_size(
            _split_axes(axis_or_k)[1])
        return make_rs_plan(tree, k, bucket_bytes)

    @staticmethod
    def pack(tree, plan: RSPlan):
        """-> (flat fp32 padded bucket list, small-leaf list, leaves)."""
        leaves = jax.tree.flatten(tree)[0]
        flats = []
        for b in plan.buckets:
            f = jnp.concatenate(
                [leaves[i].reshape(-1).astype(jnp.float32) for i in b.leaves])
            pad = b.padded - f.shape[0]
            if pad:
                f = jnp.pad(f, (0, pad))
            flats.append(f)
        return flats, [leaves[i] for i in plan.small], leaves

    @staticmethod
    def unpack(flats, smalls, plan: RSPlan):
        """Inverse of ``pack``: rebuild the pytree at original shapes/dtypes."""
        out = [None] * len(plan.shapes)
        for b, f in zip(plan.buckets, flats):
            off = 0
            for i, n in zip(b.leaves, b.sizes):
                out[i] = f[off:off + n].reshape(plan.shapes[i]).astype(
                    plan.dtypes[i])
                off += n
        for i, s in zip(plan.small, smalls):
            out[i] = s.astype(plan.dtypes[i]).reshape(plan.shapes[i])
        return jax.tree.unflatten(plan.treedef, out)

    # -- the halves (inside shard_map) ------------------------------------

    def reduce_scatter(self, grads, axis, *, sum_fn=default_chunk_sum,
                       bucket_bytes: int = 0, plan: RSPlan | None = None,
                       raw: bool = False):
        """Mean-reduce and scatter: each rank keeps the fp32 shard of every
        bucket plus the fully psum'd small leaves.

        Returns ``({"shards", "full"}, plan)`` — or with ``raw=True`` (asa
        family only) ``{"chunks", "scales", "full"}`` where chunks are the
        un-summed per-rank receives for the fused RS+update kernel."""
        if self.kind == "none":
            raise ValueError("'none' exchanger has no reduce_scatter half")
        if plan is None:
            plan = self.plan_for(grads, axis, bucket_bytes)
        inv_k = 1.0 / _axis_size(axis)
        flats, smalls, _ = self.pack(grads, plan)
        full = [jax.lax.psum(s.astype(jnp.float32), axis) * inv_k
                for s in smalls]
        if raw:
            if not self.supports_raw:
                raise ValueError(
                    f"raw reduce-scatter unsupported for {self.name!r}")
            pairs = [_rs_asa_raw(f, axis, sum_fn, self.transfer_dtype)
                     for f in flats]
            return {"chunks": [p[0] for p in pairs],
                    "scales": [p[1] for p in pairs if p[1] is not None],
                    "full": full}, plan
        rs = _RS_FNS[self.kind]
        shards = [rs(f, axis, inv_k, sum_fn, self.transfer_dtype)
                  for f in flats]
        return {"shards": shards, "full": full}, plan

    def all_gather(self, shards, plan: RSPlan, axis, *,
                   wire_dtype=...):
        """Gather (s,) fp32 shards back to (k*s,) flat buckets at the wire
        dtype. ``wire_dtype`` overrides the strategy's transfer dtype (e.g.
        fp32 parameter gathers, or int8 strategies gathering params at
        fp16)."""
        if wire_dtype is ...:
            wire_dtype = self.transfer_dtype
        ag = _AG_FNS[self.kind]
        return [ag(s, axis, wire_dtype) for s in shards]

    @property
    def supports_raw(self) -> bool:
        """Whether reduce_scatter(raw=True) can hand un-summed chunks to the
        fused RS+update kernel (single-axis alltoall family)."""
        return self.kind == "asa"

    # -- full exchange (composition of the halves) ------------------------

    def exchange(self, grads, axis, sum_fn=default_chunk_sum,
                 bucket_bytes: int = 0):
        """Mean-reduce ``grads`` across ``axis`` (str or tuple of axes).

        Composition of ``reduce_scatter`` and ``all_gather``; ``ar`` keeps
        the single fused ``psum`` per bucket so the MPI_Allreduce baseline
        stays one collective (XLA lowers it to RS+AG internally anyway).

        ``bucket_bytes`` > 0 packs leaves into flat fp32 buckets of up to
        that size before exchanging (DDP-style bucketing: fewer, larger
        collectives — a latency win when leaves are many/small). Only valid
        for data-parallel-only setups: flattening would destroy
        model-parallel shardings.
        """
        if self.kind == "none":
            return grads
        plan = self.plan_for(grads, axis, bucket_bytes)
        if self.kind == "ar":
            inv_k = 1.0 / _axis_size(axis)
            flats, smalls, _ = self.pack(grads, plan)
            red = [jax.lax.psum(f, axis) * inv_k for f in flats]
            full = [jax.lax.psum(s.astype(jnp.float32), axis) * inv_k
                    for s in smalls]
            return self.unpack(red, full, plan)
        res, plan = self.reduce_scatter(grads, axis, sum_fn=sum_fn,
                                        plan=plan)
        flats = self.all_gather(res["shards"], plan, axis)
        return self.unpack(flats, res["full"], plan)


EXCHANGERS: dict[str, Exchanger] = {
    "ar": Exchanger("ar", "ar"),
    "asa": Exchanger("asa", "asa"),
    "asa16": Exchanger("asa16", "asa", jnp.float16),
    "asabf16": Exchanger("asabf16", "asa", jnp.bfloat16),
    "asa8": Exchanger("asa8", "asa", jnp.int8),
    "ring": Exchanger("ring", "ring"),
    "ring16": Exchanger("ring16", "ring", jnp.float16),
    "hier": Exchanger("hier", "asa"),
    "hier16": Exchanger("hier16", "asa", jnp.float16),
    "none": Exchanger("none", "none"),
}


def get_exchanger(name: str) -> Exchanger:
    if name not in EXCHANGERS:
        raise KeyError(f"unknown exchanger {name!r}; known: {sorted(EXCHANGERS)}")
    return EXCHANGERS[name]


def _dtype_bytes(dtype) -> int:
    return 4 if dtype is None else jnp.dtype(dtype).itemsize


def wire_summary(exchanger: Exchanger, plan: RSPlan, *,
                 param_ag: bool = False, sync_every: int = 1) -> dict:
    """Analytic per-rank bytes-on-wire for one full exchange over ``plan``.

    Host-side accounting for telemetry: the collectives themselves run
    inside jitted programs where no host code can observe them, so the
    train loop instead increments ``exchange/bytes_wire`` by this static
    per-step figure (the same modeling discipline as
    ``roofline.analysis.parse_collectives``, but from the plan rather than
    the HLO). Per rank, egress:

    - ``asa``/``ring`` RS: ``(k-1) * shard_len`` elements at the transfer
      dtype per bucket (alltoall / k-1 ppermute hops), int8 adds the
      per-row fp32 scales;
    - AG: the ``shard_len`` shard to each of the other ``k-1`` ranks — at
      the transfer dtype, or :func:`param_wire_dtype` when the gather
      carries updated *parameters* (``param_ag=True``, the RS->update->AG
      path);
    - ``ar``: the classic fused-allreduce volume ``2 (k-1)/k`` of the
      bucket at fp32;
    - small (psum'd) leaves: ``2 (k-1)/k`` of the leaf at fp32.

    ``sync_every`` > 1 (easgd/asgd tau) scales ``bytes_per_step`` down:
    the traffic only moves on averaging steps."""
    k = plan.k
    g_sz = _dtype_bytes(exchanger.transfer_dtype)
    ag_dtype = (param_wire_dtype(exchanger) if param_ag
                else exchanger.transfer_dtype)
    a_sz = _dtype_bytes(ag_dtype)
    int8_rs = exchanger.transfer_dtype == jnp.int8
    int8_ag = ag_dtype == jnp.int8
    rs_b = ag_b = 0
    per_bucket = []
    for b in plan.buckets:
        if exchanger.kind == "none":
            rs, ag = 0, 0
        elif exchanger.kind == "ar":
            half = int(2 * (k - 1) / k * b.padded * 4 / 2)
            rs, ag = half, half
        else:
            rs = (k - 1) * b.shard_len * g_sz
            if int8_rs:
                rs += (k - 1) * 4            # per-row fp32 scales
            ag = (k - 1) * b.shard_len * a_sz
            if int8_ag:
                ag += (k - 1) * 4            # one fp32 scale per shard
        rs_b += rs
        ag_b += ag
        per_bucket.append({"leaves": len(b.leaves), "padded": b.padded,
                           "rs_bytes": rs, "ag_bytes": ag})
    small_b = 0 if exchanger.kind == "none" else sum(
        int(2 * (k - 1) / k * np.prod(plan.shapes[i] or (1,)) * 4)
        for i in plan.small)
    total = rs_b + ag_b + small_b
    return {
        "strategy": exchanger.name,
        "wire_dtype": str(jnp.dtype(exchanger.transfer_dtype or jnp.float32)),
        "ag_dtype": str(jnp.dtype(ag_dtype or jnp.float32)),
        "k": k,
        "num_buckets": plan.num_buckets,
        "rs_bytes": rs_b,
        "ag_bytes": ag_b,
        "small_bytes": small_b,
        "bytes_per_exchange": total,
        "sync_every": sync_every,
        "bytes_per_step": total / max(sync_every, 1),
        "per_bucket": per_bucket,
    }


def param_wire_dtype(exchanger: Exchanger):
    """Wire format for the updated-parameter all-gather leg of the
    RS->update->AG path: the strategy's transfer dtype, except int8
    strategies gather params at fp16 (absmax-int8 on weights is too lossy
    to re-apply every step)."""
    if exchanger.transfer_dtype == jnp.int8:
        return jnp.float16
    return exchanger.transfer_dtype


def half_programs(exchanger: Exchanger, params_abs, mesh, *,
                  axis: str = "data", bucket_bytes: int = 0):
    """Standalone jitted RS / AG half programs over a ``(k, ...)`` gradient
    stack — the per-program attribution path for the exchange halves.

    The real halves run fused inside the train step, where no host code
    can lower or time them separately; these programs rebuild each half in
    isolation with the *same* plan, wire dtypes and collectives (the
    ``bench_comm`` idiom), so their ``cost_analysis`` and micro-timed
    durations attribute the step's exchange cost per half.

    Returns ``(rs_fn, ag_fn, grads_abs, shards_abs, plan)``: jitted
    callables plus abstract input stacks — lower them for cost capture,
    or materialize zeros to micro-time an execution.
    """
    if exchanger.kind == "none":
        raise ValueError("'none' exchanger has no halves to profile")
    P = jax.sharding.PartitionSpec
    k = int(mesh.shape[axis])
    plan = exchanger.plan_for(params_abs, k, bucket_bytes)

    def rs(gs):
        per = jax.tree.map(lambda v: v[0], gs)
        res, _ = exchanger.reduce_scatter(per, axis, plan=plan)
        return ([s[None] for s in res["shards"]],
                [f[None] for f in res["full"]])

    def ag(sh):
        flats = exchanger.all_gather([s[0] for s in sh], plan, axis,
                                     wire_dtype=param_wire_dtype(exchanger))
        return [f[None] for f in flats]

    def _wrap(f):
        return jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P(axis),
                                     out_specs=P(axis),
                                     axis_names=frozenset({axis}),
                                     check_vma=False))

    grads_abs = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((k, *l.shape), l.dtype), params_abs)
    shards_abs = [jax.ShapeDtypeStruct((k, b.shard_len), jnp.float32)
                  for b in plan.buckets]
    return _wrap(rs), _wrap(ag), grads_abs, shards_abs, plan
