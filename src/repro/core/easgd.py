"""Elastic Averaging SGD (paper §4; Zhang et al. 2015).

Theano-MPI re-implements Platoon's EASGD over CUDA-aware MPI SendRecv. The
TPU/SPMD adaptation keeps per-worker parameter replicas as a leading axis
sharded over the data axis; the elastic attraction to the replicated center
runs every ``tau`` steps (the averaging period) as a psum — a synchronous
clock emulation of bounded-staleness asynchrony (the paper itself equates
larger tau with larger effective batch).

Worker update :  x_i <- x_i - eta*g_i - alpha*(x_i - center)   (every tau)
Center update :  center <- center + alpha * sum_i (x_i - center)
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.registry import Model
from repro.optim.optimizers import Optimizer


def init_easgd_state(model: Model, optimizer: Optimizer, key, num_workers: int):
    params = model.init(key)
    stack = lambda p: jnp.broadcast_to(p[None], (num_workers, *p.shape))
    workers = jax.tree.map(stack, params)
    return {
        "workers": workers,
        "opt": jax.tree.map(stack, optimizer.init(params)["m"]),
        "center": params,
        "step": jnp.zeros((), jnp.int32),
    }


def make_easgd_step(model: Model, lr_fn: Callable, mesh,
                    alpha: float = 0.5, tau: int = 1,
                    momentum: float = 0.9, data_axis: str = "data"):
    """Returns ``step(state, batch, rng) -> (state, metrics)``."""

    def per_shard(state, batch, rng):
        rng = jax.random.fold_in(rng, jax.lax.axis_index(data_axis))
        w = jax.tree.map(lambda v: v[0], state["workers"])
        m = jax.tree.map(lambda v: v[0], state["opt"])
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(w, batch, rng)
        lr = lr_fn(state["step"])

        # local momentum-SGD step
        def upd(p, g, mm):
            mm_new = momentum * mm + g.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * mm_new).astype(p.dtype),
                    mm_new)
        out = jax.tree.map(upd, w, grads, m)
        is_t = lambda t: isinstance(t, tuple)
        w = jax.tree.map(lambda t: t[0], out, is_leaf=is_t)
        m = jax.tree.map(lambda t: t[1], out, is_leaf=is_t)

        # elastic averaging every tau steps
        do_avg = ((state["step"] + 1) % tau == 0).astype(jnp.float32)

        def elastic(wi, c):
            delta = alpha * (wi.astype(jnp.float32) - c.astype(jnp.float32))
            wi_new = (wi.astype(jnp.float32) - do_avg * delta).astype(wi.dtype)
            c_new = (c.astype(jnp.float32)
                     + do_avg * jax.lax.psum(delta, data_axis)).astype(c.dtype)
            return wi_new, c_new
        out = jax.tree.map(elastic, w, state["center"])
        w = jax.tree.map(lambda t: t[0], out, is_leaf=is_t)
        center = jax.tree.map(lambda t: t[1], out, is_leaf=is_t)

        metrics = jax.tree.map(lambda v: jax.lax.pmean(v, data_axis), metrics)
        new_state = {
            "workers": jax.tree.map(lambda v: v[None], w),
            "opt": jax.tree.map(lambda v: v[None], m),
            "center": center,
            "step": state["step"] + 1,
        }
        return new_state, metrics

    state_spec = {"workers": P(data_axis), "opt": P(data_axis),
                  "center": P(), "step": P()}
    return jax.shard_map(
        per_shard, mesh=mesh,
        in_specs=(state_spec, P(data_axis), P()),
        out_specs=(state_spec, P()),
        axis_names=frozenset({data_axis}),
        check_vma=False)
