"""Async training plans: EASGD (paper §4; Zhang et al. 2015) and ASGD.

Theano-MPI re-implements Platoon's EASGD over CUDA-aware MPI SendRecv. The
TPU/SPMD adaptation keeps per-worker parameter *and optimizer-state*
replicas as a leading worker axis sharded over the data axes; the elastic
attraction to the replicated center runs every ``tau`` steps (the
averaging period) — a synchronous clock emulation of bounded-staleness
asynchrony (the paper itself equates larger tau with larger effective
batch).

Promoted to first-class (engine) status:

- the per-worker descent goes through the shared :class:`Optimizer`
  interface (momentum-SGD *and* AdamW), not an inline update;
- the center traffic routes through :class:`Exchanger` — the ASA
  decomposition and fp16/int8 wire precision apply to the elastic
  exchange exactly as they do to BSP gradients;
- the state uses the engine's canonical layout (``params/opt/step`` +
  the ``center`` extra), so checkpoint save/resume is shared.

Sync-step semantics (server-style ordering: the center absorbs the worker
deltas first, workers then attract to the *updated* center — what a
Platoon worker observes after its round trip):

    delta_i = x_i - c
    c'      = c + alpha * sum_i delta_i     (exchanger: mean * k)
    x_i'    = x_i - alpha * (x_i - c')

``algo="asgd"`` is the ``alpha = 1`` point of the same scaffolding: the
center applies the full sum of worker deltas (each worker's accumulated
local updates since its last sync — staleness bounded by tau) and the
workers re-fetch the center. At ``tau = 1`` that collapses to synchronous
model averaging, which from a synced start equals BSP gradient averaging
with the learning rate scaled by ``k`` (momentum/Adam states stay local
but their mean tracks the BSP state by linearity) — the parity tested in
``tests/test_engine.py``.

The local (non-averaging) step is a *separate* function with no
param-sized collective at all — the engine dispatches sync vs local by
``step_idx % tau``, so at tau > 1 the wire really is idle between
averaging rounds (measured in ``benchmarks/bench_easgd.py``).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.exchanger import Exchanger, default_chunk_sum, norm_axes
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer


def init_async_state(model: Model, optimizer: Optimizer, key,
                     num_workers: int, *, mesh=None, data_axes=("data",)):
    """Canonical engine layout + the async extras.

    ``params``/``opt`` are per-worker replica stacks (leading worker dim of
    extent ``num_workers``, sharded over the data axes when ``mesh`` is
    given); ``center`` is the replicated center replica."""
    params = model.init(key)
    stack = lambda p: jnp.broadcast_to(p[None], (num_workers, *p.shape))
    state = {"params": jax.tree.map(stack, params),
             "opt": jax.tree.map(stack, optimizer.init(params)),
             "center": params,
             "step": jnp.zeros((), jnp.int32)}
    if mesh is not None:
        worker = NamedSharding(mesh, P(norm_axes(data_axes)))
        rep = NamedSharding(mesh, P())
        put = lambda sh: (lambda l: jax.device_put(l, sh))
        state = {"params": jax.tree.map(put(worker), state["params"]),
                 "opt": jax.tree.map(put(worker), state["opt"]),
                 "center": jax.tree.map(put(rep), state["center"]),
                 "step": jax.device_put(state["step"], rep)}
    return state


def make_async_step(model: Model, optimizer: Optimizer, exchanger: Exchanger,
                    lr_fn: Callable, mesh, *, algo: str = "easgd",
                    alpha: float = 0.5, data_axes=("data",),
                    sum_fn=default_chunk_sum, bucket_bytes: int = 0,
                    unroll: bool = False, quorum: bool = False):
    """Returns ``(local_step, sync_step)``, both un-jitted.

    Each is ``step(state, batch, rng) -> (state, metrics)``. ``local_step``
    is the pure per-worker descent (no param-sized collective);
    ``sync_step`` additionally runs the elastic exchange. The engine
    dispatches ``sync_step`` on every tau-th step.

    With ``quorum=True`` the sync step instead takes per-worker weight
    vectors — ``sync(state, batch, rng, absorb, attract)`` with ``absorb``
    and ``attract`` of shape (k,) fp32, sharded like the replica stacks —
    the elastic-fleet variant (see ``repro.fault``):

        c'   = c + sum_i absorb_i * (x_i - c)
        x_i' = x_i - attract_i * (x_i - c')

    ``absorb_i = alpha / (1 + staleness_i)`` for reporting workers (the
    staleness-scaled late-absorption rule) and 0 for non-reporting rows,
    whose params ignore the center this round. alpha is folded into the
    weights by the membership controller, so full participation at
    staleness 0 (``absorb = attract = alpha``) reproduces the fixed sync
    step exactly; ``attract_i == 1`` snaps to the center (the asgd
    re-fetch, special-cased against fp rounding)."""
    if algo not in ("easgd", "asgd"):
        raise ValueError(f"unknown async algo {algo!r}")
    if exchanger.kind == "none":
        raise ValueError("async plans need a real exchanger for the center "
                         "traffic (got 'none')")
    # asgd = the alpha=1 point: center applies the full delta sum, workers
    # re-fetch the center (tau-bounded staleness)
    a = float(alpha) if algo == "easgd" else 1.0
    axes = tuple(data_axes)
    entry = norm_axes(axes)

    def _worker_rng(rng):
        idx = jax.lax.axis_index(axes[0])
        for ax in axes[1:]:
            idx = idx * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
        return jax.random.fold_in(rng, idx)

    def local_update(state, batch, rng):
        rng = _worker_rng(rng)
        w = jax.tree.map(lambda v: v[0], state["params"])
        opt = jax.tree.map(lambda v: v[0], state["opt"])
        (_, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(w, batch, rng, unroll=unroll)
        w, opt = optimizer.update(w, grads, opt, lr_fn(state["step"]))
        metrics = jax.tree.map(lambda v: jax.lax.pmean(v, entry), metrics)
        return w, opt, metrics

    def restack(w, opt, center, step):
        return {"params": jax.tree.map(lambda v: v[None], w),
                "opt": jax.tree.map(lambda v: v[None], opt),
                "center": center, "step": step}

    def per_shard_local(state, batch, rng):
        w, opt, metrics = local_update(state, batch, rng)
        return restack(w, opt, state["center"], state["step"] + 1), metrics

    def per_shard_sync(state, batch, rng):
        w, opt, metrics = local_update(state, batch, rng)
        k = 1
        for ax in axes:
            k *= jax.lax.axis_size(ax)
        delta = jax.tree.map(
            lambda wi, c: wi.astype(jnp.float32) - c.astype(jnp.float32),
            w, state["center"])
        # the elastic exchange IS an exchanger round: ASA decomposition,
        # bucketing and fp16/int8 wire precision apply to the center traffic
        dmean = exchanger.exchange(delta, entry, sum_fn=sum_fn,
                                   bucket_bytes=bucket_bytes)
        c_new = jax.tree.map(
            lambda c, d: (c.astype(jnp.float32) + a * k * d).astype(c.dtype),
            state["center"], dmean)
        if a == 1.0:
            # exact re-fetch (w - (w - c) would round): workers snap to the
            # updated center — the asgd/model-averaging point
            w_new = jax.tree.map(lambda wi, c: c.astype(wi.dtype), w, c_new)
        else:
            w_new = jax.tree.map(
                lambda wi, c: (wi.astype(jnp.float32)
                               - a * (wi.astype(jnp.float32)
                                      - c.astype(jnp.float32))
                               ).astype(wi.dtype), w, c_new)
        return restack(w_new, opt, c_new, state["step"] + 1), metrics

    def per_shard_sync_quorum(state, batch, rng, absorb, attract):
        w, opt, metrics = local_update(state, batch, rng)
        k = 1
        for ax in axes:
            k *= jax.lax.axis_size(ax)
        wa = absorb[0].astype(jnp.float32)    # this worker's absorb weight
        at = attract[0].astype(jnp.float32)
        # weighted delta: alpha (staleness-scaled) is already folded into
        # wa, so the center update is c + sum_i wa_i * delta_i
        delta = jax.tree.map(
            lambda wi, c: wa * (wi.astype(jnp.float32)
                                - c.astype(jnp.float32)),
            w, state["center"])
        dmean = exchanger.exchange(delta, entry, sum_fn=sum_fn,
                                   bucket_bytes=bucket_bytes)
        c_new = jax.tree.map(
            lambda c, d: (c.astype(jnp.float32) + k * d).astype(c.dtype),
            state["center"], dmean)
        # attract==1 must snap exactly (w - (w - c) would round); non-
        # reporting rows (attract==0) keep their params bit-identical
        w_new = jax.tree.map(
            lambda wi, c: jnp.where(
                at == 1.0, c.astype(wi.dtype),
                jnp.where(at == 0.0, wi,
                          (wi.astype(jnp.float32)
                           - at * (wi.astype(jnp.float32)
                                   - c.astype(jnp.float32))
                           ).astype(wi.dtype))),
            w, c_new)
        return restack(w_new, opt, c_new, state["step"] + 1), metrics

    state_spec = {"params": P(entry), "opt": P(entry),
                  "center": P(), "step": P()}

    def wrap(fn, extra_in=()):
        return jax.shard_map(fn, mesh=mesh,
                             in_specs=(state_spec, P(axes), P(), *extra_in),
                             out_specs=(state_spec, P()),
                             axis_names=frozenset(axes),
                             check_vma=False)

    if quorum:
        return (wrap(per_shard_local),
                wrap(per_shard_sync_quorum, extra_in=(P(entry), P(entry))))
    return wrap(per_shard_local), wrap(per_shard_sync)


def reshard_async_state(state, old_workers, new_workers,
                        optimizer: Optimizer, *, mesh,
                        data_axes=("data",)):
    """Migrate an async state between memberships (elastic join/leave).

    ``old_workers``/``new_workers`` are ordered worker-id tuples defining
    the replica-stack row order before and after the change. Survivor rows
    carry over by id (params *and* optimizer state — a surviving worker
    keeps its momentum); joiners start at the center with a fresh
    ``optimizer.init`` row (their delta is zero, their staleness 0).
    ``center`` and ``step`` pass through unchanged.

    Host-side by design: membership changes happen at round boundaries
    (rare), and the gather/restack is O(state size) — the same cost class
    as the checkpoint save that production systems do at the same place.
    The result lands on ``mesh`` with the canonical async placement
    (stacks sharded over the data axes, center/step replicated).
    """
    import numpy as np

    k_new = len(new_workers)
    mesh_k = 1
    for a in data_axes:
        mesh_k *= int(mesh.shape[a])
    if k_new != mesh_k:
        raise ValueError(f"{k_new} workers but the new mesh has {mesh_k} "
                         f"devices over {data_axes}")
    old_index = {w: i for i, w in enumerate(old_workers)}

    center_host = jax.tree.map(np.asarray, state["center"])
    fresh_opt = jax.tree.map(np.asarray,
                             optimizer.init(state["center"]))

    def rows(stack_leaf, fill_leaf):
        host = np.asarray(stack_leaf)
        return np.stack([host[old_index[w]] if w in old_index
                         else np.asarray(fill_leaf)
                         for w in new_workers])

    new_params = jax.tree.map(rows, state["params"], center_host)
    new_opt = jax.tree.map(rows, state["opt"], fresh_opt)

    worker = NamedSharding(mesh, P(norm_axes(tuple(data_axes))))
    rep = NamedSharding(mesh, P())
    put = lambda sh: (lambda l: jax.device_put(l, sh))
    return {"params": jax.tree.map(put(worker), new_params),
            "opt": jax.tree.map(put(worker), new_opt),
            "center": jax.tree.map(put(rep), center_host),
            "step": jax.device_put(np.asarray(state["step"]), rep)}
