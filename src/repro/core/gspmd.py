"""GSPMD / FSDP train step — the production path for architectures whose
replicated-over-data parameters cannot fit a v5e chip (>= ~34B here).

The paper's pure data-parallel exchange assumes replicated parameters. At
123B that is memory-infeasible, so we layer the paper's own decomposition
(Alltoall-sum-Allgather == reduce-scatter + all-gather) into the optimizer:

- ``mode='ar'``    : gradients all-reduced by GSPMD (paper's AR baseline,
                     optimizer state replicated over data)
- ``mode='zero1'`` : **ZeRO-1 via the ASA decomposition** — gradients
                     reduce-scattered over the data axis, each data-rank
                     updates its 1/k optimizer-state shard, updated params
                     all-gathered. Structurally identical to the paper's
                     ASA with the descent step fused between the two legs.

Implemented declaratively: parameters/optimizer state get 'data' added to
their PartitionSpec (FSDP), and GSPMD lowers the gradient reduction to
reduce-scatter + the forward gathers — exactly the ASA collective schedule.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import MODEL_AXIS, dp_axes_of, param_spec
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer


def fsdp_param_spec(path, leaf, mesh: Mesh) -> P:
    """param_spec + 'data' on the first dimension not taken by 'model'.

    Stacked-layer leaves (leading L dim) shard L over data when divisible,
    else the next free dim."""
    from repro.dist.sharding import sanitize_spec
    base = list(sanitize_spec(param_spec(path, leaf), leaf.shape, mesh))
    base = base + [None] * (leaf.ndim - len(base))
    dp = dp_axes_of(mesh)
    kdp = 1
    for a in dp:
        kdp *= mesh.shape[a]
    # choose the largest free dim (prefer exact divisibility)
    cands = [i for i in range(leaf.ndim) if base[i] is None]
    if not cands:
        return P(*base)
    div = [i for i in cands if leaf.shape[i] % kdp == 0]
    pick = max(div or cands, key=lambda i: leaf.shape[i])
    if leaf.shape[pick] < kdp and not div:
        return P(*base)  # too small to shard
    base[pick] = dp if len(dp) > 1 else dp[0]
    return P(*base)


def fsdp_shardings(mesh: Mesh, tree):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, fsdp_param_spec(path, leaf,
                                                               mesh)), tree)


def fsdp_state_shardings(mesh: Mesh, state):
    opt_sh = {}
    for k, v in state["opt"].items():
        opt_sh[k] = (fsdp_shardings(mesh, v) if k in ("m", "v")
                     else NamedSharding(mesh, P()))
    return {"params": fsdp_shardings(mesh, state["params"]),
            "opt": opt_sh,
            "step": NamedSharding(mesh, P())}


def make_gspmd_step(model: Model, optimizer: Optimizer, lr_fn: Callable,
                    mesh: Mesh, *, mode: str = "zero1",
                    unroll: bool = False):
    """Plain (non-shard_map) step; sharding via in_shardings + constraints.

    mode='zero1': grads constrained to the FSDP spec => GSPMD emits
    reduce-scatter for the gradient reduction (ASA leg 1) and all-gathers
    parameters for the next forward (ASA leg 2).
    mode='ar': grads constrained replicated => all-reduce (paper baseline).
    """

    def step(state, batch, rng):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(state["params"], batch, rng,
                                         unroll=unroll)
        if mode == "zero1":
            # reduce-scatter the gradients (ASA leg 1, fused with update)
            grads = jax.tree_util.tree_map_with_path(
                lambda path, g: jax.lax.with_sharding_constraint(
                    g, fsdp_param_spec(path, g, mesh)), grads)
        lr = lr_fn(state["step"])
        new_params, new_opt = optimizer.update(state["params"], grads,
                                               state["opt"], lr)
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return step
