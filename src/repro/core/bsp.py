"""BSP synchronous data-parallel training (paper §3.1, §4).

Builds a jitted train step that runs under ``jax.shard_map`` with the data
(and pod) axes *manual* — so the configured Exchanger's collectives are the
literal HLO collectives — and any model-parallel axes left to GSPMD.

Both of the paper's parallel-SGD schemes are supported:

- ``subgd``: sum/mean gradients across workers BEFORE the descent step
  (the paper notes this needs no LR rescaling);
- ``awagd``: each worker descends on its local gradient, then weights AND
  momentum are averaged (Krizhevsky's scheme; LR scales with k).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.exchanger import Exchanger, default_chunk_sum
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer


def init_train_state(model: Model, optimizer: Optimizer, key):
    params = model.init(key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _norm_axes(data_axes):
    axes = tuple(data_axes)
    return axes[0] if len(axes) == 1 else axes


def make_bsp_step(model: Model, optimizer: Optimizer, exchanger: Exchanger,
                  lr_fn: Callable, mesh, data_axes=("data",),
                  scheme: str = "subgd", sum_fn=default_chunk_sum,
                  unroll: bool = False, microbatches: int = 1,
                  bucket_bytes: int = 0):
    """Returns ``step(state, batch, rng) -> (state, metrics)`` (un-jitted).

    ``microbatches`` > 1 splits the local batch and accumulates gradients
    over a ``lax.scan`` (activation-memory reduction; the exchange then
    amortizes over the whole accumulated gradient — the regime the paper's
    §3.2 'overlap with backprop' remark targets).
    """
    axes = _norm_axes(data_axes)

    def grad_of(params, batch, rng):
        if microbatches <= 1:
            return jax.value_and_grad(model.loss_fn, has_aux=True)(
                params, batch, rng, unroll=unroll)

        def split(v):
            return v.reshape(microbatches, v.shape[0] // microbatches,
                             *v.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(carry, mbatch):
            acc, loss_sum, aux_sum = carry
            (loss, metrics), g = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, mbatch, rng,
                                             unroll=unroll)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                               acc, g)
            return (acc, loss_sum + loss, aux_sum + metrics["aux"]), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (acc, loss_sum, aux_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32),
                   jnp.zeros((), jnp.float32)), mb)
        m = float(microbatches)
        grads = jax.tree.map(lambda a: a / m, acc)
        return (loss_sum / m, {"loss": loss_sum / m, "aux": aux_sum / m}), grads

    def per_shard(state, batch, rng):
        rng = jax.random.fold_in(rng, jax.lax.axis_index(data_axes[0]))
        (loss, metrics), grads = grad_of(state["params"], batch, rng)
        lr = lr_fn(state["step"])
        if scheme == "subgd":
            grads = exchanger.exchange(grads, axes, sum_fn=sum_fn,
                                       bucket_bytes=bucket_bytes)
            new_params, new_opt = optimizer.update(
                state["params"], grads, state["opt"], lr)
        elif scheme == "awagd":
            new_params, new_opt = optimizer.update(
                state["params"], grads, state["opt"], lr)
            # average weights AND momentum after the descent step ([7], [15])
            new_params = exchanger.exchange(new_params, axes, sum_fn=sum_fn)
            new_opt = exchanger.exchange(new_opt, axes, sum_fn=sum_fn)
        else:
            raise ValueError(f"unknown scheme {scheme!r}")
        metrics = jax.tree.map(lambda v: jax.lax.pmean(v, axes), metrics)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    batch_spec = P(data_axes)
    step = jax.shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(), batch_spec, P()),
        out_specs=(P(), P()),
        axis_names=frozenset(data_axes),
        check_vma=False)
    return step


def make_loss_grad_step(model: Model, exchanger: Exchanger, mesh,
                        data_axes=("data",), sum_fn=default_chunk_sum):
    """Exchange-only step (gradient computation + exchange, no update) —
    used by the communication benchmarks to isolate exchange cost."""
    axes = _norm_axes(data_axes)

    def per_shard(params, batch, rng):
        (_, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch, rng)
        return exchanger.exchange(grads, axes, sum_fn=sum_fn)

    return jax.shard_map(per_shard, mesh=mesh,
                         in_specs=(P(), P(data_axes), P()),
                         out_specs=P(),
                         axis_names=frozenset(data_axes),
                         check_vma=False)
