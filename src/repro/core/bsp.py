"""BSP synchronous data-parallel training (paper §3.1, §4).

Builds a jitted train step that runs under ``jax.shard_map`` with the data
(and pod) axes *manual* — so the configured Exchanger's collectives are the
literal HLO collectives — and any model-parallel axes left to GSPMD.

Both of the paper's parallel-SGD schemes are supported:

- ``subgd``: sum/mean gradients across workers BEFORE the descent step
  (the paper notes this needs no LR rescaling);
- ``awagd``: each worker descends on its local gradient, then weights AND
  momentum are averaged (Krizhevsky's scheme; LR scales with k).

Beyond the paper, ``subgd`` has a ZeRO-1-style **sharded fused update**
path (``sharded_update=True``): the exchange is split into its
reduce-scatter / all-gather halves and the optimizer updates only the
local 1/k shard between them (RS -> update -> AG). The full reduced
gradient is never materialized, optimizer state lives sharded over the
data axis (1/k memory), and the wire precision applies to both directions
— gradients in, updated parameters out. With ``overlap="buckets"`` the
microbatch ``lax.scan`` double-buffers: microbatch *i-1*'s bucket
reduce-scatters are issued while microbatch *i*'s backprop runs, so the
latency-hiding scheduler can overlap exchange with compute (the paper's
§3.2 remark); each bucket's sharded update is dispatched independently so
updates and parameter all-gathers interleave too. Note the tradeoff:
overlap exchanges every microbatch's gradient separately (m× wire volume,
hidden behind backprop) while the serialized path exchanges the
accumulated gradient once.
"""
from __future__ import annotations

from math import prod
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.exchanger import (Exchanger, RSPlan, default_chunk_sum,
                                  make_rs_plan, norm_axes, param_wire_dtype)
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer


def init_train_state(model: Model, optimizer: Optimizer, key):
    params = model.init(key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _model_plan(model: Model, mesh, data_axes, bucket_bytes: int) -> RSPlan:
    """The (deterministic) bucket plan shared by init and the step."""
    params_abs = jax.eval_shape(model.init, jax.random.key(0))
    k = int(mesh.shape[data_axes[-1]])
    return make_rs_plan(params_abs, k, bucket_bytes)


def init_sharded_train_state(model: Model, optimizer: Optimizer, key, mesh,
                             data_axes=("data",), bucket_bytes: int = 0):
    """Train state for the RS->update->AG path.

    Optimizer state lives as flat per-bucket arrays sharded over the last
    data axis (global extent ``k * shard_len``; each rank materializes
    1/k), alongside the fp32 **master** parameter shard (``"master"``).
    Updates accumulate in the master — ``state["params"]`` is the compute
    copy rebuilt from the wire-dtype all-gather each step, so fp16/int8
    gather rounding never feeds back into the update (sub-ulp updates
    still accumulate, the standard ZeRO-1 master-weights discipline).
    Small psum'd leaves keep replicated flat state and update
    ``params`` directly at fp32."""
    if optimizer.flat_init is None:
        raise ValueError(f"optimizer {optimizer.name!r} has no flat/sharded "
                         "update support (flat_init/flat_update)")
    params = model.init(key)
    plan = _model_plan(model, mesh, data_axes, bucket_bytes)
    ax = data_axes[-1]
    shard = NamedSharding(mesh, P(ax))

    def bucket_state(b):
        # jit with out_shardings so each rank only ever allocates its own
        # 1/k shard — a host-side flat_init would materialize the full
        # (k*shard_len,) state exactly where the ZeRO-1 memory matters
        abs_st = jax.eval_shape(lambda: optimizer.flat_init(b.padded))
        sh = jax.tree.map(
            lambda l: shard if (len(l.shape) == 1 and l.shape[0] == b.padded)
            else NamedSharding(mesh, P()), abs_st)
        return jax.jit(lambda: optimizer.flat_init(b.padded),
                       out_shardings=sh)()

    master = ([] if not plan.buckets else
              jax.jit(lambda ps: Exchanger.pack(ps, plan)[0],
                      out_shardings=[shard] * plan.num_buckets)(params))
    opt = {"buckets": [bucket_state(b) for b in plan.buckets],
           "small": [optimizer.flat_init(prod(plan.shapes[i]))
                     for i in plan.small],
           "master": master}
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def _sharded_state_specs(optimizer: Optimizer, plan: RSPlan, ax: str):
    """in/out spec tree: params/step/small-leaf state replicated, per-bucket
    flat state and fp32 master shards split over the rs axis (the (k*s,)
    arrays; scalars like adamw's ``t`` stay replicated)."""
    def bucket_spec(b):
        st = jax.eval_shape(lambda: optimizer.flat_init(b.padded))
        return jax.tree.map(
            lambda l: P(ax) if (len(l.shape) == 1 and l.shape[0] == b.padded)
            else P(), st)

    return {"params": P(),
            "opt": {"buckets": [bucket_spec(b) for b in plan.buckets],
                    "small": P(),
                    "master": [P(ax) for _ in plan.buckets]},
            "step": P()}


def make_bsp_step(model: Model, optimizer: Optimizer, exchanger: Exchanger,
                  lr_fn: Callable, mesh, data_axes=("data",),
                  scheme: str = "subgd", sum_fn=default_chunk_sum,
                  unroll: bool = False, microbatches: int = 1,
                  bucket_bytes: int = 0, sharded_update: bool = False,
                  overlap: str | None = None, fuse_rs_update=None,
                  grad_norm: bool = False):
    """Returns ``step(state, batch, rng) -> (state, metrics)`` (un-jitted).

    ``microbatches`` > 1 splits the local batch and accumulates gradients
    over a ``lax.scan`` (activation-memory reduction; the exchange then
    amortizes over the whole accumulated gradient — the regime the paper's
    §3.2 'overlap with backprop' remark targets).

    ``sharded_update=True`` (subgd only) takes the RS->update->AG path;
    the state must come from :func:`init_sharded_train_state` with the
    same ``bucket_bytes``. ``overlap="buckets"`` additionally
    double-buffers the microbatch scan (see module docstring); it implies
    ``sharded_update`` and needs ``microbatches >= 2`` to overlap
    anything. ``fuse_rs_update`` selects the Pallas fused
    dequant+sum+update kernel on the raw alltoall receives (needs a
    single-axis asa-family strategy and an optimizer with
    ``rs_fused_update``; None = auto: on when kernels run compiled — TPU —
    off in interpreter mode where the jnp flat update is faster).

    ``grad_norm=True`` adds the post-exchange global gradient norm to the
    step metrics — the telemetry layer's single *in-graph* opt-in (it adds
    reductions to the compiled step, so it is off by default and gated by
    ``REPRO_TELEMETRY_GRADNORM``; non-sharded paths only, where the full
    reduced gradient exists to be normed)."""
    if overlap not in (None, "buckets"):
        raise ValueError(f"unknown overlap mode {overlap!r}")
    if overlap:
        sharded_update = True
    if sharded_update and scheme != "subgd":
        raise ValueError("sharded_update requires scheme='subgd' "
                         "(awagd updates on the local gradient)")
    axes = norm_axes(data_axes)
    ax_rs = data_axes[-1]

    def grad_of(params, batch, rng):
        if microbatches <= 1:
            return jax.value_and_grad(model.loss_fn, has_aux=True)(
                params, batch, rng, unroll=unroll)

        def split(v):
            return v.reshape(microbatches, v.shape[0] // microbatches,
                             *v.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(carry, mbatch):
            acc, loss_sum, aux_sum = carry
            (loss, metrics), g = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, mbatch, rng,
                                             unroll=unroll)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                               acc, g)
            return (acc, loss_sum + loss, aux_sum + metrics["aux"]), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (acc, loss_sum, aux_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32),
                   jnp.zeros((), jnp.float32)), mb)
        m = float(microbatches)
        grads = jax.tree.map(lambda a: a / m, acc)
        return (loss_sum / m, {"loss": loss_sum / m, "aux": aux_sum / m}), grads

    if not sharded_update:
        def per_shard(state, batch, rng):
            rng = jax.random.fold_in(rng, jax.lax.axis_index(data_axes[0]))
            (loss, metrics), grads = grad_of(state["params"], batch, rng)
            lr = lr_fn(state["step"])
            if scheme == "subgd":
                grads = exchanger.exchange(grads, axes, sum_fn=sum_fn,
                                           bucket_bytes=bucket_bytes)
                new_params, new_opt = optimizer.update(
                    state["params"], grads, state["opt"], lr)
            elif scheme == "awagd":
                new_params, new_opt = optimizer.update(
                    state["params"], grads, state["opt"], lr)
                # average weights AND momentum after the descent step
                # ([7], [15]) — with the same bucketing as the gradients
                new_params = exchanger.exchange(new_params, axes,
                                                sum_fn=sum_fn,
                                                bucket_bytes=bucket_bytes)
                new_opt = exchanger.exchange(new_opt, axes, sum_fn=sum_fn,
                                             bucket_bytes=bucket_bytes)
            else:
                raise ValueError(f"unknown scheme {scheme!r}")
            metrics = jax.tree.map(lambda v: jax.lax.pmean(v, axes), metrics)
            if grad_norm:
                # subgd: grads here are the post-exchange global mean
                # (identical on every rank); awagd: the local gradient —
                # the pmean reports the worker average
                sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads))
                metrics["grad_norm"] = jnp.sqrt(
                    jax.lax.pmean(sq, axes))
            new_state = {"params": new_params, "opt": new_opt,
                         "step": state["step"] + 1}
            return new_state, metrics

        state_specs = P()
    else:
        if optimizer.flat_update is None or optimizer.flat_init is None:
            raise ValueError(f"optimizer {optimizer.name!r} has no "
                             "flat_init/flat_update; cannot shard the "
                             "update")
        plan = _model_plan(model, mesh, data_axes, bucket_bytes)
        raw_ok = (exchanger.supports_raw and not isinstance(axes, tuple)
                  and optimizer.rs_fused_update is not None)
        if fuse_rs_update is None:
            # auto: the fused kernel only pays off compiled; in Pallas
            # interpreter mode (CPU hosts) the jnp flat_update path wins
            from repro.kernels import default_interpret
            use_raw = raw_ok and not default_interpret()
        else:
            use_raw = bool(fuse_rs_update)
        if use_raw and not raw_ok:
            raise ValueError(
                f"fuse_rs_update needs a single-axis alltoall strategy and "
                f"an optimizer with rs_fused_update (got {exchanger.name!r}"
                f" / {optimizer.name!r})")
        nb = plan.num_buckets

        def shard_wd_mask(b, start):
            # 1.0 where the element's original leaf is >=2-D (weight decay
            # applies). Built O(shard_len) from the static leaf boundaries
            # — materializing the full bucket mask just to slice 1/k of it
            # would add O(model) traffic to the memory-saving path.
            pos = start + jnp.arange(b.shard_len)
            mask = jnp.zeros((b.shard_len,), jnp.float32)
            off = 0
            for i, n in zip(b.leaves, b.sizes):
                if len(plan.shapes[i]) > 1:
                    mask = mask + ((pos >= off) & (pos < off + n)).astype(
                        jnp.float32)
                off += n
            return mask

        def rs_accum(grads):
            """RS one microbatch's grads to fp32 accumulables."""
            res, _ = exchanger.reduce_scatter(grads, axes, sum_fn=sum_fn,
                                              plan=plan, raw=use_raw)
            if use_raw:
                ch, sc = res["chunks"], res["scales"]
                if sc:   # int8 wire: dequant before accumulating
                    ch = [c.astype(jnp.float32) * s for c, s in zip(ch, sc)]
                else:
                    ch = [c.astype(jnp.float32) for c in ch]
                return ch, res["full"]
            return res["shards"], res["full"]

        def per_shard(state, batch, rng):
            rng = jax.random.fold_in(rng, jax.lax.axis_index(data_axes[0]))
            params = state["params"]
            lr = lr_fn(state["step"])
            idx = jax.lax.axis_index(ax_rs)

            if overlap == "buckets" and microbatches > 1:
                def split(v):
                    return v.reshape(microbatches,
                                     v.shape[0] // microbatches, *v.shape[1:])
                mb = jax.tree.map(split, batch)
                mb0 = jax.tree.map(lambda v: v[0], mb)
                rest = jax.tree.map(lambda v: v[1:], mb)

                def one_grad(mbatch):
                    return jax.value_and_grad(model.loss_fn, has_aux=True)(
                        params, mbatch, rng, unroll=unroll)

                (l0, met0), g0 = one_grad(mb0)
                acc0 = [jnp.zeros((plan.k, b.shard_len) if use_raw
                                  else (b.shard_len,), jnp.float32)
                        for b in plan.buckets]
                accf0 = [jnp.zeros(plan.shapes[i], jnp.float32)
                         for i in plan.small]

                def body(carry, mbatch):
                    acc, accf, pending, loss_s, aux_s = carry
                    # the RS of the PREVIOUS microbatch is issued first and
                    # is data-independent of THIS microbatch's grads: the
                    # scheduler overlaps the collective with the backward
                    # dots that follow it in the loop body
                    sh, fl = rs_accum(pending)
                    (l, met), g = one_grad(mbatch)
                    acc = [a + s for a, s in zip(acc, sh)]
                    accf = [a + f for a, f in zip(accf, fl)]
                    return (acc, accf, g, loss_s + l,
                            aux_s + met["aux"]), None

                carry, _ = jax.lax.scan(
                    body, (acc0, accf0, g0, l0, met0["aux"]), rest)
                acc, accf, pending, loss_s, aux_s = carry
                sh, fl = rs_accum(pending)         # last microbatch: exposed
                acc = [a + s for a, s in zip(acc, sh)]
                accf = [a + f for a, f in zip(accf, fl)]
                m = float(microbatches)
                loss = loss_s / m
                metrics = {"loss": loss, "aux": aux_s / m}
                fulls = [a / m for a in accf]
                if use_raw:
                    chunks, scales = acc, [None] * nb
                    scale = 1.0 / (plan.k * m)
                else:
                    shards = [a / m for a in acc]
            else:
                (loss, metrics), grads = grad_of(params, batch, rng)
                res, _ = exchanger.reduce_scatter(grads, axes, sum_fn=sum_fn,
                                                  plan=plan, raw=use_raw)
                fulls = res["full"]
                if use_raw:
                    chunks = res["chunks"]
                    scales = res["scales"] or [None] * nb
                    scale = 1.0 / plan.k
                else:
                    shards = res["shards"]

            p_leaves = jax.tree.flatten(params)[0]
            p_smalls = [p_leaves[i] for i in plan.small]
            wire = param_wire_dtype(exchanger)
            new_flats, new_bstates, new_master = [], [], []
            for bi, b in enumerate(plan.buckets):
                # the fp32 master shard is persistent state: updates
                # accumulate there, and only the compute copy goes through
                # the (possibly lossy) wire-dtype all-gather
                p_sh = state["opt"]["master"][bi]
                mask_sh = shard_wd_mask(b, idx * b.shard_len)
                st = state["opt"]["buckets"][bi]
                if use_raw:
                    p_new, st_new = optimizer.rs_fused_update(
                        chunks[bi], p_sh, st, lr, mask_sh, scale,
                        scales[bi])
                else:
                    p_new, st_new = optimizer.flat_update(
                        p_sh, shards[bi], st, lr, mask_sh)
                new_bstates.append(st_new)
                new_master.append(p_new)
                # per-bucket dispatch: each AG depends only on its bucket's
                # update, so gathers and updates interleave
                new_flats.append(exchanger.all_gather(
                    [p_new], plan, axes, wire_dtype=wire)[0])
            new_smalls, new_sstates = [], []
            for si, i in enumerate(plan.small):
                p_fl = p_smalls[si].reshape(-1).astype(jnp.float32)
                mask = (jnp.ones_like(p_fl) if len(plan.shapes[i]) > 1
                        else None)
                p_new, st_new = optimizer.flat_update(
                    p_fl, fulls[si].reshape(-1), state["opt"]["small"][si],
                    lr, mask)
                new_smalls.append(p_new)
                new_sstates.append(st_new)
            new_params = Exchanger.unpack(new_flats, new_smalls, plan)
            metrics = jax.tree.map(lambda v: jax.lax.pmean(v, axes), metrics)
            new_state = {"params": new_params,
                         "opt": {"buckets": new_bstates,
                                 "small": new_sstates,
                                 "master": new_master},
                         "step": state["step"] + 1}
            return new_state, metrics

        state_specs = _sharded_state_specs(optimizer, plan, ax_rs)

    batch_spec = P(data_axes)
    step = jax.shard_map(
        per_shard, mesh=mesh,
        in_specs=(state_specs, batch_spec, P()),
        out_specs=(state_specs, P()),
        axis_names=frozenset(data_axes),
        check_vma=False)
    return step


def make_loss_grad_step(model: Model, exchanger: Exchanger, mesh,
                        data_axes=("data",), sum_fn=default_chunk_sum):
    """Exchange-only step (gradient computation + exchange, no update) —
    used by the communication benchmarks to isolate exchange cost."""
    axes = norm_axes(data_axes)

    def per_shard(params, batch, rng):
        (_, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch, rng)
        return exchanger.exchange(grads, axes, sum_fn=sum_fn)

    return jax.shard_map(per_shard, mesh=mesh,
                         in_specs=(P(), P(data_axes), P()),
                         out_specs=P(),
                         axis_names=frozenset(data_axes),
                         check_vma=False)
