"""Decoder-only LM executor: dense / MoE / SSM / hybrid blocks.

Layers with identical parameter structure are stacked and scanned
(``lax.scan`` over the leading layer axis, rematerialized); heterogeneous
layer kinds (e.g. DeepSeek's first dense layer + MoE rest) are grouped into
consecutive homogeneous *segments*, each with its own stack.

Supports:
- train/prefill forward (full sequence) -> logits (+ MoE aux loss)
- one-token decode against a KV/SSM cache (``init_cache`` / ``decode_step``)
- early-fusion VLM inputs (precomputed image-patch embeddings, stub frontend)
- Hymba meta tokens (learnable prefix) and per-layer global/sliding windows
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import act
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (dense_init, dtype_of, embed_init, rms_norm,
                                 softmax_xent)
from repro.models.mlp import init_mlp, mlp_forward
from repro.models.moe import init_moe, moe_forward


def _unroll_of(unroll, count: int) -> int:
    """unroll: False/0->1 (scan), True->full, int n->min(n, count).

    The dry-run compiles with unroll=1 and unroll=2 and extrapolates
    per-layer costs (scan bodies are costed once by XLA)."""
    if unroll is True:
        return count
    u = int(unroll)
    if count % max(u, 1):
        # keep trip count integral: fall back to 1
        return count if u >= count else 1 if u <= 1 else (u if count % u == 0 else 1)
    return max(1, min(u, count))


# ---------------------------------------------------------------------------
# layer layout
# ---------------------------------------------------------------------------

def layer_kinds(cfg: ArchConfig) -> list[str]:
    """Per-layer kind: 'dense' | 'moe' | 'ssm' | 'hybrid'."""
    kinds = []
    for i in range(cfg.num_layers):
        if cfg.block == "ssm":
            kinds.append("ssm")
        elif cfg.block == "hybrid":
            kinds.append("hybrid")
        elif cfg.moe is not None:
            m = cfg.moe
            if i < m.first_k_dense or ((i - m.first_k_dense) % m.moe_every) != 0:
                kinds.append("dense")
            else:
                kinds.append("moe")
        else:
            kinds.append("dense")
    return kinds


def layer_windows(cfg: ArchConfig, shape_kind: str, seq_len: int) -> list[int]:
    """Static per-layer attention window (0 = full causal)."""
    a = cfg.attention
    wins = []
    for i in range(cfg.num_layers):
        w = a.sliding_window if a else 0
        if cfg.global_attn_every:
            is_global = (i % cfg.global_attn_every == 0) or i == cfg.num_layers - 1
            w = 0 if is_global else (a.sliding_window or 1024)
        # long-context shapes force a window on full-attention layers
        if seq_len > 100_000 and cfg.long_context_window and w == 0:
            w = cfg.long_context_window
        wins.append(w)
    return wins


def segments(cfg: ArchConfig) -> list[tuple[str, int]]:
    """Group consecutive identical kinds -> [(kind, count), ...]."""
    segs: list[tuple[str, int]] = []
    for k in layer_kinds(cfg):
        if segs and segs[-1][0] == k:
            segs[-1] = (k, segs[-1][1] + 1)
        else:
            segs.append((k, 1))
    return segs


# ---------------------------------------------------------------------------
# single-layer init/apply
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, kind: str, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": jnp.zeros((d,), jnp.float32)}
    if kind in ("dense", "moe", "hybrid"):
        p["attn"] = attn_mod.init_attention(ks[0], cfg, dtype)
    if kind in ("ssm", "hybrid"):
        p["ssm"] = ssm_mod.init_ssm(ks[1], d, cfg.ssm, dtype)
    if kind == "hybrid":
        p["fuse_na"] = jnp.zeros((d,), jnp.float32)
        p["fuse_ns"] = jnp.zeros((d,), jnp.float32)
    if kind == "dense":
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, dtype)
    elif kind == "moe":
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        p["moe"] = init_moe(ks[2], d, cfg.moe, dtype)
    elif kind == "hybrid" and cfg.d_ff:
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, dtype)
    return p


def _apply_layer(p, x, positions, cfg: ArchConfig, kind: str, window,
                 attn_impl=None):
    """Full-sequence layer application. Returns (x, aux)."""
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], eps)
    if kind == "dense" or kind == "moe":
        x = x + attn_mod.attn_forward(p["attn"], h, positions, cfg, window,
                                      impl=attn_impl)
    elif kind == "ssm":
        x = x + ssm_mod.ssm_forward(p["ssm"], h, cfg.d_model, cfg.ssm, eps)
    elif kind == "hybrid":
        ya = attn_mod.attn_forward(p["attn"], h, positions, cfg, window,
                                   impl=attn_impl)
        ys = ssm_mod.ssm_forward(p["ssm"], h, cfg.d_model, cfg.ssm, eps)
        x = x + 0.5 * (rms_norm(ya, p["fuse_na"], eps)
                       + rms_norm(ys, p["fuse_ns"], eps))
    if "mlp" in p:
        x = x + mlp_forward(p["mlp"], rms_norm(x, p["ln2"], eps))
    elif "moe" in p:
        y, a = moe_forward(p["moe"], rms_norm(x, p["ln2"], eps), cfg.moe)
        x = x + y
        aux = aux + a
    return x, aux


def _decode_layer(p, cache, x, pos, cfg: ArchConfig, kind: str, window,
                  attn_impl=None, tables=None, page_size=0):
    eps = cfg.norm_eps
    h = rms_norm(x, p["ln1"], eps)
    new_cache = {}
    if kind in ("dense", "moe"):
        y, new_cache["attn"] = attn_mod.attn_decode(
            p["attn"], cache["attn"], h, pos, cfg, window, impl=attn_impl,
            tables=tables, page_size=page_size)
        x = x + y
    elif kind == "ssm":
        y, new_cache["ssm"] = ssm_mod.ssm_decode(
            p["ssm"], cache["ssm"], h, cfg.d_model, cfg.ssm, eps)
        x = x + y
    elif kind == "hybrid":
        ya, new_cache["attn"] = attn_mod.attn_decode(
            p["attn"], cache["attn"], h, pos, cfg, window, impl=attn_impl,
            tables=tables, page_size=page_size)
        ys, new_cache["ssm"] = ssm_mod.ssm_decode(
            p["ssm"], cache["ssm"], h, cfg.d_model, cfg.ssm, eps)
        x = x + 0.5 * (rms_norm(ya, p["fuse_na"], eps)
                       + rms_norm(ys, p["fuse_ns"], eps))
    if "mlp" in p:
        x = x + mlp_forward(p["mlp"], rms_norm(x, p["ln2"], eps))
    elif "moe" in p:
        # full capacity: decode routing must be drop-free so each slot's
        # output is independent of what the other slots are decoding (the
        # serving engine's bit-exactness contract under churn)
        y, _ = moe_forward(p["moe"], rms_norm(x, p["ln2"], eps), cfg.moe,
                           full_capacity=True)
        x = x + y
    return x, new_cache


def _prefill_layer(p, cache, x, positions, pos0, valid_count, valid_flat,
                   cfg: ArchConfig, kind: str, window, attn_impl=None,
                   tables=None, page_size=0):
    """Whole-chunk layer application that also writes the layer cache.

    x: (B,C,d); positions (B,C) absolute; pos0 scalar chunk start;
    valid_count scalar <= C (same for every batch row); valid_flat (B*C,)
    bool marks real (non-pad) tokens."""
    eps = cfg.norm_eps
    h = rms_norm(x, p["ln1"], eps)
    new_cache = {}
    if kind in ("dense", "moe"):
        y, new_cache["attn"] = attn_mod.attn_prefill(
            p["attn"], cache["attn"], h, positions, pos0, cfg, window,
            impl=attn_impl, tables=tables, page_size=page_size)
        x = x + y
    elif kind == "ssm":
        y, new_cache["ssm"] = ssm_mod.ssm_prefill(
            p["ssm"], cache["ssm"], h, valid_count, cfg.d_model,
            cfg.ssm, eps)
        x = x + y
    elif kind == "hybrid":
        ya, new_cache["attn"] = attn_mod.attn_prefill(
            p["attn"], cache["attn"], h, positions, pos0, cfg, window,
            impl=attn_impl, tables=tables, page_size=page_size)
        ys, new_cache["ssm"] = ssm_mod.ssm_prefill(
            p["ssm"], cache["ssm"], h, valid_count, cfg.d_model,
            cfg.ssm, eps)
        x = x + 0.5 * (rms_norm(ya, p["fuse_na"], eps)
                       + rms_norm(ys, p["fuse_ns"], eps))
    if "mlp" in p:
        x = x + mlp_forward(p["mlp"], rms_norm(x, p["ln2"], eps))
    elif "moe" in p:
        y, _ = moe_forward(p["moe"], rms_norm(x, p["ln2"], eps), cfg.moe,
                           full_capacity=True, valid=valid_flat)
        x = x + y
    return x, new_cache


def _init_layer_cache(batch: int, max_len: int, cfg: ArchConfig, kind: str,
                      dtype):
    c = {}
    if kind in ("dense", "moe", "hybrid"):
        c["attn"] = attn_mod.attn_init_cache(batch, max_len, cfg, dtype)
    if kind in ("ssm", "hybrid"):
        c["ssm"] = ssm_mod.ssm_init_cache(batch, cfg.d_model, cfg.ssm, dtype)
    return c


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_decoder(key, cfg: ArchConfig):
    dtype = dtype_of(cfg.param_dtype)
    kemb, khead, kblocks, kmeta = jax.random.split(key, 4)
    params: dict = {
        "embed": embed_init(kemb, cfg.vocab_size, cfg.d_model, dtype),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(khead, cfg.d_model, (cfg.vocab_size,),
                                    dtype)
    if cfg.num_meta_tokens:
        params["meta"] = (jax.random.normal(
            kmeta, (cfg.num_meta_tokens, cfg.d_model), jnp.float32)
            * 0.02).astype(dtype)

    segs = segments(cfg)
    blocks = []
    lkeys = jax.random.split(kblocks, cfg.num_layers)
    li = 0
    for kind, count in segs:
        seg_keys = jnp.stack(lkeys[li:li + count])
        li += count
        stacked = jax.vmap(
            lambda k: _init_layer(k, cfg, kind, dtype))(seg_keys)
        blocks.append(stacked)
    params["blocks"] = blocks
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg: ArchConfig, dtype):
    """Token embedding + early fusion + meta tokens. Returns (h, positions)."""
    tokens = batch["tokens"]
    h = params["embed"][tokens].astype(dtype)
    if cfg.modality == "vlm" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(dtype)       # (B, n_img, d)
        h = jnp.concatenate([img, h], axis=1)
    if cfg.num_meta_tokens:
        meta = jnp.broadcast_to(
            params["meta"].astype(dtype)[None],
            (h.shape[0], cfg.num_meta_tokens, cfg.d_model))
        h = jnp.concatenate([meta, h], axis=1)
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return h, positions


def decoder_forward(params, batch, cfg: ArchConfig, *, unroll: bool = False):
    """batch: {tokens:(B,St) [, image_embeds:(B,Ni,d)]}. Returns (logits, aux).

    logits cover only the token positions (meta/image prefixes stripped)."""
    dtype = dtype_of(cfg.dtype)
    h, positions = _embed_inputs(params, batch, cfg, dtype)
    wins = layer_windows(cfg, "train", h.shape[1])
    kinds = layer_kinds(cfg)
    segs = segments(cfg)
    # resolve the attention implementation once per forward (env/config/
    # backend dispatch happens here, not per layer inside the scan body)
    attn_impl = (attn_mod.resolve_attn_impl(cfg.attention)
                 if cfg.attention is not None else None)

    aux_total = jnp.zeros((), jnp.float32)
    li = 0
    for seg_idx, (kind, count) in enumerate(segs):
        stacked = params["blocks"][seg_idx]
        seg_wins = jnp.asarray(wins[li:li + count], jnp.int32)
        uniform = len(set(wins[li:li + count])) == 1
        static_win = wins[li] if uniform else None
        li += count

        def body(carry, xs, _kind=kind, _static=static_win):
            x, aux = carry
            lp, w = xs
            win = _static if _static is not None else w
            x, a = _apply_layer(lp, x, positions, cfg, _kind, win,
                                attn_impl=attn_impl)
            x = act.constrain(x)
            return (x, aux + a), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        if cfg.scan_layers and count > 1:
            (h, aux_total), _ = jax.lax.scan(
                body_fn, (h, aux_total), (stacked, seg_wins),
                unroll=_unroll_of(unroll, count))
        else:
            for j in range(count):
                lp = jax.tree.map(lambda v: v[j], stacked)
                (h, aux_total), _ = body_fn((h, aux_total),
                                            (lp, seg_wins[j]))

    h = rms_norm(h, params["ln_f"], cfg.norm_eps)
    n_prefix = cfg.num_meta_tokens + (
        batch["image_embeds"].shape[1]
        if (cfg.modality == "vlm" and "image_embeds" in batch) else 0)
    if n_prefix:
        h = h[:, n_prefix:]
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head.astype(dtype))
    return logits, aux_total


def decoder_loss(params, batch, cfg: ArchConfig, *, unroll: bool = False):
    logits, aux = decoder_forward(params, batch, cfg, unroll=unroll)
    labels = batch["labels"]
    mask = (labels >= 0)
    loss = softmax_xent(logits, jnp.maximum(labels, 0), mask)
    return loss + aux, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decoder_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Cache pytree mirroring the segment structure."""
    dtype = dtype_of(cfg.dtype)
    total_len = max_len + cfg.num_meta_tokens + (
        cfg.num_image_tokens if cfg.modality == "vlm" else 0)
    caches = []
    for kind, count in segments(cfg):
        one = _init_layer_cache(batch, total_len, cfg, kind, dtype)
        stacked = jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (count, *v.shape)), one)
        caches.append(stacked)
    return caches


def init_paged_decoder_cache(cfg: ArchConfig, max_slots: int,
                             page_size: int, num_pages: int):
    """Paged cache pool mirroring the segment structure: attention leaves
    hold (count, num_pages, page_size, ...) physical pages shared by every
    slot through a block table, SSM conv/state leaves keep one lane per
    slot (count, max_slots, ...) — they have no sequence dim to page. The
    meta/VLM row padding of ``init_decoder_cache`` does not apply: the
    serve paths never write meta prefixes, and pages are allocated by
    demand, not worst case."""
    dtype = dtype_of(cfg.dtype)
    caches = []
    for kind, count in segments(cfg):
        one = {}
        if kind in ("dense", "moe", "hybrid"):
            one["attn"] = attn_mod.attn_init_cache(num_pages, page_size,
                                                   cfg, dtype)
        if kind in ("ssm", "hybrid"):
            one["ssm"] = ssm_mod.ssm_init_cache(max_slots, cfg.d_model,
                                                cfg.ssm, dtype)
        stacked = jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (count, *v.shape)), one)
        caches.append(stacked)
    return caches


def decoder_decode_step(params, caches, tokens, pos, cfg: ArchConfig,
                        *, seq_len: int, unroll: bool = False,
                        block_tables=None, page_size: int = 0):
    """One decode step. tokens:(B,1) int32; pos: scalar int32 (cache index
    shared by the whole batch) or (B,) int32 per-sequence indices (the
    serving engine's slot pool, where every sequence is at its own depth).

    ``block_tables`` (B, NP) int32 routes attention caches through the
    paged layout (``init_paged_decoder_cache``); the tables are a scan
    constant — same physical pages for every layer of a slot's lane.

    Returns (logits (B,1,V), new_caches)."""
    dtype = dtype_of(cfg.dtype)
    h = params["embed"][tokens].astype(dtype)
    wins = layer_windows(cfg, "decode", seq_len)
    segs = segments(cfg)
    attn_impl = (attn_mod.resolve_attn_impl(cfg.attention)
                 if cfg.attention is not None else None)

    li = 0
    new_caches = []
    for seg_idx, (kind, count) in enumerate(segs):
        stacked = params["blocks"][seg_idx]
        cache = caches[seg_idx]
        seg_wins = jnp.asarray(wins[li:li + count], jnp.int32)
        uniform = len(set(wins[li:li + count])) == 1
        static_win = wins[li] if uniform else None
        li += count

        def body(x, xs, _kind=kind, _static=static_win):
            lp, lc, w = xs
            win = _static if _static is not None else w
            x, nc = _decode_layer(lp, lc, x, pos, cfg, _kind, win,
                                  attn_impl=attn_impl, tables=block_tables,
                                  page_size=page_size)
            return x, nc

        if cfg.scan_layers and count > 1:
            h, nc = jax.lax.scan(body, h, (stacked, cache, seg_wins),
                                 unroll=_unroll_of(unroll, count))
        else:
            ncs = []
            for j in range(count):
                lp = jax.tree.map(lambda v: v[j], stacked)
                lc = jax.tree.map(lambda v: v[j], cache)
                h, nc1 = body(h, (lp, lc, seg_wins[j]))
                ncs.append(nc1)
            nc = jax.tree.map(lambda *vs: jnp.stack(vs), *ncs)
        new_caches.append(nc)

    h = rms_norm(h, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head.astype(dtype))
    return logits, new_caches


def decoder_prefill(params, caches, tokens, pos0, valid, cfg: ArchConfig,
                    *, seq_len: int, unroll: bool = False,
                    block_tables=None, page_size: int = 0):
    """Chunked whole-prompt prefill: one full-sequence pass over a (B,C)
    token chunk starting at cache position ``pos0`` that computes logits
    for every chunk position AND writes all layer caches — replacing the
    token-by-token forced-decode loop (C model calls, C wasted LM-head
    projections) with a single call.

    ``valid`` (scalar int32 <= C, shared by the batch) marks how many
    leading chunk positions are real tokens; trailing pad positions are
    excluded from SSM state updates and MoE routing, and their (garbage)
    cache rows sit beyond the live sequence where causal masking hides
    them until the decode steps overwrite them in order.

    Long prompts run as consecutive calls with pos0 = 0, C, 2C, ...; the
    attention chunk attends the whole cache written so far, and SSM state
    carries through the cache. Meta-token/VLM prefixes are not applied
    (consistent with ``decoder_decode_step``).

    Returns (logits (B,C,V), new_caches)."""
    dtype = dtype_of(cfg.dtype)
    B, C = tokens.shape
    h = params["embed"][tokens].astype(dtype)
    pos0 = jnp.asarray(pos0, jnp.int32)
    valid = jnp.asarray(valid, jnp.int32)
    positions = jnp.broadcast_to(
        pos0 + jnp.arange(C, dtype=jnp.int32)[None], (B, C))
    valid_flat = jnp.broadcast_to((jnp.arange(C) < valid)[None],
                                  (B, C)).reshape(-1)
    wins = layer_windows(cfg, "decode", seq_len)
    segs = segments(cfg)
    attn_impl = (attn_mod.resolve_attn_impl(cfg.attention)
                 if cfg.attention is not None else None)

    li = 0
    new_caches = []
    for seg_idx, (kind, count) in enumerate(segs):
        stacked = params["blocks"][seg_idx]
        cache = caches[seg_idx]
        seg_wins = jnp.asarray(wins[li:li + count], jnp.int32)
        uniform = len(set(wins[li:li + count])) == 1
        static_win = wins[li] if uniform else None
        li += count

        def body(x, xs, _kind=kind, _static=static_win):
            lp, lc, w = xs
            win = _static if _static is not None else w
            x, nc = _prefill_layer(lp, lc, x, positions, pos0, valid,
                                   valid_flat, cfg, _kind, win,
                                   attn_impl=attn_impl, tables=block_tables,
                                   page_size=page_size)
            x = act.constrain(x)
            return x, nc

        if cfg.scan_layers and count > 1:
            h, nc = jax.lax.scan(body, h, (stacked, cache, seg_wins),
                                 unroll=_unroll_of(unroll, count))
        else:
            ncs = []
            for j in range(count):
                lp = jax.tree.map(lambda v: v[j], stacked)
                lc = jax.tree.map(lambda v: v[j], cache)
                h, nc1 = body(h, (lp, lc, seg_wins[j]))
                ncs.append(nc1)
            nc = jax.tree.map(lambda *vs: jnp.stack(vs), *ncs)
        new_caches.append(nc)

    h = rms_norm(h, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head.astype(dtype))
    return logits, new_caches
