"""The paper's own benchmark convnets in pure JAX: AlexNet (grouped, to match
Table 2's 60,965,224 params), VGG-16 (138,357,544), GoogLeNet + both aux
classifiers (~13.38M). Used by the paper-faithful BSP experiments.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import softmax_xent


def _conv_init(key, kh, kw, cin, cout, groups=1):
    fan_in = kh * kw * cin // groups
    std = math.sqrt(2.0 / fan_in)
    w = jax.random.normal(key, (kh, kw, cin // groups, cout),
                          jnp.float32) * std
    return {"w": w, "b": jnp.zeros((cout,), jnp.float32)}


def _fc_init(key, cin, cout):
    std = math.sqrt(2.0 / cin)
    return {"w": jax.random.normal(key, (cin, cout), jnp.float32) * std,
            "b": jnp.zeros((cout,), jnp.float32)}


def _conv(p, x, stride=1, padding="SAME", groups=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    return y + p["b"]


def _maxpool(x, k=3, s=2, padding="VALID"):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, s, s, 1), padding)


def _avgpool(x, k, s, padding="VALID"):
    y = jax.lax.reduce_window(x, 0.0, jax.lax.add,
                              (1, k, k, 1), (1, s, s, 1), padding)
    return y / (k * k)


def _gap(x):
    return jnp.mean(x, axis=(1, 2))


def _lrn(x, n=5, alpha=1e-4, beta=0.75, k=2.0):
    """Local response normalization (AlexNet)."""
    sq = jnp.square(x)
    # sum over a window of n channels
    pad = jnp.pad(sq, ((0, 0), (0, 0), (0, 0), (n // 2, n // 2)))
    acc = jnp.zeros_like(x)
    for i in range(n):
        acc = acc + pad[..., i:i + x.shape[-1]]
    return x / jnp.power(k + alpha * acc, beta)


# ---------------------------------------------------------------------------
# AlexNet (original grouped topology -> 60,965,224 params at 1000 classes)
# ---------------------------------------------------------------------------

def init_alexnet(key, cfg: ArchConfig):
    ks = jax.random.split(key, 8)
    C = cfg.num_classes
    p = {
        "c1": _conv_init(ks[0], 11, 11, 3, 96),
        "c2": _conv_init(ks[1], 5, 5, 96, 256, groups=2),
        "c3": _conv_init(ks[2], 3, 3, 256, 384),
        "c4": _conv_init(ks[3], 3, 3, 384, 384, groups=2),
        "c5": _conv_init(ks[4], 3, 3, 384, 256, groups=2),
    }
    feat = jax.eval_shape(
        lambda q: _alexnet_features(q, jnp.zeros(
            (1, cfg.image_size, cfg.image_size, 3), jnp.float32)), p)
    fdim = int(feat.shape[1] * feat.shape[2] * feat.shape[3])
    p["f6"] = _fc_init(ks[5], fdim, 4096)
    p["f7"] = _fc_init(ks[6], 4096, 4096)
    p["f8"] = _fc_init(ks[7], 4096, C)
    return p


def _alexnet_features(p, x):
    x = jax.nn.relu(_conv(p["c1"], x, stride=4, padding="VALID"))
    x = _maxpool(_lrn(x))
    x = jax.nn.relu(_conv(p["c2"], x, groups=2))
    x = _maxpool(_lrn(x))
    x = jax.nn.relu(_conv(p["c3"], x))
    x = jax.nn.relu(_conv(p["c4"], x, groups=2))
    x = jax.nn.relu(_conv(p["c5"], x, groups=2))
    return _maxpool(x)


def alexnet_forward(p, x, train: bool = False, rng=None):
    x = _alexnet_features(p, x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["f6"]["w"] + p["f6"]["b"])
    if train and rng is not None:
        x = x * jax.random.bernoulli(jax.random.fold_in(rng, 6), 0.5,
                                     x.shape) * 2.0
    x = jax.nn.relu(x @ p["f7"]["w"] + p["f7"]["b"])
    if train and rng is not None:
        x = x * jax.random.bernoulli(jax.random.fold_in(rng, 7), 0.5,
                                     x.shape) * 2.0
    return x @ p["f8"]["w"] + p["f8"]["b"]


# ---------------------------------------------------------------------------
# VGG-16 (138,357,544 params at 1000 classes)
# ---------------------------------------------------------------------------

_VGG16 = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]


def init_vgg16(key, cfg: ArchConfig):
    p = {}
    cin = 3
    i = 0
    for cout, reps in _VGG16:
        for r in range(reps):
            p[f"c{i}"] = _conv_init(jax.random.fold_in(key, i), 3, 3, cin,
                                    cout)
            cin = cout
            i += 1
    side = cfg.image_size // 32
    p["f0"] = _fc_init(jax.random.fold_in(key, 100), cin * side * side, 4096)
    p["f1"] = _fc_init(jax.random.fold_in(key, 101), 4096, 4096)
    p["f2"] = _fc_init(jax.random.fold_in(key, 102), 4096, cfg.num_classes)
    return p


def vgg16_forward(p, x, train: bool = False, rng=None):
    i = 0
    for cout, reps in _VGG16:
        for r in range(reps):
            x = jax.nn.relu(_conv(p[f"c{i}"], x))
            i += 1
        x = _maxpool(x, k=2, s=2)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["f0"]["w"] + p["f0"]["b"])
    x = jax.nn.relu(x @ p["f1"]["w"] + p["f1"]["b"])
    return x @ p["f2"]["w"] + p["f2"]["b"]


# ---------------------------------------------------------------------------
# GoogLeNet (Inception v1, with both aux classifiers)
# ---------------------------------------------------------------------------

# (1x1, 3x3red, 3x3, 5x5red, 5x5, pool_proj)
_INCEPTION = {
    "3a": (64, 96, 128, 16, 32, 32),
    "3b": (128, 128, 192, 32, 96, 64),
    "4a": (192, 96, 208, 16, 48, 64),
    "4b": (160, 112, 224, 24, 64, 64),
    "4c": (128, 128, 256, 24, 64, 64),
    "4d": (112, 144, 288, 32, 64, 64),
    "4e": (256, 160, 320, 32, 128, 128),
    "5a": (256, 160, 320, 32, 128, 128),
    "5b": (384, 192, 384, 48, 128, 128),
}


def _init_inception(key, cin, spec):
    c1, r3, c3, r5, c5, pp = spec
    ks = jax.random.split(key, 6)
    return {
        "b1": _conv_init(ks[0], 1, 1, cin, c1),
        "b3r": _conv_init(ks[1], 1, 1, cin, r3),
        "b3": _conv_init(ks[2], 3, 3, r3, c3),
        "b5r": _conv_init(ks[3], 1, 1, cin, r5),
        "b5": _conv_init(ks[4], 5, 5, r5, c5),
        "bp": _conv_init(ks[5], 1, 1, cin, pp),
    }


def _inception(p, x):
    b1 = jax.nn.relu(_conv(p["b1"], x))
    b3 = jax.nn.relu(_conv(p["b3"], jax.nn.relu(_conv(p["b3r"], x))))
    b5 = jax.nn.relu(_conv(p["b5"], jax.nn.relu(_conv(p["b5r"], x))))
    bp = jax.nn.relu(_conv(p["bp"], _maxpool(x, k=3, s=1, padding="SAME")))
    return jnp.concatenate([b1, b3, b5, bp], axis=-1)


def _out_ch(spec):
    return spec[0] + spec[2] + spec[4] + spec[5]


def init_googlenet(key, cfg: ArchConfig):
    C = cfg.num_classes
    p = {
        "c1": _conv_init(jax.random.fold_in(key, 0), 7, 7, 3, 64),
        "c2r": _conv_init(jax.random.fold_in(key, 1), 1, 1, 64, 64),
        "c2": _conv_init(jax.random.fold_in(key, 2), 3, 3, 64, 192),
    }
    cin = 192
    for i, (name, spec) in enumerate(_INCEPTION.items()):
        p[f"i{name}"] = _init_inception(jax.random.fold_in(key, 10 + i),
                                        cin, spec)
        cin = _out_ch(spec)
    p["fc"] = _fc_init(jax.random.fold_in(key, 50), 1024, C)
    # aux classifiers after 4a (512ch, 14x14 at 224px) and 4d (528ch)
    aux_side = max(1, (cfg.image_size // 16 - 5) // 3 + 1)
    for j, cin_aux in ((0, 512), (1, 528)):
        p[f"aux{j}_conv"] = _conv_init(jax.random.fold_in(key, 60 + j),
                                       1, 1, cin_aux, 128)
        p[f"aux{j}_fc1"] = _fc_init(jax.random.fold_in(key, 62 + j),
                                    128 * aux_side * aux_side, 1024)
        p[f"aux{j}_fc2"] = _fc_init(jax.random.fold_in(key, 64 + j), 1024, C)
    return p


def _aux_head(p, j, x):
    x = _avgpool(x, 5, 3)
    x = jax.nn.relu(_conv(p[f"aux{j}_conv"], x))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p[f"aux{j}_fc1"]["w"] + p[f"aux{j}_fc1"]["b"])
    return x @ p[f"aux{j}_fc2"]["w"] + p[f"aux{j}_fc2"]["b"]


def googlenet_forward(p, x, train: bool = False, rng=None):
    """Returns (logits, [aux0_logits, aux1_logits])."""
    x = jax.nn.relu(_conv(p["c1"], x, stride=2))
    x = _maxpool(x)
    x = _lrn(x)
    x = jax.nn.relu(_conv(p["c2r"], x))
    x = jax.nn.relu(_conv(p["c2"], x))
    x = _lrn(x)
    x = _maxpool(x)
    aux = []
    for name, spec in _INCEPTION.items():
        x = _inception(p[f"i{name}"], x)
        if name in ("3b", "4e"):
            x = _maxpool(x)
        if train:
            if name == "4a":
                aux.append(_aux_head(p, 0, x))
            elif name == "4d":
                aux.append(_aux_head(p, 1, x))
    x = _gap(x)
    logits = x @ p["fc"]["w"] + p["fc"]["b"]
    return logits, aux


# ---------------------------------------------------------------------------
# unified interface
# ---------------------------------------------------------------------------

def init_conv(key, cfg: ArchConfig):
    return {"alexnet": init_alexnet, "vgg16": init_vgg16,
            "googlenet": init_googlenet}[cfg.conv_arch](key, cfg)


def conv_loss(params, batch, cfg: ArchConfig, rng=None, *, unroll=False):
    """batch: {images: (B,H,W,3), labels: (B,)}."""
    x, labels = batch["images"], batch["labels"]
    if cfg.conv_arch == "googlenet":
        logits, aux = googlenet_forward(params, x, train=True, rng=rng)
        loss = softmax_xent(logits, labels)
        for a in aux:
            loss = loss + 0.3 * softmax_xent(a, labels)
    elif cfg.conv_arch == "alexnet":
        logits = alexnet_forward(params, x, train=True, rng=rng)
        loss = softmax_xent(logits, labels)
    else:
        logits = vgg16_forward(params, x, train=True, rng=rng)
        loss = softmax_xent(logits, labels)
    return loss, {"loss": loss, "aux": jnp.zeros((), jnp.float32)}


def conv_predict(params, x, cfg: ArchConfig):
    if cfg.conv_arch == "googlenet":
        return googlenet_forward(params, x)[0]
    if cfg.conv_arch == "alexnet":
        return alexnet_forward(params, x)
    return vgg16_forward(params, x)
