"""Encoder-decoder transformer (SeamlessM4T backbone).

Encoder consumes precomputed frontend frame embeddings (audio stub per the
assignment carve-out), decoder is a causal text/unit decoder with cross
attention. Decode caches: self-attn KV cache + precomputed cross-attn K/V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import act
from repro.models import attention as attn_mod
from repro.models.common import (dense_init, dtype_of, embed_init, rms_norm,
                                 softmax_xent)
from repro.models.mlp import init_mlp, mlp_forward
from repro.models.transformer import _unroll_of


def _init_enc_layer(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": attn_mod.init_gqa(ks[0], cfg, cfg.attention, dtype),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "self_attn": attn_mod.init_gqa(ks[0], cfg, cfg.attention, dtype),
        "ln_x": jnp.zeros((cfg.d_model,), jnp.float32),
        "cross_attn": attn_mod.init_gqa(ks[1], cfg, cfg.attention, dtype),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def init_encdec(key, cfg: ArchConfig):
    dtype = dtype_of(cfg.param_dtype)
    kemb, khead, kenc, kdec = jax.random.split(key, 4)
    enc_keys = jnp.stack(jax.random.split(kenc, cfg.num_encoder_layers))
    dec_keys = jnp.stack(jax.random.split(kdec, cfg.num_layers))
    return {
        "embed": embed_init(kemb, cfg.vocab_size, cfg.d_model, dtype),
        "head": dense_init(khead, cfg.d_model, (cfg.vocab_size,), dtype),
        "ln_enc": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln_dec": jnp.zeros((cfg.d_model,), jnp.float32),
        "enc": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(enc_keys),
        "dec": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(dec_keys),
    }


def _bidir_attend(p, x, positions, cfg):
    """Encoder self-attention (no causal mask)."""
    a = cfg.attention
    q, k, v = attn_mod._project_qkv(p, x, a)
    q = attn_mod.apply_rope(q, positions, a.rope_theta)
    k = attn_mod.apply_rope(k, positions, a.rope_theta)
    S = x.shape[1]
    keep = jnp.ones((S, S), bool)
    out = attn_mod.gqa_attend(q, k, v, keep, a)
    return jnp.einsum("bsf,fd->bsd", out.reshape(x.shape[0], S, -1), p["wo"])


def _cross_attend(p, x, enc_out, q_positions, cfg):
    a = cfg.attention
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    keep = jnp.ones((x.shape[1], enc_out.shape[1]), bool)
    out = attn_mod.gqa_attend(q, k, v, keep, a)
    return jnp.einsum("bsf,fd->bsd",
                      out.reshape(x.shape[0], x.shape[1], -1), p["wo"])


def encode(params, frames, cfg: ArchConfig, *, unroll: bool = False):
    """frames: (B, T_src, d) stub embeddings -> encoder output (B,T_src,d)."""
    dtype = dtype_of(cfg.dtype)
    h = frames.astype(dtype)
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        x = x + _bidir_attend(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                              positions, cfg)
        x = x + mlp_forward(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return act.constrain(x), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        h, _ = jax.lax.scan(body_fn, h, params["enc"],
                            unroll=_unroll_of(unroll, cfg.num_encoder_layers))
    else:
        for j in range(cfg.num_encoder_layers):
            lp = jax.tree.map(lambda v: v[j], params["enc"])
            h, _ = body_fn(h, lp)
    return rms_norm(h, params["ln_enc"], cfg.norm_eps)


def decode_train(params, tokens, enc_out, cfg: ArchConfig,
                 *, unroll: bool = False):
    dtype = dtype_of(cfg.dtype)
    h = params["embed"][tokens].astype(dtype)
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    # hoisted like decoder_forward: resolve once, not per scan-body layer
    impl = attn_mod.resolve_attn_impl(cfg.attention)

    def body(x, lp):
        x = x + attn_mod.gqa_forward(
            lp["self_attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
            positions, cfg.attention, 0, impl=impl)
        x = x + _cross_attend(lp["cross_attn"],
                              rms_norm(x, lp["ln_x"], cfg.norm_eps),
                              enc_out, positions, cfg)
        x = x + mlp_forward(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return act.constrain(x), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        h, _ = jax.lax.scan(body_fn, h, params["dec"],
                            unroll=_unroll_of(unroll, cfg.num_layers))
    else:
        for j in range(cfg.num_layers):
            lp = jax.tree.map(lambda v: v[j], params["dec"])
            h, _ = body_fn(h, lp)
    h = rms_norm(h, params["ln_dec"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", h, params["head"].astype(dtype))


def encdec_loss(params, batch, cfg: ArchConfig, *, unroll: bool = False):
    enc_out = encode(params, batch["frames"], cfg, unroll=unroll)
    logits = decode_train(params, batch["tokens"], enc_out, cfg, unroll=unroll)
    labels = batch["labels"]
    mask = labels >= 0
    loss = softmax_xent(logits, jnp.maximum(labels, 0), mask)
    return loss, {"loss": loss, "aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def init_encdec_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Self-attn cache + cross K/V (filled by prefill_encoder)."""
    dtype = dtype_of(cfg.dtype)
    a = cfg.attention
    L = cfg.num_layers
    self_c = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (L, *v.shape)),
        attn_mod.gqa_init_cache(batch, max_len, a, dtype))
    cross = {
        "k": jnp.zeros((L, batch, cfg.encoder_seq_len, a.num_kv_heads,
                        a.head_dim), dtype),
        "v": jnp.zeros((L, batch, cfg.encoder_seq_len, a.num_kv_heads,
                        a.head_dim), dtype),
    }
    return {"self": self_c, "cross": cross}


def prefill_encoder(params, frames, cfg: ArchConfig, cache,
                    *, unroll: bool = False):
    """Run encoder and precompute per-layer cross-attention K/V."""
    enc_out = encode(params, frames, cfg, unroll=unroll)

    def kv(lp):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
        return k, v

    ks, vs = jax.vmap(kv)(params["dec"])
    return {"self": cache["self"],
            "cross": {"k": ks.astype(cache["cross"]["k"].dtype),
                      "v": vs.astype(cache["cross"]["v"].dtype)}}


def encdec_decode_step(params, cache, tokens, pos, cfg: ArchConfig,
                       *, seq_len: int, unroll: bool = False):
    """One decoder token. tokens: (B,1)."""
    dtype = dtype_of(cfg.dtype)
    a = cfg.attention
    h = params["embed"][tokens].astype(dtype)
    # sliding-window for long-context shapes (sub-quadratic requirement)
    window = cfg.long_context_window if seq_len > 100_000 else 0

    def body(x, xs):
        lp, sc, ck, cv = xs
        y, nsc = attn_mod.gqa_decode(
            lp["self_attn"], sc, rms_norm(x, lp["ln1"], cfg.norm_eps),
            pos, a, window)
        x = x + y
        hq = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", hq, lp["cross_attn"]["wq"])
        keep = jnp.ones((1, ck.shape[1]), bool)
        out = attn_mod.gqa_attend(q, ck, cv, keep, a)
        x = x + jnp.einsum("bsf,fd->bsd",
                           out.reshape(x.shape[0], 1, -1),
                           lp["cross_attn"]["wo"])
        x = x + mlp_forward(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return x, nsc

    if cfg.scan_layers:
        h, new_self = jax.lax.scan(
            body, h, (params["dec"], cache["self"],
                      cache["cross"]["k"], cache["cross"]["v"]),
            unroll=_unroll_of(unroll, cfg.num_layers))
    else:
        ncs = []
        for j in range(cfg.num_layers):
            xs = jax.tree.map(lambda v: v[j],
                              (params["dec"], cache["self"],
                               cache["cross"]["k"], cache["cross"]["v"]))
            h, nc1 = body(h, xs)
            ncs.append(nc1)
        new_self = jax.tree.map(lambda *vs: jnp.stack(vs), *ncs)
    h = rms_norm(h, params["ln_dec"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["head"].astype(dtype))
    return logits, {"self": new_self, "cross": cache["cross"]}
