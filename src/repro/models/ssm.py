"""Mamba-2 SSD (state-space duality) block.  [arXiv:2405.21060]

TPU adaptation: the SSD *chunked* algorithm is used for training/prefill —
it recasts the selective scan as block matmuls (MXU-friendly: intra-chunk
quadratic attention-like term + inter-chunk state recurrence via lax.scan),
instead of the CUDA selective-scan kernel. Decode keeps the O(1) recurrent
state update: h <- exp(dt*A) h + dt * B x ; y = C h + D x.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.common import dense_init, rms_norm


def d_inner_of(d_model: int, s: SSMConfig) -> int:
    return s.expand * d_model


def num_heads_of(d_model: int, s: SSMConfig) -> int:
    return d_inner_of(d_model, s) // s.head_dim


def init_ssm(key, d_model: int, s: SSMConfig, dtype):
    di = d_inner_of(d_model, s)
    nh = num_heads_of(d_model, s)
    G, N = s.ngroups, s.state_dim
    ks = jax.random.split(key, 6)
    # separate projections (z, x head-sharded over TP; B/C/dt small, replicated)
    p = {
        "wz": dense_init(ks[0], d_model, (di,), dtype),
        "wx": dense_init(ks[4], d_model, (di,), dtype),
        "wbc": dense_init(ks[5], d_model, (2 * G * N,), dtype),
        "wdt": dense_init(jax.random.fold_in(key, 9), d_model, (nh,), dtype),
        "out_proj": dense_init(ks[1], di, (d_model,), dtype),
        "conv_w": (jax.random.normal(ks[2], (s.conv_width, di + 2 * G * N),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * G * N,), dtype),
        # A in (-exp) log-space, per head; dt bias; D skip
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[3], (nh,)) * 0.1 + 0.001,
                     1e-4, 0.1))).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": jnp.zeros((di,), jnp.float32),  # gated RMSNorm scale
    }
    return p


def _project(p, x):
    """x: (..., d) -> z (..., di), xBC (..., di+2GN), dt (..., nh)."""
    z = jnp.einsum("...d,dk->...k", x, p["wz"])
    xs = jnp.einsum("...d,dk->...k", x, p["wx"])
    bc = jnp.einsum("...d,dk->...k", x, p["wbc"])
    dt = jnp.einsum("...d,dk->...k", x, p["wdt"])
    return z, jnp.concatenate([xs, bc], axis=-1), dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv along seq. xBC:(B,S,D), w:(W,D)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(W):
        out = out + pad[:, i:i + xBC.shape[1], :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xBC.dtype)


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} x[..., k]."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None):
    """SSD forward.

    x: (b, s, h, p)   dt: (b, s, h)   A: (h,) negative
    B, C: (b, s, g, n)   returns y: (b, s, h, p), final_state (b,h,p,n)

    ``init_state`` (b,h,n,p fp32, default zeros) seeds the inter-chunk
    recurrence, so a long prompt can be prefilled in consecutive calls
    (serving engine's chunked prefill) with the state carried through the
    cache. Positions with dt==0 are exact no-ops on the state (decay 1,
    contribution 0), which is how both internal chunk padding and the
    engine's prompt padding stay bit-transparent.
    """
    b, S0, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    Q = chunk
    pad = (-S0) % Q
    if pad:
        # zero dt on padding: decay=1, contribution=0 -> outputs unaffected
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = S0 + pad
    nc = S // Q
    rep = h // g

    # work in fp32 for the recurrence
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)  # (b,s,h,n)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)

    # chunk
    xc = xf.reshape(b, nc, Q, h, p)
    dtc = dtf.reshape(b, nc, Q, h)
    Bc = Bf.reshape(b, nc, Q, h, n)
    Cc = Cf.reshape(b, nc, Q, h, n)
    dA = dtc * A  # (b,nc,Q,h)

    # 1. intra-chunk (diagonal block) output
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))       # (b,nc,h,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)    # (b,nc,h,Q,Q)
    y_diag = jnp.einsum("bchqk,bchqk,bckh,bckhp->bcqhp",
                        scores, L, dtc, xc)

    # 2. per-chunk end states
    dA_cum = jnp.cumsum(dA, axis=2)                      # (b,nc,Q,h)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (b,nc,Q,h)
    states = jnp.einsum("bcqhn,bcqh,bcqh,bcqhp->bchnp",
                        Bc, decay_to_end, dtc, xc)       # (b,nc,h,n,p)

    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])           # (b,nc,h)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    init = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (b,nc,h,n,p)

    # 4. inter-chunk (off-diagonal) output
    decay_in = jnp.exp(dA_cum)                           # (b,nc,Q,h)
    y_off = jnp.einsum("bcqhn,bcqh,bchnp->bcqhp",
                       Cc, decay_in, prev_states)

    y = (y_diag + y_off).reshape(b, S, h, p)[:, :S0]
    return y.astype(x.dtype), final


def ssm_forward(p, x, d_model: int, s: SSMConfig, eps: float = 1e-5):
    """Training/prefill SSD block. x: (B,S,d) -> (B,S,d)."""
    di = d_inner_of(d_model, s)
    nh = num_heads_of(d_model, s)
    G, N = s.ngroups, s.state_dim
    B_, S_, _ = x.shape

    z, xBC, dt = _project(p, x)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :di].reshape(B_, S_, nh, s.head_dim)
    Bm = xBC[..., di:di + G * N].reshape(B_, S_, G, N)
    Cm = xBC[..., di + G * N:].reshape(B_, S_, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, _ = ssd_chunked(xs, dt, A, Bm, Cm, s.chunk)
    y = y + xs.astype(jnp.float32).astype(y.dtype) * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B_, S_, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], eps)
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"])


# ---------------------------------------------------------------------------
# recurrent decode
# ---------------------------------------------------------------------------

def ssm_init_cache(batch: int, d_model: int, s: SSMConfig, dtype):
    di = d_inner_of(d_model, s)
    nh = num_heads_of(d_model, s)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1,
                           di + 2 * s.ngroups * s.state_dim), dtype),
        "state": jnp.zeros((batch, nh, s.state_dim, s.head_dim), jnp.float32),
    }


def ssm_decode(p, cache, x, d_model: int, s: SSMConfig, eps: float = 1e-5):
    """Single-token recurrent step. x: (B,1,d)."""
    di = d_inner_of(d_model, s)
    nh = num_heads_of(d_model, s)
    G, N = s.ngroups, s.state_dim
    Bsz = x.shape[0]

    z, xBC, dt = _project(p, x[:, 0])                          # (B, .)
    # conv over the rolling window
    win = jnp.concatenate([cache["conv"],
                           xBC[:, None, :].astype(cache["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bwk,wk->bk", win.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    xBC = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    new_conv = win[:, 1:, :]

    xs = xBC[..., :di].reshape(Bsz, nh, s.head_dim)
    Bm = xBC[..., di:di + G * N].reshape(Bsz, G, N)
    Cm = xBC[..., di + G * N:].reshape(Bsz, G, N)
    rep = nh // G
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)      # (B,nh,N)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])                                   # (nh,)
    decay = jnp.exp(dt * A)                                    # (B,nh)

    h = cache["state"]                                         # (B,nh,N,P)
    h = h * decay[..., None, None] + jnp.einsum(
        "bhn,bh,bhp->bhnp", Bh, dt, xs.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h)                     # (B,nh,P)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)[:, None, :],
                 p["norm"], eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, {"conv": new_conv, "state": h}


# ---------------------------------------------------------------------------
# chunked prefill (serving)
# ---------------------------------------------------------------------------

def ssm_prefill(p, cache, x, valid, d_model: int, s: SSMConfig,
                eps: float = 1e-5):
    """Whole-chunk prefill that also writes the recurrent cache.

    x: (B,C,d) — one prompt chunk; ``valid`` (scalar int32 <= C) marks how
    many leading positions are real tokens. Pad positions are masked out of
    the state update (dt=0 is an exact no-op) and of the conv tail, so a
    prompt prefilled in chunks of C ends with the cache bit-identical to a
    single-call prefill as long as C is a multiple of ``s.chunk`` (chunk
    boundaries must align for the SSD block decomposition to match).

    The first chunk of a prompt expects a *zeroed* conv/state lane (a
    fresh ``init_cache`` or an engine ``reset_slot``): the recurrent state
    deliberately carries across calls, so a previous occupant's state
    would leak in. (Gating on pos0==0 inside the graph was tried and
    perturbs XLA's scan fusion enough to break chunked-vs-single-call
    bitwise equality — the engine resets the lane at admission instead.)

    Returns (y (B,C,d), new_cache) with new_cache = {conv, state} holding
    the last conv_width-1 *valid* inputs and the state after position
    valid-1."""
    di = d_inner_of(d_model, s)
    nh = num_heads_of(d_model, s)
    G, N = s.ngroups, s.state_dim
    B_, C_, _ = x.shape
    W = s.conv_width
    valid = jnp.asarray(valid, jnp.int32)

    z, xBC, dt = _project(p, x)
    # causal conv with the cached history window instead of zero padding;
    # same multiply-add order as _causal_conv (bitwise match for chunk 0)
    win = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC], axis=1)
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(W):
        out = out + win[:, i:i + C_, :].astype(jnp.float32) \
            * p["conv_w"][i].astype(jnp.float32)
    xBC = jax.nn.silu(out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    # rows [valid, valid+W-2] of win are the last W-1 valid inputs
    new_conv = jax.lax.dynamic_slice_in_dim(win, valid, W - 1, axis=1)

    xs = xBC[..., :di].reshape(B_, C_, nh, s.head_dim)
    Bm = xBC[..., di:di + G * N].reshape(B_, C_, G, N)
    Cm = xBC[..., di + G * N:].reshape(B_, C_, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = jnp.where((jnp.arange(C_) < valid)[None, :, None], dt, 0.0)
    A = -jnp.exp(p["A_log"])

    y, final = ssd_chunked(xs, dt, A, Bm, Cm, s.chunk,
                           init_state=cache["state"])
    y = y + xs.astype(jnp.float32).astype(y.dtype) \
        * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B_, C_, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, {"conv": new_conv.astype(cache["conv"].dtype),
                 "state": final}


# ---------------------------------------------------------------------------
# naive reference (oracle for tests)
# ---------------------------------------------------------------------------

def ssd_naive(x, dt, A, B, C):
    """Sequential recurrence oracle, O(S) scan. Shapes as ssd_chunked."""
    b, S, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(hst, t):
        decay = jnp.exp(dtf[:, t] * A)                        # (b,h)
        hst = hst * decay[..., None, None] + jnp.einsum(
            "bhn,bh,bhp->bhnp", Bf[:, t], dtf[:, t], xf[:, t])
        y = jnp.einsum("bhn,bhnp->bhp", Cf[:, t], hst)
        return hst, y

    init = jnp.zeros((b, h, n, p), jnp.float32)
    final, ys = jax.lax.scan(step, init, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
