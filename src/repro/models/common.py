"""Shared layers: norms, initializers, RoPE, embeddings, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_shape, dtype=jnp.float32):
    """Truncated-normal fan-in init; out_shape may be a tuple."""
    if isinstance(out_shape, int):
        out_shape = (out_shape,)
    shape = (in_dim, *out_shape)
    std = 1.0 / np.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim), jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def head_rms_norm(x, eps: float = 1e-6):
    """Per-head qk-norm without learned scale (Chameleon-style simplified)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, hd) rotated pairwise; positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                              # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """Mean CE over valid positions; logits (..., V) fp, labels int."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
