"""Unified model API.

``build_model(cfg)`` returns a ``Model`` with:
- ``init(key) -> params``                     (master params, fp32)
- ``loss_fn(params, batch, rng=None, unroll=False) -> (loss, metrics)``
- ``forward(params, batch, unroll=False) -> logits``   (prefill path)
- ``init_cache(batch, max_len) -> cache``     (decoder/encdec only)
- ``decode_step(params, cache, batch, pos, seq_len, unroll) -> (logits, cache)``
  (``pos`` may be a scalar or a per-sequence (B,) vector — serving slots)
- ``chunk_prefill(params, cache, tokens, pos0, valid, seq_len, unroll) ->
  (logits, cache)``  (decoder only: whole-chunk prompt prefill that writes
  the cache in one pass; ``valid`` masks trailing prompt padding)

Mixed precision: forward/loss cast >=2-D fp32 master weights to the compute
dtype (bf16) at entry; gradients flow back to fp32 masters.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, transformer, vision
from repro.models.common import dtype_of


def cast_params(params, dtype):
    """Cast matmul weights (ndim>=2 floats) to the compute dtype; keep
    norm scales / biases / integer leaves as-is."""

    def leaf(p):
        if p.ndim >= 2 and p.dtype == jnp.float32:
            return p.astype(dtype)
        return p

    return jax.tree.map(leaf, params)


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    loss_fn: Callable
    forward: Callable
    init_cache: Callable | None = None
    decode_step: Callable | None = None
    prefill: Callable | None = None        # encdec: encoder -> cross-attn cache
    chunk_prefill: Callable | None = None  # decoder: chunked prompt prefill
    init_paged_cache: Callable | None = None  # decoder: paged serve pool


def build_model(cfg: ArchConfig) -> Model:
    cdt = dtype_of(cfg.dtype)

    if cfg.family == "decoder":
        def loss_fn(params, batch, rng=None, unroll=False):
            return transformer.decoder_loss(cast_params(params, cdt), batch,
                                            cfg, unroll=unroll)

        def forward(params, batch, unroll=False):
            return transformer.decoder_forward(cast_params(params, cdt),
                                               batch, cfg, unroll=unroll)[0]

        def init_cache(batch, max_len):
            return transformer.init_decoder_cache(cfg, batch, max_len)

        def init_paged_cache(max_slots, page_size, num_pages):
            return transformer.init_paged_decoder_cache(
                cfg, max_slots, page_size, num_pages)

        def decode_step(params, cache, batch, pos, seq_len, unroll=False,
                        block_tables=None, page_size=0):
            return transformer.decoder_decode_step(
                cast_params(params, cdt), cache, batch["tokens"], pos, cfg,
                seq_len=seq_len, unroll=unroll, block_tables=block_tables,
                page_size=page_size)

        def chunk_prefill(params, cache, tokens, pos0, valid, *, seq_len,
                          unroll=False, block_tables=None, page_size=0):
            return transformer.decoder_prefill(
                cast_params(params, cdt), cache, tokens, pos0, valid, cfg,
                seq_len=seq_len, unroll=unroll, block_tables=block_tables,
                page_size=page_size)

        return Model(cfg, lambda k: transformer.init_decoder(k, cfg),
                     loss_fn, forward, init_cache, decode_step,
                     chunk_prefill=chunk_prefill,
                     init_paged_cache=init_paged_cache)

    if cfg.family == "encdec":
        def loss_fn(params, batch, rng=None, unroll=False):
            return encdec.encdec_loss(cast_params(params, cdt), batch, cfg,
                                      unroll=unroll)

        def forward(params, batch, unroll=False):
            p = cast_params(params, cdt)
            enc_out = encdec.encode(p, batch["frames"], cfg, unroll=unroll)
            return encdec.decode_train(p, batch["tokens"], enc_out, cfg,
                                       unroll=unroll)

        def init_cache(batch, max_len):
            return encdec.init_encdec_cache(cfg, batch, max_len)

        def decode_step(params, cache, batch, pos, seq_len, unroll=False):
            return encdec.encdec_decode_step(
                cast_params(params, cdt), cache, batch["tokens"], pos, cfg,
                seq_len=seq_len, unroll=unroll)

        def prefill(params, frames, cache, unroll=False):
            return encdec.prefill_encoder(cast_params(params, cdt), frames,
                                          cfg, cache, unroll=unroll)

        return Model(cfg, lambda k: encdec.init_encdec(k, cfg),
                     loss_fn, forward, init_cache, decode_step, prefill)

    if cfg.family == "conv":
        def loss_fn(params, batch, rng=None, unroll=False):
            return vision.conv_loss(params, batch, cfg, rng, unroll=unroll)

        def forward(params, batch, unroll=False):
            return vision.conv_predict(params, batch["images"], cfg)

        return Model(cfg, lambda k: vision.init_conv(k, cfg), loss_fn,
                     forward)

    raise ValueError(f"unknown family {cfg.family!r}")


def count_params(params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))
