"""Attention: GQA (+QKV bias, qk-norm, sliding window) and DeepSeek MLA.

Train path computes full (windowed-)causal attention; decode path attends one
query against a KV cache (GQA caches k/v; MLA caches the 512-d latent + the
shared rope key and uses the absorbed-matmul trick, so the cache is 576
floats/token as in the paper).

Three interchangeable attention implementations back every path
(``resolve_attn_impl``; DESIGN.md "Attention kernels"):

- ``flash``:     the Pallas tiled kernels (``kernels/flash_attention``) —
                 fused online-softmax forward + custom-VJP backward for
                 train, q-chunk×cache tiles for prefill, split-KV for
                 decode. The default wherever Pallas compiles (TPU).
- ``ref``:       the XLA einsum paths below — the parity oracles, and the
                 default on interpret-only backends (CPU). Long sequences
                 still route through the blockwise scan when
                 ``AttentionConfig.block_kv`` is set.
- ``blockwise``: force the ``lax.scan`` online-softmax fallback.

Selection: ``REPRO_ATTN_IMPL`` env > ``AttentionConfig.attn_impl`` >
backend default.
"""
from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, AttentionConfig
from repro.kernels.flash_attention import (flash_attention, flash_decode,
                                           flash_decode_paged)
from repro.models.common import (apply_rope, dense_init, head_rms_norm)

NEG_INF = -1e30

_IMPLS = ("flash", "ref", "blockwise")


def resolve_attn_impl(a: AttentionConfig | None) -> str:
    """Resolve the attention implementation for a config.

    Priority: ``REPRO_ATTN_IMPL`` env > ``a.attn_impl`` > backend default
    (``flash`` where Pallas kernels compile — i.e. not in interpreter
    mode — else the einsum ``ref`` oracles)."""
    impl = os.environ.get("REPRO_ATTN_IMPL", "") or (
        (a.attn_impl or "") if a is not None else "")
    if impl in ("", "auto"):
        from repro.kernels import default_interpret
        return "ref" if default_interpret() else "flash"
    if impl not in _IMPLS:
        raise ValueError(
            f"REPRO_ATTN_IMPL / attn_impl must be one of {_IMPLS} or "
            f"'auto', got {impl!r}")
    return impl


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ArchConfig, a: AttentionConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (a.num_heads, a.head_dim), dtype),
        "wk": dense_init(ks[1], d, (a.num_kv_heads, a.head_dim), dtype),
        "wv": dense_init(ks[2], d, (a.num_kv_heads, a.head_dim), dtype),
        "wo": dense_init(ks[3], a.num_heads * a.head_dim, (d,), dtype),
    }
    if a.qkv_bias:
        p["bq"] = jnp.zeros((a.num_heads, a.head_dim), dtype)
        p["bk"] = jnp.zeros((a.num_kv_heads, a.head_dim), dtype)
        p["bv"] = jnp.zeros((a.num_kv_heads, a.head_dim), dtype)
    return p


def init_mla(key, cfg: ArchConfig, a: AttentionConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    qd = a.qk_nope_dim + a.qk_rope_dim
    return {
        "wq": dense_init(ks[0], d, (a.num_heads, qd), dtype),
        "wdkv": dense_init(ks[1], d, (a.kv_lora_rank,), dtype),
        "wkr": dense_init(ks[2], d, (a.qk_rope_dim,), dtype),
        # up-projections from the latent
        "wuk": dense_init(ks[3], a.kv_lora_rank,
                          (a.num_heads, a.qk_nope_dim), dtype),
        "wuv": dense_init(ks[4], a.kv_lora_rank,
                          (a.num_heads, a.v_head_dim), dtype),
        "wo": dense_init(jax.random.fold_in(key, 7),
                         a.num_heads * a.v_head_dim, (d,), dtype),
    }


def init_attention(key, cfg: ArchConfig, dtype):
    a = cfg.attention
    assert a is not None
    if a.kv_lora_rank:
        return init_mla(key, cfg, a, dtype)
    return init_gqa(key, cfg, a, dtype)


# ---------------------------------------------------------------------------
# masking
# ---------------------------------------------------------------------------

def _is_static(window) -> bool:
    return isinstance(window, int)


def causal_window_mask(q_pos, k_pos, window):
    """(S_q, S_k) boolean mask. window<=0 => plain causal.

    ``window`` may be a python int (static) or a traced scalar (per-layer,
    used by hybrid archs inside layer scans)."""
    keep = k_pos[None, :] <= q_pos[:, None]
    dist = q_pos[:, None] - k_pos[None, :]
    if _is_static(window):
        if window > 0:
            keep &= dist < window
    else:
        keep &= (window <= 0) | (dist < window)
    return keep


def decode_keep(k_pos, pos, window):
    """(S_k,) mask for a single query at position ``pos``."""
    keep = k_pos <= pos
    dist = pos - k_pos
    if _is_static(window):
        if window > 0:
            keep &= dist < window
    else:
        keep &= (window <= 0) | (dist < window)
    return keep


def _decode_pos(pos, batch: int):
    """Normalize a decode position argument to ((B,1) rope positions,
    per-example (B,) cache indices or None-if-scalar).

    A scalar ``pos`` is the classic whole-batch decode step; a (B,) vector
    is the serving engine's per-slot position (each sequence in the batch
    is at its own depth)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return jnp.full((batch, 1), pos, jnp.int32), None
    return pos[:, None], pos


def decode_keep_batched(k_pos, pos_vec, window):
    """(B, S_k) mask for one query per batch row at position ``pos_vec[b]``."""
    keep = k_pos[None, :] <= pos_vec[:, None]
    dist = pos_vec[:, None] - k_pos[None, :]
    if _is_static(window):
        if window > 0:
            keep &= dist < window
    else:
        keep &= (window <= 0) | (dist < window)
    return keep


def _update_cache_rows(buf, new, pos, pos_vec):
    """Write the (B,1,...) ``new`` rows into ``buf`` (B,S,...) at the cache
    index — a shared scalar ``pos`` or per-example ``pos_vec``."""
    new = new.astype(buf.dtype)
    if pos_vec is None:
        return jax.lax.dynamic_update_slice_in_dim(buf, new, pos, axis=1)
    return jax.vmap(
        lambda b, u, i: jax.lax.dynamic_update_slice_in_dim(b, u, i, axis=0)
    )(buf, new, pos_vec)


def _page_coords(pos, page_size: int, num_logical: int):
    """(logical page, in-page row) for absolute positions. Pages clamp
    into the table so pad positions past the last logical page scatter
    into it (or the null page) where masking hides them."""
    return jnp.clip(pos // page_size, 0, num_logical - 1), pos % page_size


def _scatter_page_rows(buf, new, tables, pos_vec, page_size: int):
    """Write one (B, 1, ...) row per batch element into the paged buffer
    (P, page_size, ...) through the block table (B, NP). Idle slots map
    to the null page; their duplicate writes land there harmlessly."""
    B = new.shape[0]
    pj, pr = _page_coords(pos_vec, page_size, tables.shape[1])
    pid = tables[jnp.arange(B), pj]
    return buf.at[pid, pr].set(new[:, 0].astype(buf.dtype))


def _scatter_chunk_rows(buf, new, tables, positions, page_size: int):
    """Scatter a (B, C, ...) prefill chunk into the paged buffer through
    each row's block table. ``positions`` (B, C) absolute — any alignment
    (prefix-cache resume starts mid-stream); rows whose page the table
    maps to 0 write the null page (pad tails), exactly the garbage-row
    contract the contiguous path has beyond ``valid``."""
    B, C = new.shape[:2]
    pj, pr = _page_coords(positions, page_size, tables.shape[1])
    pid = jnp.take_along_axis(tables, pj, axis=1)            # (B, C)
    flat = new.reshape((B * C,) + new.shape[2:]).astype(buf.dtype)
    return buf.at[pid.reshape(-1), pr.reshape(-1)].set(flat)


def _gather_lane(buf, tables):
    """(B, NP*page_size, ...) virtual contiguous lanes gathered from the
    paged buffer — the ref-impl read path (bit-identical rows to a
    contiguous pool lane wherever the lane was actually written)."""
    pages = buf[tables]                                      # (B, NP, ps, ...)
    return pages.reshape((tables.shape[0], -1) + buf.shape[2:])


def _masked_softmax(scores, keep):
    """Masked softmax that never materializes an fp32 copy of the score
    tensor: max-subtract and exp run in the score dtype and only the
    row-sum accumulates in fp32 (XLA fuses the upcast into the
    reduction), so the dense path's peak memory is the score tensor
    itself rather than 3x it. Weights return in the score dtype; pinned
    by the peak-memory regression in tests/test_flash_attention.py."""
    scores = jnp.where(keep, scores, NEG_INF)
    m = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    e = jnp.exp(scores - m)
    l = jnp.sum(e, axis=-1, keepdims=True, dtype=jnp.float32)
    return e / l.astype(e.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def _project_qkv(p, x, a: AttentionConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if a.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def gqa_attend(q, k, v, keep, a: AttentionConfig):
    """q:(B,Sq,H,hd) k,v:(B,Sk,KV,hd), keep:(Sq,Sk) or (B,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k) / jnp.sqrt(hd).astype(q.dtype)
    if keep.ndim == 2:
        keep_b = keep[None, None, None]
    else:
        keep_b = keep[:, None, None]
    w = _masked_softmax(scores, keep_b).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, Sq, H, hd)


def gqa_attend_blockwise(q, k, v, q_pos, k_pos, window, a: AttentionConfig,
                         block: int = 1024, scale=None):
    """Flash-style attention: lax.scan over KV blocks with an online
    softmax, so the (Sq, Sk) score matrix is never materialized in HBM —
    the per-step working set is (Sq, block). Beyond-paper optimization for
    the memory-bound prefill/train shapes (see EXPERIMENTS.md §Perf).

    ``v`` may have a different trailing dim than q/k (the MLA absorbed
    layout: q/k in the latent+rope space, v = the latent); ``scale``
    overrides the default 1/sqrt(head_dim) score scale.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    Sk = k.shape[1]
    hv = v.shape[-1]
    pad = (-Sk) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=10 ** 9)
    nb = (Sk + pad) // block
    qg = q.reshape(B, Sq, KV, G, hd)
    if scale is None:
        scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    kb = k.reshape(B, nb, block, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block, KV, hv).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(nb, block)

    def step(carry, inp):
        m, l, acc = carry                          # (B,KV,G,Sq), ., (+hd)
        kblk, vblk, pblk = inp
        s = jnp.einsum("bskgh,btkh->bkgst", qg, kblk).astype(jnp.float32)
        s = s * scale
        keep = pblk[None, :] <= q_pos[:, None]      # (Sq, block)
        dist = q_pos[:, None] - pblk[None, :]
        if _is_static(window):
            if window > 0:
                keep &= dist < window
        else:
            keep &= (window <= 0) | (dist < window)
        s = jnp.where(keep[None, None, None], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)                 # (B,KV,G,Sq)
        m_new = jnp.maximum(m, m_blk)
        p_ = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p_, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p_.astype(vblk.dtype), vblk
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb),
                                  unroll=nb if a.block_unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hv)
    return out.astype(q.dtype)


def gqa_forward(p, x, positions, a: AttentionConfig, window: int,
                impl: str | None = None):
    """Training/prefill full self-attention. x:(B,S,d)."""
    impl = impl or resolve_attn_impl(a)
    q, k, v = _project_qkv(p, x, a)
    if a.qk_norm:
        q, k = head_rms_norm(q), head_rms_norm(k)
    q = apply_rope(q, positions, a.rope_theta)
    k = apply_rope(k, positions, a.rope_theta)
    B, S = x.shape[:2]
    if impl == "flash":
        # q and k are rows of the same sequence, so the kernel's row-index
        # masking (q_off=0) is exact for any *common-offset* positions:
        # causality and window distance only depend on q_pos - k_pos.
        # Packed/non-monotonic position vectors need the ref path, whose
        # mask compares the actual position values.
        out = flash_attention(q, k, v, window=window)
    elif impl == "blockwise" or (a.block_kv and S > a.block_kv):
        out = gqa_attend_blockwise(q, k, v, positions[0], positions[0],
                                   window, a, block=a.block_kv or 1024)
    else:
        keep = causal_window_mask(positions[0], positions[0], window)
        out = gqa_attend(q, k, v, keep, a)
    return jnp.einsum("bsf,fd->bsd", out.reshape(B, S, -1), p["wo"])


def gqa_init_cache(batch: int, max_len: int, a: AttentionConfig, dtype):
    return {
        "k": jnp.zeros((batch, max_len, a.num_kv_heads, a.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, a.num_kv_heads, a.head_dim), dtype),
    }


def gqa_decode(p, cache, x, pos, a: AttentionConfig, window: int,
               impl: str | None = None, tables=None, page_size: int = 0):
    """One-token decode. x:(B,1,d); pos: scalar int (current index) or a
    (B,) vector of per-sequence indices (serving engine slots).

    ``tables`` (B, NP) int32 switches the cache to the paged layout
    (cache leaves are (P, page_size, ...) physical pages): the new row
    scatters through the table, flash reads fetch pages tile-wise inside
    ``flash_decode_paged``, and the ref path gathers the virtual lane —
    identical math to the contiguous layout on the gathered rows.

    Returns (out, new_cache)."""
    impl = impl or resolve_attn_impl(a)
    q, k, v = _project_qkv(p, x, a)
    if a.qk_norm:
        q, k = head_rms_norm(q), head_rms_norm(k)
    posv, pos_vec = _decode_pos(pos, x.shape[0])
    q = apply_rope(q, posv, a.rope_theta)
    k = apply_rope(k, posv, a.rope_theta)
    B = x.shape[0]
    if tables is not None:
        pv = posv[:, 0]
        ck = _scatter_page_rows(cache["k"], k, tables, pv, page_size)
        cv = _scatter_page_rows(cache["v"], v, tables, pv, page_size)
        if impl == "flash":
            out = flash_decode_paged(q, ck, cv, tables, pv,
                                     page_size=page_size, window=window)
        else:
            lk, lv = _gather_lane(ck, tables), _gather_lane(cv, tables)
            keep = decode_keep_batched(jnp.arange(lk.shape[1]), pv,
                                       window)[:, None, :]
            out = gqa_attend(q, lk, lv, keep, a)
        y = jnp.einsum("bsf,fd->bsd", out.reshape(B, 1, -1), p["wo"])
        return y, {"k": ck, "v": cv}
    ck = _update_cache_rows(cache["k"], k, pos, pos_vec)
    cv = _update_cache_rows(cache["v"], v, pos, pos_vec)
    S = ck.shape[1]
    if impl == "flash":
        out = flash_decode(q, ck, cv,
                           pos_vec if pos_vec is not None else pos,
                           window=window)
    else:
        if pos_vec is None:
            keep = decode_keep(jnp.arange(S), pos, window)[None, :]  # (1,S)
        else:
            keep = decode_keep_batched(jnp.arange(S), pos_vec,
                                       window)[:, None, :]
        out = gqa_attend(q, ck, cv, keep, a)
    y = jnp.einsum("bsf,fd->bsd", out.reshape(B, 1, -1), p["wo"])
    return y, {"k": ck, "v": cv}


def gqa_prefill(p, cache, x, positions, pos0, a: AttentionConfig,
                window: int, impl: str | None = None, tables=None,
                page_size: int = 0):
    """Chunked prompt prefill: attend a whole (B,C,d) chunk against the
    cache and write its K/V rows at [pos0, pos0+C) in one pass.

    ``positions`` (B,C) are absolute positions (pos0 + arange(C)); rows
    beyond the valid prompt length write pad garbage that is masked out of
    every later read (causality) and overwritten by the decode steps.

    ``tables`` (B, NP) switches to the paged cache layout: chunk rows
    scatter through the block table (any pos0 alignment — prefix-cache
    resume and the 1-token full-hit re-prefill both land mid-page) and
    the chunk attends the gathered virtual lane."""
    impl = impl or resolve_attn_impl(a)
    q, k, v = _project_qkv(p, x, a)
    if a.qk_norm:
        q, k = head_rms_norm(q), head_rms_norm(k)
    q = apply_rope(q, positions, a.rope_theta)
    k = apply_rope(k, positions, a.rope_theta)
    B, C = x.shape[:2]
    if tables is not None:
        ck = _scatter_chunk_rows(cache["k"], k, tables, positions, page_size)
        cv = _scatter_chunk_rows(cache["v"], v, tables, positions, page_size)
        lane_k, lane_v = _gather_lane(ck, tables), _gather_lane(cv, tables)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), pos0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), pos0, axis=1)
        lane_k, lane_v = ck, cv
    S = lane_k.shape[1]
    if impl == "flash":
        # q-chunk x full-cache tiles; rows start at the chunk origin
        out = flash_attention(q, lane_k, lane_v, q_off=positions[:, 0],
                              window=window)
    else:
        keep = causal_window_mask(positions[0], jnp.arange(S), window)
        out = gqa_attend(q, lane_k, lane_v, keep, a)
    y = jnp.einsum("bsf,fd->bsd", out.reshape(B, C, -1), p["wo"])
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_forward(p, x, positions, a: AttentionConfig, window: int,
                impl: str | None = None):
    """Training/prefill MLA.

    ``flash``/``blockwise`` attend in the absorbed-matmul layout — W_uk is
    folded into the query so keys are the cached (latent ‖ rope-key)
    vectors and values are the latent itself (the same math the decode
    path uses), which keeps attention a single KV-head problem and never
    expands per-head k_nope/v to HBM. The ``ref`` dense path keeps the
    naive per-head expansion as the oracle, but long sequences route
    through the shared blockwise scan when ``block_kv`` is set (so
    long-seq MLA never builds the (B,H,S,S) score matrix either)."""
    impl = impl or resolve_attn_impl(a)
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = jnp.split(q, [a.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, a.rope_theta)

    c_kv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])          # (B,S,R)
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["wkr"])          # (B,S,rope)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        a.rope_theta)[:, :, 0, :]

    if impl == "flash" or impl == "blockwise" or (
            a.block_kv and S > a.block_kv):
        lat_scale = 1.0 / math.sqrt(a.qk_nope_dim + a.qk_rope_dim)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"])
        q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)    # (B,S,H,R+rope)
        k_cat = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None]
        v_lat = c_kv[:, :, None]                             # (B,S,1,R)
        if impl == "flash":
            o_lat = flash_attention(q_cat, k_cat, v_lat, window=window,
                                    sm_scale=lat_scale)
        else:
            o_lat = gqa_attend_blockwise(
                q_cat, k_cat, v_lat, positions[0], positions[0], window,
                a, block=a.block_kv or 1024,
                scale=jnp.float32(lat_scale))
        out = jnp.einsum("bshr,rhk->bshk", o_lat.astype(x.dtype),
                         p["wuv"]).reshape(B, S, -1)
        return jnp.einsum("bsf,fd->bsd", out, p["wo"])

    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuk"])     # (B,S,H,nope)
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuv"])          # (B,S,H,vd)
    scale = 1.0 / jnp.sqrt(a.qk_nope_dim + a.qk_rope_dim).astype(x.dtype)
    s_nope = jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
    keep = causal_window_mask(positions[0], positions[0], window)
    w = _masked_softmax((s_nope + s_rope) * scale,
                        keep[None, None]).astype(x.dtype)
    out = jnp.einsum("bhst,bthk->bshk", w, v).reshape(B, S, -1)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"])


def mla_init_cache(batch: int, max_len: int, a: AttentionConfig, dtype):
    return {
        "ckv": jnp.zeros((batch, max_len, a.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_len, a.qk_rope_dim), dtype),
    }


def mla_decode(p, cache, x, pos, a: AttentionConfig, window: int,
               tables=None, page_size: int = 0):
    """Absorbed-matmul MLA decode: attends in the 512-d latent space.
    ``pos`` may be a scalar or a (B,) per-sequence vector. ``tables``
    switches to paged latent/rope-key caches ((P, page_size, R/rope)):
    row writes scatter through the block table and attention runs on the
    gathered virtual lanes — the absorbed einsum path is already the
    memory-lean kernel here, so there is no separate flash variant."""
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = jnp.split(q, [a.qk_nope_dim], axis=-1)
    posv, pos_vec = _decode_pos(pos, B)
    q_rope = apply_rope(q_rope, posv, a.rope_theta)
    # absorb W_uk into the query: (B,1,H,nope) x (R,H,nope) -> (B,1,H,R)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"])

    c_new = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    kr_new = jnp.einsum("bsd,dr->bsr", x, p["wkr"])
    kr_new = apply_rope(kr_new[:, :, None, :], posv, a.rope_theta)[:, :, 0, :]
    if tables is not None:
        pv = posv[:, 0]
        ckv = _scatter_page_rows(cache["ckv"], c_new, tables, pv, page_size)
        kr = _scatter_page_rows(cache["kr"], kr_new, tables, pv, page_size)
        lat, ropek = _gather_lane(ckv, tables), _gather_lane(kr, tables)
        keep = decode_keep_batched(jnp.arange(lat.shape[1]), pv,
                                   window)[:, None, None, :]
    else:
        ckv = _update_cache_rows(cache["ckv"], c_new, pos, pos_vec)
        kr = _update_cache_rows(cache["kr"], kr_new, pos, pos_vec)
        lat, ropek = ckv, kr
        S = lat.shape[1]
        if pos_vec is None:
            keep = decode_keep(jnp.arange(S), pos,
                               window)[None, None, None, :]
        else:
            keep = decode_keep_batched(jnp.arange(S), pos_vec,
                                       window)[:, None, None, :]
    scale = 1.0 / jnp.sqrt(a.qk_nope_dim + a.qk_rope_dim).astype(x.dtype)
    s_lat = jnp.einsum("bshr,btr->bhst", q_lat, lat)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, ropek)
    w = _masked_softmax((s_lat + s_rope) * scale, keep).astype(x.dtype)
    o_lat = jnp.einsum("bhst,btr->bshr", w, lat)             # (B,1,H,R)
    out = jnp.einsum("bshr,rhk->bshk", o_lat, p["wuv"]).reshape(B, 1, -1)
    y = jnp.einsum("bsf,fd->bsd", out, p["wo"])
    return y, {"ckv": ckv, "kr": kr}


def mla_prefill(p, cache, x, positions, pos0, a: AttentionConfig,
                window: int, tables=None, page_size: int = 0):
    """Chunked MLA prefill: absorbed-matmul attention (same math as
    ``mla_decode``, C query rows instead of 1) that writes the latent +
    rope-key cache rows at [pos0, pos0+C) — through the block table when
    ``tables`` is given (paged layout, any alignment)."""
    B, C, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = jnp.split(q, [a.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, a.rope_theta)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"])

    c_new = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    kr_new = jnp.einsum("bsd,dr->bsr", x, p["wkr"])
    kr_new = apply_rope(kr_new[:, :, None, :], positions,
                        a.rope_theta)[:, :, 0, :]
    if tables is not None:
        ckv = _scatter_chunk_rows(cache["ckv"], c_new, tables, positions,
                                  page_size)
        kr = _scatter_chunk_rows(cache["kr"], kr_new, tables, positions,
                                 page_size)
        lat, ropek = _gather_lane(ckv, tables), _gather_lane(kr, tables)
    else:
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], c_new.astype(cache["ckv"].dtype), pos0, axis=1)
        kr = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], kr_new.astype(cache["kr"].dtype), pos0, axis=1)
        lat, ropek = ckv, kr

    S = lat.shape[1]
    keep = causal_window_mask(positions[0], jnp.arange(S), window)  # (C,S)
    scale = 1.0 / jnp.sqrt(a.qk_nope_dim + a.qk_rope_dim).astype(x.dtype)
    s_lat = jnp.einsum("bshr,btr->bhst", q_lat, lat)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, ropek)
    w = _masked_softmax((s_lat + s_rope) * scale,
                        keep[None, None]).astype(x.dtype)
    o_lat = jnp.einsum("bhst,btr->bshr", w, lat)             # (B,C,H,R)
    out = jnp.einsum("bshr,rhk->bshk", o_lat, p["wuv"]).reshape(B, C, -1)
    y = jnp.einsum("bsf,fd->bsd", out, p["wo"])
    return y, {"ckv": ckv, "kr": kr}


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def attn_forward(p, x, positions, cfg: ArchConfig, window: int,
                 impl: str | None = None):
    a = cfg.attention
    if a.kv_lora_rank:
        return mla_forward(p, x, positions, a, window, impl=impl)
    return gqa_forward(p, x, positions, a, window, impl=impl)


def attn_init_cache(batch: int, max_len: int, cfg: ArchConfig, dtype):
    a = cfg.attention
    if a.kv_lora_rank:
        return mla_init_cache(batch, max_len, a, dtype)
    return gqa_init_cache(batch, max_len, a, dtype)


def attn_decode(p, cache, x, pos, cfg: ArchConfig, window: int,
                impl: str | None = None, tables=None, page_size: int = 0):
    a = cfg.attention
    if a.kv_lora_rank:
        # MLA decode attends in the latent space already ((B,H,1,S) scores
        # against the 576-float cache rows) — the absorbed ref path *is*
        # the memory-lean kernel here
        return mla_decode(p, cache, x, pos, a, window, tables=tables,
                          page_size=page_size)
    return gqa_decode(p, cache, x, pos, a, window, impl=impl,
                      tables=tables, page_size=page_size)


def attn_prefill(p, cache, x, positions, pos0, cfg: ArchConfig, window: int,
                 impl: str | None = None, tables=None, page_size: int = 0):
    a = cfg.attention
    if a.kv_lora_rank:
        return mla_prefill(p, cache, x, positions, pos0, a, window,
                           tables=tables, page_size=page_size)
    return gqa_prefill(p, cache, x, positions, pos0, a, window, impl=impl,
                       tables=tables, page_size=page_size)
