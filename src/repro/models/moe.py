"""Mixture-of-Experts layer: top-k router + capacity-based dispatch.

TPU-native design (GShard/Mixtral style): tokens are scattered into a dense
``(E, C, d)`` expert buffer (capacity C per expert), experts run as one
batched einsum sharded over the ``model`` axis (expert parallelism — GSPMD
inserts the all-to-all at the token->expert resharding boundary), and results
are combined with the router probabilities. Tokens overflowing an expert's
capacity are dropped (contribute zero), the standard TPU MoE trade-off.

Also computes the switch-transformer auxiliary load-balance loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import dense_init
from repro.models.mlp import init_mlp, mlp_forward


def init_moe(key, d_model: int, m: MoEConfig, dtype):
    kr, ke, ks = jax.random.split(key, 3)
    k1, k2, k3 = jax.random.split(ke, 3)
    E, F = m.num_experts, m.expert_dim
    p = {
        "router": dense_init(kr, d_model, (E,), jnp.float32),
        "wi": dense_init(k1, d_model, (E, F), dtype).transpose(1, 0, 2),
        "wu": dense_init(k2, d_model, (E, F), dtype).transpose(1, 0, 2),
        "wd": dense_init(k3, F, (E, d_model), dtype).transpose(1, 0, 2),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks, d_model,
                               m.num_shared_experts * m.shared_expert_dim
                               if m.shared_expert_dim else m.expert_dim,
                               dtype)
    return p


def capacity(tokens: int, m: MoEConfig) -> int:
    c = int(tokens * m.top_k / m.num_experts * m.capacity_factor)
    return max(8, min(tokens, c))


def moe_forward(p, x, m: MoEConfig, *, full_capacity: bool = False,
                valid=None):
    """x: (B, S, d) -> (y, aux_loss).

    ``full_capacity=True`` sizes the expert buffer at C=T so no token is
    ever dropped (each token routes to K *distinct* experts, so per-expert
    load is at most T). That removes the only cross-token coupling in the
    layer, making per-token outputs independent of batch composition — the
    contract the serving engine relies on for bit-exact continuous batching
    (idle-slot garbage tokens must not perturb live requests). Training
    keeps the capped capacity (the standard TPU drop trade-off).

    ``valid`` (flat (T,) bool) excludes tokens (prompt padding in chunked
    prefill) from routing: they claim no buffer slot and contribute only
    the shared-expert output."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E, K = m.num_experts, m.top_k
    C = T if full_capacity else capacity(T, m)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (T,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)               # (T,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- position of each (token, choice) within its expert ----------------
    # one-hot over experts for each of the K choices: (T, K, E)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)
    if valid is not None:
        onehot = onehot * valid.reshape(T, 1, 1).astype(jnp.int32)
        gate_vals = gate_vals * valid.reshape(T, 1).astype(gate_vals.dtype)
    # rank of each choice within its expert, counted over flattened (T*K)
    flat = onehot.reshape(T * K, E)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat)           # (T*K, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(T, K)  # (T,K)
    keep = pos < C
    if valid is not None:
        # invalid tokens must not scatter into (and clobber) a live slot
        keep &= valid.reshape(T, 1)
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # --- scatter tokens into the (E, C, d) buffer ---------------------------
    slot = gate_idx * C + jnp.where(keep, pos, C * E)           # OOB -> drop
    buf = jnp.zeros((E * C + 1, d), x.dtype)
    # each token may occupy up to K slots
    buf = buf.at[slot.reshape(-1)].set(
        jnp.repeat(xt, K, axis=0), mode="drop")
    buf = buf[:-1].reshape(E, C, d)

    # --- expert computation (sharded over experts) --------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["wd"])                # (E,C,d)

    # --- gather back ---------------------------------------------------------
    out_flat = out.reshape(E * C, d)
    tok_out = out_flat[jnp.clip(slot, 0, E * C - 1).reshape(-1)]
    tok_out = tok_out.reshape(T, K, d) * gate_vals[..., None].astype(x.dtype)
    y = jnp.sum(tok_out, axis=1).reshape(B, S, d)

    if "shared" in p:
        y = y + mlp_forward(p["shared"], x)

    # --- load-balance auxiliary loss (switch transformer eq. 4) -------------
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(jnp.sum(onehot, axis=1).astype(jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce) * m.router_aux_weight
    return y, aux
