"""SwiGLU MLP (dense FFN)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, (d_ff,), dtype),   # gate
        "wu": dense_init(k2, d_model, (d_ff,), dtype),   # up
        "wd": dense_init(k3, d_ff, (d_model,), dtype),   # down
    }


def mlp_forward(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["wi"])
    u = jnp.einsum("bsd,df->bsf", x, p["wu"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p["wd"])
