"""Test-support utilities (no runtime dependencies on the training stack)."""
from __future__ import annotations


class FakeMesh:
    """axis_names/shape-only mesh stand-in for spec logic (sanitize_spec /
    param_spec read nothing else), so production mesh shapes — 16x16,
    2x16x16 — can be exercised without allocating devices."""

    def __init__(self, axes: dict):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)

