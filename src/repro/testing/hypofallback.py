"""Deterministic fallback for the tiny subset of the ``hypothesis`` API the
test suite uses (``given``/``settings``/``strategies``), for containers where
hypothesis is not installed (this repo cannot assume extra deps; CI installs
the real thing and takes precedence via the try/except import in the tests).

Unlike hypothesis there is no shrinking or example database — each strategy
draws from a PRNG seeded by the test's qualified name, always including the
boundary values, so runs are reproducible and failures re-fire on re-run.
"""
from __future__ import annotations

import random

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, sampler):
        self._sampler = sampler

    def sample(self, rng: random.Random):
        return self._sampler(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        def s(rng):
            r = rng.random()
            if r < 0.15:
                return min_value
            if r < 0.3:
                return max_value
            return rng.randint(min_value, max_value)
        return _Strategy(s)

    @staticmethod
    def floats(min_value: float, max_value: float, **_) -> _Strategy:
        def s(rng):
            r = rng.random()
            if r < 0.15:
                return min_value
            if r < 0.3:
                return max_value
            return min_value + (max_value - min_value) * rng.random()
        return _Strategy(s)

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        vals = list(elements)
        return _Strategy(lambda rng: vals[rng.randrange(len(vals))])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)


st = _Strategies()


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_):
    """Records max_examples on the (already ``given``-wrapped) test fn."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    """Kwargs-form ``@given``: runs the test over deterministic draws."""
    def deco(fn):
        # NOT functools.wraps: pytest follows __wrapped__ to the original
        # signature and would treat the strategy params as fixtures.
        def wrapper(*args, **kw):
            # read from the wrapper (@settings outside @given) or from the
            # wrapped fn (@settings inside @given) — hypothesis allows both
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                _DEFAULT_EXAMPLES))
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                example = {k: s.sample(rng) for k, s in strategies.items()}
                fn(*args, **example, **kw)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__qualname__ = fn.__qualname__
        return wrapper
    return deco
