"""Mamba2-1.3B attention-free SSM (SSD / state-space duality).  [arXiv:2405.21060]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="decoder",
    num_layers=48,
    d_model=2048,
    d_ff=0,                     # attention/MLP-free: SSD blocks only
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=128),
    block="ssm",
    long_context_window=0,       # natively sub-quadratic (O(1) decode state)
    source="arXiv:2405.21060 (Mamba-2 SSD)",
)
