"""GoogLeNet (Inception v1) with both auxiliary classifiers
(paper Table 2: 13,378,280 params including aux classifiers).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="googlenet",
    family="conv",
    conv_arch="googlenet",
    num_layers=22, d_model=0, d_ff=0, vocab_size=0,
    image_size=224, num_classes=1000,
    scan_layers=False,
    source="Theano-MPI paper Table 2 / arXiv:1409.4842",
)
