"""VGGNet-19-ish (paper Table 2 reports VGG 'Depth 19', 138,357,544 params —
that parameter count is VGG-16's; we implement VGG-16 to match the count).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="vggnet",
    family="conv",
    conv_arch="vgg16",
    num_layers=16, d_model=0, d_ff=0, vocab_size=0,
    image_size=224, num_classes=1000,
    scan_layers=False,
    source="Theano-MPI paper Table 2 / arXiv:1409.1556",
)
