"""Llama-3.2-1B small llama3 dense decoder.  [hf:meta-llama/Llama-3.2-1B]"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="llama3.2-1b",
    family="decoder",
    num_layers=16,
    d_model=2048,
    d_ff=8192,
    vocab_size=128256,
    attention=AttentionConfig(
        num_heads=32, num_kv_heads=8, head_dim=64, rope_theta=500_000.0),
    block="attn",
    tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B",
)
