"""Minitron-8B pruned Nemotron dense decoder.  [arXiv:2407.14679]"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="decoder",
    num_layers=32,
    d_model=4096,
    d_ff=16384,
    vocab_size=256000,
    attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128),
    block="attn",
    source="arXiv:2407.14679 (Minitron pruned Nemotron-4)",
)
