"""Llama-4-Scout-17B-16E MoE with early fusion.  [hf:meta-llama/Llama-4-Scout-17B-16E]

16 routed experts top-1 + 1 shared expert; vision frontend STUB (early-fusion
patch embeddings via input_specs()).
"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="decoder",
    num_layers=48,
    d_model=5120,
    d_ff=16384,                  # dense interleaved-layer FFN
    vocab_size=202048,
    attention=AttentionConfig(num_heads=40, num_kv_heads=8, head_dim=128,
                              rope_theta=500_000.0),
    moe=MoEConfig(num_experts=16, top_k=1, expert_dim=8192,
                  num_shared_experts=1, shared_expert_dim=8192,
                  moe_every=1),  # Scout: every layer MoE (interleave step 1)
    block="attn",
    modality="vlm",
    num_image_tokens=1024,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
