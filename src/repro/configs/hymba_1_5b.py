"""Hymba-1.5B hybrid-head (parallel attention ∥ mamba) decoder.  [arXiv:2411.13676]

Each block runs attention heads and SSM heads IN PARALLEL on the same input
and fuses normalized outputs. 128 learnable meta tokens are prepended; most
layers use sliding-window attention, every 16th (plus first/last) is global.
"""
from repro.configs.base import ArchConfig, AttentionConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="decoder",
    num_layers=32,
    d_model=1600,
    d_ff=5504,
    vocab_size=32001,
    attention=AttentionConfig(num_heads=25, num_kv_heads=5, head_dim=64,
                              sliding_window=1024),
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, chunk=128),
    block="hybrid",
    num_meta_tokens=128,
    global_attn_every=16,
    long_context_window=0,       # natively sub-quadratic (sw + O(1) ssm)
    source="arXiv:2411.13676 (Hymba)",
)
