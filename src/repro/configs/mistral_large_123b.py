"""Mistral-Large-123B dense decoder.  [hf:mistralai/Mistral-Large-Instruct-2407]"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="decoder",
    num_layers=88,
    d_model=12288,
    d_ff=28672,
    vocab_size=32768,
    attention=AttentionConfig(num_heads=96, num_kv_heads=8, head_dim=128,
                              rope_theta=1_000_000.0),
    block="attn",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)
