from repro.configs.base import (ArchConfig, AttentionConfig, MoEConfig,
                                SSMConfig, InputShape, INPUT_SHAPES, reduced)
from repro.configs.registry import (get_config, get_smoke_config, get_shape,
                                    list_archs, ASSIGNED_ARCHS, PAPER_ARCHS)
