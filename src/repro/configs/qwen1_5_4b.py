"""Qwen1.5-4B-class dense decoder with QKV bias.  [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="decoder",
    num_layers=40,
    d_model=2560,
    d_ff=6912,
    vocab_size=151936,
    attention=AttentionConfig(
        num_heads=20, num_kv_heads=20, head_dim=128, qkv_bias=True,
        rope_theta=1_000_000.0),
    block="attn",
    source="hf:Qwen/Qwen1.5-0.5B (scaled family config per assignment)",
)
