"""DeepSeek-V2-Lite-16B: MLA attention + fine-grained MoE.  [arXiv:2405.04434]

MLA: kv_lora_rank=512, qk_rope=64, qk_nope=128, v_head=128, 16 heads.
MoE: 64 routed experts top-6 + 2 shared, expert_dim=1408, first layer dense.
(The assignment note "160 routed" belongs to DeepSeek-V2-236B; the V2-Lite
column of arXiv:2405.04434 Table 1 is 64 routed / 2 shared, which we follow —
consistent with the primary "MoE 64e top-6" assignment spec.)
"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="decoder",
    num_layers=27,
    d_model=2048,
    d_ff=10944,                  # dense-layer FFN (first_k_dense)
    vocab_size=102400,
    attention=AttentionConfig(
        num_heads=16, num_kv_heads=16, head_dim=192,  # = nope+rope
        kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, expert_dim=1408,
                  num_shared_experts=2, shared_expert_dim=2816,
                  first_k_dense=1),
    block="attn",
    source="arXiv:2405.04434 (DeepSeek-V2-Lite)",
)
