"""SeamlessM4T-Large-v2 encoder-decoder multimodal backbone.  [arXiv:2308.11596]

The speech frontend (mel-spectrogram + conformer feature extractor) is a STUB:
``input_specs()`` provides precomputed frame embeddings (B, T_src, d_model).
This config is the text/unit transformer backbone (24L enc + 24L dec).
"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,               # decoder layers
    num_encoder_layers=24,
    encoder_seq_len=4096,        # stub frame count for full-size lowering
    d_model=1024,
    d_ff=8192,
    vocab_size=256206,
    attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64),
    block="attn",
    modality="audio",
    source="arXiv:2308.11596 (SeamlessM4T v2)",
)
