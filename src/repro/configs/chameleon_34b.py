"""Chameleon-34B early-fusion VLM (VQ image tokens).  [arXiv:2405.09818]

The VQ-VAE image tokenizer is a frontend STUB: ``input_specs()`` provides
precomputed image-token embeddings; this config is the fused decoder backbone.
Chameleon uses qk-norm for training stability.
"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="decoder",
    num_layers=48,
    d_model=8192,
    d_ff=22016,
    vocab_size=65536,
    attention=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128,
                              qk_norm=True),
    block="attn",
    modality="vlm",
    num_image_tokens=1024,      # VQ tokens per image (32x32 grid)
    source="arXiv:2405.09818 (Chameleon)",
)
