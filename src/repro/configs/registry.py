"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, INPUT_SHAPES, InputShape, reduced

_MODULES = {
    "qwen1.5-4b": "qwen1_5_4b",
    "llama3.2-1b": "llama3_2_1b",
    "mamba2-1.3b": "mamba2_1_3b",
    "minitron-8b": "minitron_8b",
    "mistral-large-123b": "mistral_large_123b",
    "chameleon-34b": "chameleon_34b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "hymba-1.5b": "hymba_1_5b",
    # the paper's own benchmark models
    "alexnet": "alexnet",
    "vggnet": "vggnet",
    "googlenet": "googlenet",
}

ASSIGNED_ARCHS = [k for k in _MODULES if k not in ("alexnet", "vggnet", "googlenet")]
PAPER_ARCHS = ["alexnet", "vggnet", "googlenet"]


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    return reduced(get_config(arch))


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
