"""Config system: architecture configs, input shapes, CLI overrides.

Every assigned architecture gets one ``<arch>.py`` exporting ``CONFIG``; the
registry resolves ``--arch <id>`` and can derive a reduced smoke variant.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any


# ---------------------------------------------------------------------------
# Input shapes (assigned): name -> (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Layer / block descriptors
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    # MLA (DeepSeek-V2): latent KV compression. 0 disables.
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0   # rope sub-dim for MLA (k_rope shared across heads)
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    # sliding window; 0 = full/causal attention
    sliding_window: int = 0
    rope_theta: float = 10_000.0
    # blockwise (flash-style) attention KV block size; 0 = naive attention
    # (the paper-baseline). Enabled per-experiment in §Perf hillclimbs.
    block_kv: int = 0
    # unroll the KV-block scan (dry-run costing: scan bodies are counted
    # once by XLA, so unrolling keeps the roofline honest)
    block_unroll: bool = False
    # attention implementation: "" / "auto" (flash where Pallas compiles,
    # einsum ref elsewhere) | "flash" (Pallas tiled kernels) | "ref"
    # (einsum oracles) | "blockwise" (lax.scan online softmax). The
    # REPRO_ATTN_IMPL env var overrides; see models/attention.py.
    attn_impl: str = ""


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_dim: int               # d_ff per expert
    num_shared_experts: int = 0
    shared_expert_dim: int = 0    # d_ff of the fused shared expert(s)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # layers that stay dense (e.g. deepseek first layer); dense layers use
    # ``ArchConfig.d_ff`` as their hidden size.
    first_k_dense: int = 0
    moe_every: int = 1            # apply MoE every Nth layer (1 = all)


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int                # N (ssm_state)
    head_dim: int = 64            # P
    expand: int = 2               # d_inner = expand * d_model
    chunk: int = 128              # SSD chunk length
    conv_width: int = 4
    ngroups: int = 1


@dataclass(frozen=True)
class ArchConfig:
    """One architecture. ``family`` picks the executor:

    - "decoder":  decoder-only transformer (dense / moe / ssm / hybrid blocks)
    - "encdec":   encoder-decoder transformer
    - "conv":     image classification convnet (paper's own models)
    """
    name: str
    family: str
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: AttentionConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # block layout: "attn" (dense), "ssm" (mamba), "hybrid" (attn ∥ ssm)
    block: str = "attn"
    # modality stub: "text" | "vlm" | "audio"  (vlm/audio consume precomputed
    # frontend embeddings through input_specs())
    modality: str = "text"
    num_meta_tokens: int = 0      # hymba learnable prefix tokens
    # hybrid: every Nth layer uses full attention, rest sliding window
    global_attn_every: int = 0
    # encdec
    num_encoder_layers: int = 0
    encoder_seq_len: int = 2048   # stub-frontend frame count for enc-dec
    # vlm: fraction of the sequence that is image-patch embeddings
    num_image_tokens: int = 0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"       # compute dtype
    param_dtype: str = "float32"
    # conv family
    conv_arch: str = ""           # "alexnet" | "vgg16" | "googlenet"
    image_size: int = 224
    num_classes: int = 1000
    # long-context variant: window applied to full-attention layers when the
    # input shape is long_500k (sub-quadratic requirement). 0 = arch is
    # natively sub-quadratic (ssm) or must skip.
    long_context_window: int = 8192
    # provenance
    source: str = ""
    remat: bool = True
    scan_layers: bool = True

    # -- derived -----------------------------------------------------------
    def head_dim(self) -> int:
        a = self.attention
        if a is None:
            return 0
        return a.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        total += d  # final norm
        per_layer = 0
        a = self.attention
        if self.family in ("decoder", "encdec") and self.block in ("attn", "hybrid") and a:
            if a.kv_lora_rank:  # MLA
                qd = a.num_heads * (a.qk_nope_dim + a.qk_rope_dim)
                per_layer += d * qd
                per_layer += d * (a.kv_lora_rank + a.qk_rope_dim)
                per_layer += a.kv_lora_rank * a.num_heads * (a.qk_nope_dim + a.v_head_dim)
                per_layer += a.num_heads * a.v_head_dim * d
            else:
                per_layer += d * a.num_heads * a.head_dim  # q
                per_layer += 2 * d * a.num_kv_heads * a.head_dim  # k,v
                per_layer += a.num_heads * a.head_dim * d  # o
                if a.qkv_bias:
                    per_layer += (a.num_heads + 2 * a.num_kv_heads) * a.head_dim
        if self.block in ("ssm", "hybrid") and self.ssm:
            s = self.ssm
            d_inner = s.expand * d
            nheads = d_inner // s.head_dim
            per_layer += d * (2 * d_inner + 2 * s.ngroups * s.state_dim + nheads)
            per_layer += d_inner * d  # out proj
            per_layer += s.conv_width * (d_inner + 2 * s.ngroups * s.state_dim)
            per_layer += 2 * nheads  # A, D
        if self.moe:
            m = self.moe
            n_moe = max(0, (L - m.first_k_dense + m.moe_every - 1) // m.moe_every)
            n_dense = L - n_moe
            per_layer = per_layer  # attention handled above
            moe_ffn = m.num_experts * 3 * d * m.expert_dim + d * m.num_experts
            if m.num_shared_experts:
                moe_ffn += 3 * d * m.shared_expert_dim
            total += n_moe * moe_ffn + n_dense * 3 * d * self.d_ff
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff  # SwiGLU
        per_layer += 2 * d  # norms
        total += L * per_layer
        if self.family == "encdec":
            # encoder layers: self-attn + ffn; decoder already counted has
            # cross-attn extra
            enc_layer = 0
            if a:
                enc_layer += 2 * (d * a.num_heads * a.head_dim + 2 * d * a.num_kv_heads * a.head_dim + a.num_heads * a.head_dim * d) // 2
            enc_layer += 3 * d * self.d_ff + 2 * d
            total += self.num_encoder_layers * enc_layer
            # cross attention in decoder
            if a:
                total += L * (d * a.num_heads * a.head_dim + 2 * d * a.num_kv_heads * a.head_dim + a.num_heads * a.head_dim * d + d)
        return total

    def active_param_count(self) -> int:
        """Parameters activated per token (MoE top-k)."""
        if not self.moe:
            return self.param_count()
        m = self.moe
        d, L = self.d_model, self.num_layers
        n_moe = max(0, (L - m.first_k_dense + m.moe_every - 1) // m.moe_every)
        inactive = n_moe * (m.num_experts - m.top_k) * 3 * d * m.expert_dim
        return self.param_count() - inactive

    def with_overrides(self, **kw: Any) -> "ArchConfig":
        return replace(self, **kw)


def with_attn_impl(cfg: ArchConfig, impl: str | None) -> ArchConfig:
    """Pin the attention implementation on a config (the ``--attn-impl``
    CLI knob and ``Engine(attn_impl=...)`` both route through here).
    No-op when ``impl`` is falsy or the arch has no attention block
    (pure-SSM families), so a global flag can sweep every arch."""
    if not impl or cfg.attention is None:
        return cfg
    return replace(cfg, attention=replace(cfg.attention, attn_impl=impl))


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant: 2 layers, d_model<=512, <=4 experts, small vocab."""
    d = min(cfg.d_model, 256)
    kw: dict[str, Any] = dict(
        num_layers=2,
        d_model=d,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        encoder_seq_len=min(cfg.encoder_seq_len, 64),
        num_image_tokens=min(cfg.num_image_tokens, 16),
        num_meta_tokens=min(cfg.num_meta_tokens, 8),
        scan_layers=False,
        remat=False,
    )
    if cfg.attention is not None:
        a = cfg.attention
        heads = min(a.num_heads, 4)
        kvh = max(1, min(a.num_kv_heads, heads))
        hd = 32
        kw["attention"] = replace(
            a, num_heads=heads, num_kv_heads=kvh, head_dim=hd,
            kv_lora_rank=64 if a.kv_lora_rank else 0,
            qk_rope_dim=16 if a.kv_lora_rank else 0,
            qk_nope_dim=16 if a.kv_lora_rank else 0,
            v_head_dim=hd if a.kv_lora_rank else 0,
            sliding_window=min(a.sliding_window, 32) if a.sliding_window else 0,
        )
    if cfg.moe is not None:
        m = cfg.moe
        kw["moe"] = replace(
            m, num_experts=4, top_k=min(m.top_k, 2),
            expert_dim=128,
            num_shared_experts=min(m.num_shared_experts, 1),
            shared_expert_dim=128 if m.num_shared_experts else 0,
            first_k_dense=min(m.first_k_dense, 1),
        )
    if cfg.ssm is not None:
        s = cfg.ssm
        kw["ssm"] = replace(s, state_dim=min(s.state_dim, 16), head_dim=32,
                            chunk=16)
    if cfg.family == "conv":
        kw = dict(num_layers=cfg.num_layers, d_model=cfg.d_model, d_ff=cfg.d_ff,
                  vocab_size=cfg.vocab_size, image_size=96, num_classes=16,
                  scan_layers=False, remat=False)
    return replace(cfg, **kw)
