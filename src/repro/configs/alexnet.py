"""AlexNet — the paper's primary benchmark model (Table 2: 60,965,224 params).

[Krizhevsky et al. 2012; theano_alexnet reference implementation]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="alexnet",
    family="conv",
    conv_arch="alexnet",
    num_layers=8, d_model=0, d_ff=0, vocab_size=0,
    image_size=227, num_classes=1000,
    scan_layers=False,
    source="Theano-MPI paper Table 2 / NIPS2012",
)
