"""repro: a JAX reproduction of Theano-MPI grown toward production scale.

Importing any ``repro.*`` module installs the jax API compat shims (see
``repro._compat``) so the rest of the codebase can target one API surface.
"""
from repro import _compat as _compat

_compat.install()
