"""Parallel loading (paper §3.3, Algorithm 1).

Theano-MPI spawns a loader process per trainer that: loads a batch file from
disk, preprocesses (mean-subtract / crop / mirror), copies host->device, and
hands the trainer a ready device buffer — all overlapped with the fwd/bwd of
the previous batch.

JAX adaptation: a background thread (numpy IO and ``jax.device_put`` release
the GIL; dispatch is async) runs the same state machine with a bounded
double-buffer queue. ``mode`` messages ("train"/"val"/"stop") follow Alg 1.
"""
from __future__ import annotations

import queue
import threading
import time

import jax
import numpy as np


def preprocess_images(batch: dict, image_mean, crop: int, rng: np.random.Generator,
                      train: bool = True) -> dict:
    """Alg 1 steps 10-11: mean-subtract, random-crop, mirror."""
    x = batch["images"]
    x = x - image_mean
    H = x.shape[1]
    if crop and crop < H:
        if train:
            oy, ox = rng.integers(0, H - crop + 1, 2)
        else:
            oy = ox = (H - crop) // 2
        x = x[:, oy:oy + crop, ox:ox + crop, :]
        if train and rng.random() < 0.5:
            x = x[:, :, ::-1, :]
    out = dict(batch)
    out["images"] = np.ascontiguousarray(x, np.float32)
    return out


class LoaderError(RuntimeError):
    """A ParallelLoader worker-thread failure, re-raised in the consumer."""


class _Failure:
    """Sentinel carrying the worker thread's exception to ``get()``."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class ParallelLoader:
    """Background loader thread implementing Alg 1's overlap.

    load(file) -> preprocess -> device_put, pipelined ``depth`` batches ahead
    of the consumer. ``get()`` blocks only if the loader is behind (i.e.
    loading is slower than one training iteration, the paper's caveat).

    Failure semantics: an exception in the worker thread (missing file,
    corrupt npz, device_put failure) is propagated to the caller as a
    :class:`LoaderError` from the next ``get()`` — it never leaves the
    consumer blocked forever. ``get()`` additionally bounds its wait with
    ``timeout`` seconds (default 120) and raises ``TimeoutError`` with a
    diagnosis when the loader thread has silently died or stalled.
    """

    def __init__(self, files: list[str], *, image_mean=None, crop: int = 0,
                 depth: int = 2, mode: str = "train", sharding=None,
                 seed: int = 0, epochs: int = 1, io_delay_ms: float = 0.0,
                 timeout: float | None = 120.0):
        self.files = files
        self.image_mean = image_mean
        self.crop = crop
        self.mode = mode
        self.sharding = sharding
        self.epochs = epochs
        self.io_delay_ms = io_delay_ms  # simulated remote-disk latency (§3.3)
        self.timeout = timeout
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._ctl: queue.Queue = queue.Queue()
        self._rng = np.random.default_rng(seed)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    # -- loader state machine (Alg 1) ---------------------------------------
    def _run(self):
        try:
            for _ in range(self.epochs):
                for path in self.files:
                    # check for a mode/stop message (Alg 1 step 13-17)
                    try:
                        msg = self._ctl.get_nowait()
                        if msg == "stop":
                            self._q.put(None)
                            return
                        self.mode = msg
                    except queue.Empty:
                        pass
                    if self.io_delay_ms:
                        time.sleep(self.io_delay_ms / 1e3)
                    raw = dict(np.load(path))
                    if "images" in raw and self.image_mean is not None:
                        raw = preprocess_images(raw, self.image_mean,
                                                self.crop, self._rng,
                                                train=(self.mode == "train"))
                    if self.sharding is not None:
                        dev = {k: jax.device_put(v, self.sharding.get(k))
                               for k, v in raw.items()}
                    else:
                        dev = {k: jax.device_put(v) for k, v in raw.items()}
                    # block until the consumer frees a slot (double buffer)
                    self._q.put(dev)
        except BaseException as e:  # noqa: BLE001 — must reach the consumer
            # a raising worker used to die silently and leave get() hanging
            # on an empty queue forever; hand the exception over instead
            self._q.put(_Failure(e))
            return
        self._q.put(None)

    # -- consumer API --------------------------------------------------------
    def get(self):
        """Next ready-on-device batch, or None at end of stream.

        Raises :class:`LoaderError` if the worker thread failed, and
        ``TimeoutError`` after ``self.timeout`` seconds without a batch."""
        try:
            item = self._q.get(timeout=self.timeout)
        except queue.Empty:
            alive = self._thread.is_alive()
            raise TimeoutError(
                f"ParallelLoader.get() waited {self.timeout:.0f}s without a "
                f"batch (loader thread "
                f"{'stalled' if alive else 'died without reporting'}; "
                f"{len(self.files)} files, depth={self._q.maxsize})")
        if isinstance(item, _Failure):
            # terminal: re-queue so later get()/stop() calls also see it
            self._q.put(item)
            raise LoaderError(
                f"ParallelLoader worker thread failed: "
                f"{type(item.exc).__name__}: {item.exc}") from item.exc
        return item

    def set_mode(self, mode: str):
        self._ctl.put(mode)

    def stop(self):
        self._ctl.put("stop")
        # drain so the thread can observe the message (None and _Failure
        # are both terminal)
        try:
            while not isinstance(self._q.get_nowait(), (type(None),
                                                        _Failure)):
                pass
        except queue.Empty:
            pass
        self._thread.join(timeout=5)

    def __iter__(self):
        while True:
            b = self.get()
            if b is None:
                return
            yield b


class SyncLoader:
    """Non-overlapped baseline (load inside the training loop) — the
    counterfactual the paper's Alg 1 is compared against."""

    def __init__(self, files: list[str], *, image_mean=None, crop: int = 0,
                 mode: str = "train", sharding=None, seed: int = 0,
                 epochs: int = 1, io_delay_ms: float = 0.0):
        self.io_delay_ms = io_delay_ms
        self.files = files
        self.image_mean = image_mean
        self.crop = crop
        self.mode = mode
        self.sharding = sharding
        self.epochs = epochs
        self._rng = np.random.default_rng(seed)

    def __iter__(self):
        for _ in range(self.epochs):
            for path in self.files:
                if self.io_delay_ms:
                    time.sleep(self.io_delay_ms / 1e3)
                raw = dict(np.load(path))
                if "images" in raw and self.image_mean is not None:
                    raw = preprocess_images(raw, self.image_mean, self.crop,
                                            self._rng,
                                            train=(self.mode == "train"))
                if self.sharding is not None:
                    yield {k: jax.device_put(v, self.sharding.get(k))
                           for k, v in raw.items()}
                else:
                    yield {k: jax.device_put(v) for k, v in raw.items()}
