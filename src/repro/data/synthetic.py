"""Synthetic data sources (deterministic, seeded) for LM and image training,
plus on-disk batch-file materialization used by the parallel-loading
pipeline (the paper stores ImageNet as batch files on disk, Alg 1)."""
from __future__ import annotations

import os

import numpy as np


class LMTokenSource:
    """Deterministic pseudo-corpus: Zipfian tokens with a learnable bigram
    structure so small models show decreasing loss."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.rng = np.random.default_rng(seed)
        # low-rank bigram transition: next ~ (cur * a + b) mod V with noise
        self.a = int(self.rng.integers(2, 7))
        self.b = int(self.rng.integers(1, vocab_size))

    def batch(self, batch_size: int, step: int):
        rng = np.random.default_rng((step + 1) * 7919)
        first = rng.integers(0, self.vocab, (batch_size, 1))
        toks = [first]
        cur = first
        for _ in range(self.seq):
            nxt = (cur * self.a + self.b) % self.vocab
            noise = rng.integers(0, self.vocab, cur.shape)
            mask = rng.random(cur.shape) < 0.1
            cur = np.where(mask, noise, nxt)
            toks.append(cur)
        seq = np.concatenate(toks, axis=1)  # (B, S+1)
        return {"tokens": seq[:, :-1].astype(np.int32),
                "labels": seq[:, 1:].astype(np.int32)}


class ImageSource:
    """Synthetic class-conditional images (separable Gaussian blobs)."""

    def __init__(self, image_size: int, num_classes: int, seed: int = 0):
        self.size = image_size
        self.classes = num_classes
        rng = np.random.default_rng(seed)
        self.proto = rng.normal(0, 1, (num_classes, 8, 8, 3)).astype(np.float32)

    def batch(self, batch_size: int, step: int):
        rng = np.random.default_rng((step + 1) * 104729)
        labels = rng.integers(0, self.classes, (batch_size,))
        base = self.proto[labels]
        reps = self.size // 8 + 1
        imgs = np.tile(base, (1, reps, reps, 1))[:, :self.size, :self.size, :]
        imgs = imgs + rng.normal(0, 0.5, imgs.shape).astype(np.float32)
        return {"images": imgs.astype(np.float32),
                "labels": labels.astype(np.int32)}


def materialize_batch_files(source, out_dir: str, num_batches: int,
                            batch_size: int):
    """Write batches as .npz files on disk (the paper's batch-file layout).
    Returns the list of file paths."""
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for i in range(num_batches):
        b = source.batch(batch_size, i)
        path = os.path.join(out_dir, f"batch_{i:05d}.npz")
        np.savez(path, **b)
        paths.append(path)
    return paths
