"""Slot scheduler: FIFO admission, per-slot position/length tracking, and
mid-flight eviction of finished sequences.

All host-side bookkeeping, deliberately free of jax: the engine owns the
device arrays, the scheduler owns the request lifecycle —

    queued -> (admit) -> prefilling -> decoding -> (finish) -> freed
          \\-> (shed)                           \\-> (cancel) -> freed

A slot is a lane of the engine's fixed-size batch. Freed slots are reused
immediately by the next queued request; the decode step's shapes never
change, only the per-slot position/active vectors the scheduler exports.

SLO guardrails (DESIGN.md "Serve robustness") live at this layer because
they are pure request-lifecycle decisions:

- **Typed admission.** :meth:`submit` returns an :class:`AdmissionResult`
  — ``ACCEPTED`` with the request id, or a rejection
  (``REJECTED_QUEUE_FULL`` under the bounded queue). The result coerces
  to the rid (``int()``, dict key, ``==``), so accepted paths read like
  they always did; malformed or never-fits requests still raise
  ``ValueError`` (a caller bug, not load). Every rejection leaves the
  allocator and queue state untouched.
- **Bounded queue + shedding policy.** ``max_queue > 0`` bounds
  ``pending``; an arrival into a full queue is refused
  (``reject-newest``) or displaces the youngest queued request that
  carries no deadline (``reject-no-deadline``) — the policy knob trades
  arrival fairness against deadline goodput.
- **Cancellation.** :meth:`cancel` (queued or in-flight) and the
  engine-driven deadline cancels route through the same ``_finish`` path
  a natural completion uses, so pages/refcounts are released exactly as
  on finish. Terminal requests carry a ``finish_reason``:
  ``stop | cancel | deadline | shed``.
- **Bounded results + finish events.** ``finished`` keeps the newest
  ``finished_keep`` entries (a long-running server must not grow per
  request); :meth:`pop_finished` is the hand-off API. Accounting reads
  the monotonic ``finished_total`` / ``finish_log`` event stream instead
  of ``len(finished)`` — watermarks survive pops, drains and restores.

The scheduler also stamps the request lifecycle for telemetry: a request
carries ``t_submit``/``t_admit``/``t_prefill_done``/``t_finish``
(``clock`` seconds — ``time.perf_counter`` in production, a virtual
clock under ``serve.chaos``), and each phase is exported as an async
span (``serve/req/queued`` -> ``serve/req/prefill`` ->
``serve/req/decode``, keyed by request id) so a ``--trace-out`` Perfetto
file shows every request's queue wait, TTFT and decode tail overlapping
the engine's dispatch spans. All host-side; still no jax here.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

from repro.telemetry import trace

# admission statuses (AdmissionResult.status)
ACCEPTED = "accepted"
REJECTED_QUEUE_FULL = "rejected_queue_full"

# terminal finish_reason values
FINISH_STOP = "stop"          # eos / max_new reached
FINISH_CANCEL = "cancel"      # explicit cancel()
FINISH_DEADLINE = "deadline"  # past its deadline (engine-driven cancel)
FINISH_SHED = "shed"          # shed from the queue (never ran)

SHED_POLICIES = ("reject-newest", "reject-no-deadline")


class AdmissionResult:
    """Typed outcome of ``submit``: a status plus the request id.

    Coerces to the rid so accepted results drop into existing call sites
    (``results()[r]``, ``int(r)``, ``r == rid``); ``bool(r)`` answers
    "was it admitted to the queue". Rejections carry ``rid == -1`` and a
    human-readable ``reason``."""

    __slots__ = ("rid", "status", "reason")

    def __init__(self, rid: int, status: str, reason: str = ""):
        self.rid = rid
        self.status = status
        self.reason = reason

    @property
    def accepted(self) -> bool:
        return self.status == ACCEPTED

    def __bool__(self) -> bool:
        return self.accepted

    def __int__(self) -> int:
        return self.rid

    __index__ = __int__

    def __eq__(self, other) -> bool:
        if isinstance(other, AdmissionResult):
            return self.rid == other.rid and self.status == other.status
        if isinstance(other, int):
            return self.rid == other
        return NotImplemented

    def __hash__(self) -> int:
        return hash(self.rid)

    def __repr__(self) -> str:
        if self.accepted:
            return f"AdmissionResult(rid={self.rid})"
        return (f"AdmissionResult({self.status}"
                + (f", {self.reason!r}" if self.reason else "") + ")")


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    temperature == 0 is greedy; top_k == 0 and top_p >= 1 disable the
    respective filters. ``seed`` makes the request's sample stream
    deterministic (per-slot PRNG keys are folded from it)."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


@dataclass
class Request:
    tokens: list          # prompt token ids
    max_new: int
    sampling: SamplingParams = field(default_factory=SamplingParams)
    eos: int | None = None     # stop token (None: run to max_new)
    rid: int = -1              # assigned by the scheduler at submit
    # SLO budget (milliseconds from submit; None = no deadline)
    deadline_ms: float | None = None
    max_queue_ms: float | None = None
    # lifecycle timestamps (clock seconds; 0.0 = not reached yet)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_prefill_done: float = 0.0    # first token sampled: TTFT endpoint
    t_finish: float = 0.0
    finish_reason: str | None = None   # stop | cancel | deadline | shed

    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.t_submit if self.t_admit else 0.0

    @property
    def ttft(self) -> float:
        """Submit -> first token (queue wait + prefill + first sample)."""
        return (self.t_prefill_done - self.t_submit
                if self.t_prefill_done else 0.0)

    @property
    def deadline_at(self) -> float | None:
        """Absolute clock deadline, or None."""
        if self.deadline_ms is None:
            return None
        return self.t_submit + self.deadline_ms / 1e3

    def within_deadline(self) -> bool:
        """Did the request finish inside its budget? (vacuously true
        without one; false until finished.)"""
        if self.deadline_ms is None:
            return True
        return bool(self.t_finish) and self.t_finish <= self.deadline_at

    def to_state(self) -> dict:
        """Re-submittable host snapshot (drain/restore)."""
        s = self.sampling
        return {"tokens": list(self.tokens), "max_new": int(self.max_new),
                "eos": self.eos, "rid": int(self.rid),
                "deadline_ms": self.deadline_ms,
                "max_queue_ms": self.max_queue_ms,
                "sampling": {"temperature": s.temperature, "top_k": s.top_k,
                             "top_p": s.top_p, "seed": s.seed}}

    @classmethod
    def from_state(cls, d: dict) -> "Request":
        return cls(tokens=list(d["tokens"]), max_new=int(d["max_new"]),
                   sampling=SamplingParams(**d["sampling"]), eos=d["eos"],
                   rid=int(d["rid"]), deadline_ms=d.get("deadline_ms"),
                   max_queue_ms=d.get("max_queue_ms"))


@dataclass
class SlotState:
    """One live request bound to a slot."""
    req: Request
    pos: int = 0               # next cache write index (== tokens decoded)
    generated: list = field(default_factory=list)
    last_token: int = 0        # token to feed at the next decode step
    done: bool = False
    hit_tokens: int = 0        # prompt tokens served by the prefix cache


class SlotScheduler:
    """FIFO over a fixed pool of ``max_slots`` decode lanes.

    With a :class:`~repro.serve.cache.PageAllocator` attached, admission is
    additionally gated on page capacity: the head-of-line request admits
    only when its worst-case page need fits (``try_admit`` reserves it),
    and later requests never jump the queue — strict FIFO keeps admission
    deterministic under memory pressure. Finishing a request releases its
    pages back to the free list (prefix-cached pages survive for future
    hits)."""

    def __init__(self, max_slots: int, max_seq: int, allocator=None, *,
                 max_queue: int = 0, shed_policy: str = "reject-newest",
                 finished_keep: int = 4096, clock=None):
        if shed_policy not in SHED_POLICIES:
            raise ValueError(f"shed_policy must be one of {SHED_POLICIES}, "
                             f"got {shed_policy!r}")
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.allocator = allocator
        self.max_queue = max_queue            # 0 = unbounded (legacy)
        self.shed_policy = shed_policy
        self.finished_keep = finished_keep
        self.clock = clock or time.perf_counter
        self.pending: deque[Request] = deque()
        self.slots: list[SlotState | None] = [None] * max_slots
        self.finished: dict[int, SlotState] = {}
        self._next_rid = 0    # plain int (snapshot/restore needs the value)
        # monotonic accounting (survives pop_finished / drain / restore):
        self.finished_total = 0     # terminal events, any reason
        self.finished_dropped = 0   # results evicted by the retention window
        # event stream the engine drains each step for stats — one entry
        # per terminal request: dict(rid, reason, tokens, within_deadline,
        # had_deadline, slot) — bounded: the engine drains every step
        self.finish_log: deque = deque(maxlen=max(4 * finished_keep, 64))

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> AdmissionResult:
        if not req.tokens:
            raise ValueError("empty prompt")
        if req.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if len(req.tokens) + req.max_new > self.max_seq:
            raise ValueError(
                f"request needs {len(req.tokens) + req.max_new} cache rows, "
                f"pool holds {self.max_seq}")
        if self.allocator is not None:
            need = self.allocator.pages_needed(len(req.tokens) + req.max_new)
            if need > self.allocator.num_pages - 1:
                raise ValueError(
                    f"request needs {need} pages, pool holds "
                    f"{self.allocator.num_pages - 1}")
        if self.max_queue and len(self.pending) >= self.max_queue:
            if self.shed_policy == "reject-no-deadline":
                # displace the *youngest* queued request without a
                # deadline; an all-deadline queue falls back to
                # reject-newest. Youngest-first keeps the head (oldest,
                # closest to running) intact.
                victim = next((r for r in reversed(self.pending)
                               if r.deadline_ms is None), None)
                if victim is not None:
                    self.shed_queued(victim)
                    return self._accept(req)
            return AdmissionResult(
                -1, REJECTED_QUEUE_FULL,
                f"queue full ({len(self.pending)}/{self.max_queue})")
        return self._accept(req)

    def _accept(self, req: Request) -> AdmissionResult:
        req.rid = self._next_rid
        self._next_rid += 1
        req.t_submit = self.clock()
        trace.async_begin("serve/req/queued", req.rid,
                          prompt=len(req.tokens), max_new=req.max_new)
        self.pending.append(req)
        return AdmissionResult(req.rid, ACCEPTED)

    def resubmit(self, req: Request) -> None:
        """Drain/restore path: requeue a snapshotted request keeping its
        original rid (results stay keyed identically across the restart).
        Deadlines restart from the re-submit instant."""
        self._next_rid = max(self._next_rid, req.rid + 1)
        req.t_submit = self.clock()
        req.t_admit = req.t_prefill_done = req.t_finish = 0.0
        req.finish_reason = None
        trace.async_begin("serve/req/queued", req.rid,
                          prompt=len(req.tokens), max_new=req.max_new)
        self.pending.append(req)

    # -- admission ----------------------------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def admit(self) -> list[tuple[int, Request]]:
        """Bind queued requests to free slots (FIFO). Returns the new
        (slot, request) pairs; the engine prefill-fills each one."""
        placed = []
        for slot in self.free_slots():
            if not self.pending:
                break
            req = self.pending[0]
            hit = 0
            if self.allocator is not None:
                got = self.allocator.try_admit(slot, req.tokens, req.max_new)
                if got is None:
                    break    # head-of-line blocks until pages free up
                hit = got
            self.pending.popleft()
            req.t_admit = self.clock()
            trace.async_end("serve/req/queued", req.rid)
            trace.async_begin("serve/req/prefill", req.rid, slot=slot,
                              cached=hit)
            self.slots[slot] = SlotState(req=req, pos=len(req.tokens),
                                         last_token=req.tokens[-1],
                                         hit_tokens=hit)
            placed.append((slot, req))
        return placed

    def shed_queued(self, req: Request, reason: str = FINISH_SHED) -> None:
        """Remove a *queued* request (deadline unmeetable / queue budget
        blown). It never held a slot or pages — nothing to release."""
        self.pending.remove(req)
        trace.async_end("serve/req/queued", req.rid)
        self._terminal(req, reason, generated=[], slot=None)

    # -- decode bookkeeping -------------------------------------------------

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def queue_depth(self) -> int:
        return len(self.pending)

    def has_work(self) -> bool:
        return bool(self.pending) or self.num_active > 0

    def active_mask(self) -> list[bool]:
        return [s is not None for s in self.slots]

    def positions(self) -> list[int]:
        """Per-slot cache write index for the next decode step. Idle slots
        park at 0 — they rewrite (and causally hide) row 0 until reused."""
        return [s.pos if s is not None else 0 for s in self.slots]

    def feed_tokens(self) -> list[int]:
        return [s.last_token if s is not None else 0 for s in self.slots]

    def record_first_token(self, slot: int, token: int) -> None:
        """The prompt's continuation sampled from the prefill logits."""
        st = self.slots[slot]
        st.req.t_prefill_done = self.clock()
        trace.async_end("serve/req/prefill", st.req.rid)
        trace.async_begin("serve/req/decode", st.req.rid, slot=slot)
        self._record(slot, token)

    def record_step(self, tokens) -> list[int]:
        """Fold one decode step's sampled token per slot into the state.
        Advances positions, finishes/evicts, returns freed slots."""
        freed = []
        for slot, st in enumerate(self.slots):
            if st is None or st.done:
                continue
            st.pos += 1          # the step wrote cache row st.pos
            self._record(slot, int(tokens[slot]))
            if self.slots[slot] is None:
                freed.append(slot)
        return freed

    def _record(self, slot: int, token: int) -> None:
        st = self.slots[slot]
        st.generated.append(token)
        st.last_token = token
        req = st.req
        if (len(st.generated) >= req.max_new
                or (req.eos is not None and token == req.eos)):
            self._finish(slot, FINISH_STOP)

    def _finish(self, slot: int, reason: str) -> None:
        """The single terminal path for a slot-bound request — natural
        completion AND cancellation run through here, so pages/refcounts
        are released identically either way."""
        st = self.slots[slot]
        st.done = True
        req = st.req
        req.t_finish = self.clock()
        trace.async_end("serve/req/decode", req.rid,
                        tokens=len(st.generated), reason=reason)
        self.slots[slot] = None    # evict mid-flight; slot reusable
        if self.allocator is not None:
            self.allocator.release_slot(slot)
        self._terminal(req, reason, generated=st.generated, slot=slot,
                       state=st)

    def _terminal(self, req: Request, reason: str, *, generated, slot,
                  state: SlotState | None = None) -> None:
        req.finish_reason = reason
        if not req.t_finish:
            req.t_finish = self.clock()
        if state is None:
            state = SlotState(req=req, generated=list(generated), done=True)
        self.finished[req.rid] = state
        self.finished_total += 1
        self.finish_log.append({
            "rid": req.rid, "reason": reason, "tokens": len(state.generated),
            "within_deadline": req.within_deadline(),
            "had_deadline": req.deadline_ms is not None,
            "slot": slot})
        if self.finished_keep and len(self.finished) > self.finished_keep:
            oldest = next(iter(self.finished))
            del self.finished[oldest]
            self.finished_dropped += 1

    # -- cancellation -------------------------------------------------------

    def cancel(self, rid: int, reason: str = FINISH_CANCEL) -> bool:
        """Cancel a request wherever it is: queued (shed, nothing held) or
        in-flight (slot + pages released exactly as on finish, partial
        output kept). Returns False for unknown/already-finished rids."""
        rid = int(rid)
        for req in self.pending:
            if req.rid == rid:
                self.shed_queued(req, reason)
                return True
        for slot, st in enumerate(self.slots):
            if st is not None and st.req.rid == rid:
                self._finish(slot, reason)
                return True
        return False

    def cancel_past_deadline(self, now: float) -> list[int]:
        """Cancel every in-flight request past its deadline (the engine
        calls this at step boundaries). Returns the cancelled rids."""
        out = []
        for slot, st in enumerate(self.slots):
            if st is None:
                continue
            dl = st.req.deadline_at
            if dl is not None and now > dl:
                out.append(st.req.rid)
                self._finish(slot, FINISH_DEADLINE)
        return out

    # -- results ------------------------------------------------------------

    def results(self) -> dict[int, list]:
        return {rid: st.generated for rid, st in self.finished.items()}

    def finish_reasons(self) -> dict[int, str]:
        return {rid: st.req.finish_reason
                for rid, st in self.finished.items()}

    def pop_finished(self) -> dict[int, SlotState]:
        """Hand off (and forget) the finished-results map — the bounded-
        memory consumption API for a long-running server. Accounting is
        unaffected: it reads ``finished_total``/``finish_log``, not this
        map."""
        out = self.finished
        self.finished = {}
        return out
