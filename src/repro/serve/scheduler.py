"""Slot scheduler: FIFO admission, per-slot position/length tracking, and
mid-flight eviction of finished sequences.

All host-side bookkeeping, deliberately free of jax: the engine owns the
device arrays, the scheduler owns the request lifecycle —

    queued -> (admit) -> prefilling -> decoding -> (finish) -> freed

A slot is a lane of the engine's fixed-size batch. Freed slots are reused
immediately by the next queued request; the decode step's shapes never
change, only the per-slot position/active vectors the scheduler exports.

The scheduler also stamps the request lifecycle for telemetry: a request
carries ``t_submit``/``t_admit``/``t_prefill_done``/``t_finish``
(``time.perf_counter`` seconds), and each phase is exported as an async
span (``serve/req/queued`` -> ``serve/req/prefill`` ->
``serve/req/decode``, keyed by request id) so a ``--trace-out`` Perfetto
file shows every request's queue wait, TTFT and decode tail overlapping
the engine's dispatch spans. All host-side; still no jax here.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field

from repro.telemetry import trace


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    temperature == 0 is greedy; top_k == 0 and top_p >= 1 disable the
    respective filters. ``seed`` makes the request's sample stream
    deterministic (per-slot PRNG keys are folded from it)."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


@dataclass
class Request:
    tokens: list          # prompt token ids
    max_new: int
    sampling: SamplingParams = field(default_factory=SamplingParams)
    eos: int | None = None     # stop token (None: run to max_new)
    rid: int = -1              # assigned by the scheduler at submit
    # lifecycle timestamps (perf_counter seconds; 0.0 = not reached yet)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_prefill_done: float = 0.0    # first token sampled: TTFT endpoint
    t_finish: float = 0.0

    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.t_submit if self.t_admit else 0.0

    @property
    def ttft(self) -> float:
        """Submit -> first token (queue wait + prefill + first sample)."""
        return (self.t_prefill_done - self.t_submit
                if self.t_prefill_done else 0.0)


@dataclass
class SlotState:
    """One live request bound to a slot."""
    req: Request
    pos: int = 0               # next cache write index (== tokens decoded)
    generated: list = field(default_factory=list)
    last_token: int = 0        # token to feed at the next decode step
    done: bool = False
    hit_tokens: int = 0        # prompt tokens served by the prefix cache


class SlotScheduler:
    """FIFO over a fixed pool of ``max_slots`` decode lanes.

    With a :class:`~repro.serve.cache.PageAllocator` attached, admission is
    additionally gated on page capacity: the head-of-line request admits
    only when its worst-case page need fits (``try_admit`` reserves it),
    and later requests never jump the queue — strict FIFO keeps admission
    deterministic under memory pressure. Finishing a request releases its
    pages back to the free list (prefix-cached pages survive for future
    hits)."""

    def __init__(self, max_slots: int, max_seq: int, allocator=None):
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.allocator = allocator
        self.pending: deque[Request] = deque()
        self.slots: list[SlotState | None] = [None] * max_slots
        self.finished: dict[int, SlotState] = {}
        self._rid = itertools.count()

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> int:
        if not req.tokens:
            raise ValueError("empty prompt")
        if req.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if len(req.tokens) + req.max_new > self.max_seq:
            raise ValueError(
                f"request needs {len(req.tokens) + req.max_new} cache rows, "
                f"pool holds {self.max_seq}")
        if self.allocator is not None:
            need = self.allocator.pages_needed(len(req.tokens) + req.max_new)
            if need > self.allocator.num_pages - 1:
                raise ValueError(
                    f"request needs {need} pages, pool holds "
                    f"{self.allocator.num_pages - 1}")
        req.rid = next(self._rid)
        req.t_submit = time.perf_counter()
        trace.async_begin("serve/req/queued", req.rid,
                          prompt=len(req.tokens), max_new=req.max_new)
        self.pending.append(req)
        return req.rid

    # -- admission ----------------------------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def admit(self) -> list[tuple[int, Request]]:
        """Bind queued requests to free slots (FIFO). Returns the new
        (slot, request) pairs; the engine prefill-fills each one."""
        placed = []
        for slot in self.free_slots():
            if not self.pending:
                break
            req = self.pending[0]
            hit = 0
            if self.allocator is not None:
                got = self.allocator.try_admit(slot, req.tokens, req.max_new)
                if got is None:
                    break    # head-of-line blocks until pages free up
                hit = got
            self.pending.popleft()
            req.t_admit = time.perf_counter()
            trace.async_end("serve/req/queued", req.rid)
            trace.async_begin("serve/req/prefill", req.rid, slot=slot,
                              cached=hit)
            self.slots[slot] = SlotState(req=req, pos=len(req.tokens),
                                         last_token=req.tokens[-1],
                                         hit_tokens=hit)
            placed.append((slot, req))
        return placed

    # -- decode bookkeeping -------------------------------------------------

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def has_work(self) -> bool:
        return bool(self.pending) or self.num_active > 0

    def active_mask(self) -> list[bool]:
        return [s is not None for s in self.slots]

    def positions(self) -> list[int]:
        """Per-slot cache write index for the next decode step. Idle slots
        park at 0 — they rewrite (and causally hide) row 0 until reused."""
        return [s.pos if s is not None else 0 for s in self.slots]

    def feed_tokens(self) -> list[int]:
        return [s.last_token if s is not None else 0 for s in self.slots]

    def record_first_token(self, slot: int, token: int) -> None:
        """The prompt's continuation sampled from the prefill logits."""
        st = self.slots[slot]
        st.req.t_prefill_done = time.perf_counter()
        trace.async_end("serve/req/prefill", st.req.rid)
        trace.async_begin("serve/req/decode", st.req.rid, slot=slot)
        self._record(slot, token)

    def record_step(self, tokens) -> list[int]:
        """Fold one decode step's sampled token per slot into the state.
        Advances positions, finishes/evicts, returns freed slots."""
        freed = []
        for slot, st in enumerate(self.slots):
            if st is None or st.done:
                continue
            st.pos += 1          # the step wrote cache row st.pos
            self._record(slot, int(tokens[slot]))
            if self.slots[slot] is None:
                freed.append(slot)
        return freed

    def _record(self, slot: int, token: int) -> None:
        st = self.slots[slot]
        st.generated.append(token)
        st.last_token = token
        req = st.req
        if (len(st.generated) >= req.max_new
                or (req.eos is not None and token == req.eos)):
            st.done = True
            req.t_finish = time.perf_counter()
            trace.async_end("serve/req/decode", req.rid,
                            tokens=len(st.generated))
            self.finished[req.rid] = st
            self.slots[slot] = None    # evict mid-flight; slot reusable
            if self.allocator is not None:
                self.allocator.release_slot(slot)

    # -- results ------------------------------------------------------------

    def results(self) -> dict[int, list]:
        return {rid: st.generated for rid, st in self.finished.items()}
