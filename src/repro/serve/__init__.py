"""``repro.serve`` — the continuous-batching inference engine.

The training side of this repo keeps accelerators busy by overlapping
communication with compute; this package applies the same thesis to
serving: a fixed-slot request pool keeps the jitted decode step at one
static shape (it compiles exactly once and never retraces as requests
join/leave), chunked whole-prompt prefill replaces the token-by-token
forced-decode loop, and per-request sampling is fused into the decode
dispatch.

- ``engine``    — :class:`Engine`: admission -> chunked prefill -> batched
                  per-slot decode -> sampling -> eviction loop
- ``scheduler`` — FIFO admission + slot lifecycle bookkeeping (host side)
- ``cache``     — slot-indexed KV/SSM cache pool + mesh placement
- ``sampling``  — fused greedy/temperature/top-k/top-p with per-request
                  parameters and per-slot PRNG keys
"""
from repro.serve.engine import Engine, EngineStats
from repro.serve.scheduler import Request, SamplingParams, SlotScheduler

__all__ = ["Engine", "EngineStats", "Request", "SamplingParams",
           "SlotScheduler"]
