"""``repro.serve`` — the continuous-batching inference engine.

The training side of this repo keeps accelerators busy by overlapping
communication with compute; this package applies the same thesis to
serving: a fixed-slot request pool keeps the jitted decode step at one
static shape (it compiles exactly once and never retraces as requests
join/leave), chunked whole-prompt prefill replaces the token-by-token
forced-decode loop, and per-request sampling is fused into the decode
dispatch.

- ``engine``    — :class:`Engine`: admission -> chunked prefill -> batched
                  per-slot decode -> sampling -> eviction loop, plus the
                  SLO guardrails (deadlines, bounded queue, brownout,
                  watchdog) and graceful drain/restore
- ``scheduler`` — FIFO admission + slot lifecycle bookkeeping (host side),
                  typed :class:`AdmissionResult`, cancellation
- ``cache``     — slot-indexed KV/SSM cache pool + mesh placement
- ``sampling``  — fused greedy/temperature/top-k/top-p with per-request
                  parameters and per-slot PRNG keys
- ``chaos``     — deterministic serve fault injection (seeded FaultPlan:
                  qflood/stall/cancel/pagepress, bit-identical replay)
"""
from repro.serve.engine import Engine, EngineStats
from repro.serve.scheduler import (ACCEPTED, AdmissionResult, FINISH_CANCEL,
                                   FINISH_DEADLINE, FINISH_SHED, FINISH_STOP,
                                   REJECTED_QUEUE_FULL, Request,
                                   SamplingParams, SlotScheduler)

__all__ = ["Engine", "EngineStats", "Request", "SamplingParams",
           "SlotScheduler", "AdmissionResult", "ACCEPTED",
           "REJECTED_QUEUE_FULL", "FINISH_STOP", "FINISH_CANCEL",
           "FINISH_DEADLINE", "FINISH_SHED"]
