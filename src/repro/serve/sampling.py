"""Fused per-slot sampling: greedy / temperature / top-k / top-p with
per-request parameters and per-slot PRNG keys.

One traced function handles the whole slot pool in a single dispatch —
every slot carries its own (temperature, top_k, top_p) and its own key, so
heterogeneous requests batch together without retracing. Temperature
sampling is Gumbel-max (``argmax(logits/T + g)``), which makes the fused
Pallas kernel (``repro.kernels.slot_gather``) and this reference path
bit-comparable given shared noise, and makes the whole pipeline
deterministic under fixed per-request seeds.

Top-k/top-p need a sort over the vocab and stay on the jnp path; the
kernel covers the hot greedy/temperature fast path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gumbel_noise(keys, vocab: int):
    """Per-slot Gumbel noise: keys (S,) typed PRNG keys -> (S, V) fp32."""
    return jax.vmap(lambda k: jax.random.gumbel(k, (vocab,), jnp.float32))(
        keys)


def sample_tokens(logits, temperature, top_k, top_p, noise):
    """Sample one token per slot.

    logits: (S, V); temperature (S,) fp32 (0 = greedy); top_k (S,) int32
    (0 = off); top_p (S,) fp32 (>= 1 = off); noise (S, V) Gumbel.
    Returns (S,) int32."""
    lg = logits.astype(jnp.float32)
    S, V = lg.shape
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)

    t = jnp.maximum(temperature, 1e-6)[:, None]
    scaled = lg / t

    def apply_filters(scaled):
        # top-k: mask below the k-th largest (k = V when disabled)
        sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
        k = jnp.where(top_k > 0, jnp.clip(top_k, 1, V), V)
        kth = jnp.take_along_axis(sorted_desc, (k - 1)[:, None], axis=-1)
        masked = jnp.where(scaled >= kth, scaled, NEG_INF)

        # top-p (nucleus) over the top-k-masked distribution: keep tokens
        # whose exclusive prefix mass (sorted descending) is still below p
        # — always at least one, and p >= 1 keeps everything (fp-safe: the
        # inclusive cumsum may never reach 1.0 exactly)
        probs = jax.nn.softmax(masked, axis=-1)
        sp = jnp.sort(probs, axis=-1)[:, ::-1]
        csum = jnp.cumsum(sp, axis=-1)
        p = jnp.clip(top_p, 0.0, 1.0)[:, None]
        n_keep = jnp.maximum(jnp.sum((csum - sp) < p, axis=-1), 1)
        pth = jnp.take_along_axis(sp, (n_keep - 1)[:, None], axis=-1)
        return jnp.where(probs >= pth, masked, NEG_INF)

    # the vocab sorts run only when some slot actually filters (disabled
    # filters are identities); lax.cond keeps the trace static while the
    # all-greedy/plain-temperature hot path skips them at runtime
    masked = jax.lax.cond(jnp.any((top_k > 0) | (top_p < 1.0)),
                          apply_filters, lambda s: s, scaled)

    sampled = jnp.argmax(masked + noise, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def needs_full_path(sampling) -> bool:
    """Whether a request's params require the sort-based jnp path."""
    return sampling.top_k > 0 or sampling.top_p < 1.0
