"""Deterministic serve-side fault injection: the chaos loop.

``repro.fault`` proved the training loop survives a seeded
:class:`~repro.fault.inject.FaultPlan`; this module is the same harness
pointed at the serve engine. The non-negotiable property is the one the
train harness has: **bit-identical replay** — two runs of the same plan
(same seed, same engine shape) produce the same admissions, sheds,
cancels, brownout transitions, goodput counters and per-step log, byte
for byte.

Wall time is the enemy of that property, so the chaos loop never reads
it. The engine takes two seams:

- ``clock`` — a :class:`VirtualClock` starting at 0.0 that only moves
  when told to;
- ``cost_model`` — a pure function ``(kind, n) -> seconds`` the engine
  feeds into the clock after each dispatch (``decode`` per step at its
  live-lane count, ``prefill_chunk`` per prompt chunk).

Every duration the guardrails consume (step-time EWMA, deadlines, queue
budgets, goodput) is then a pure function of the plan. A ``stall`` event
inflates the *modeled* cost — the watchdog and deadline cancels fire
deterministically, no sleeps involved. The jitted programs are untouched:
chaos is host-side scheduling over the same compiled decode step
(``trace_counts["decode"] == 1`` before and after, asserted by the CLI).

Serve event kinds (``FaultPlan`` grammar, ``kind:magnitude@step[xD]``):

``qflood:N@S``      N requests burst-arrive at step S, drawn from the
                    per-event generator (tight deadlines + a hog mix).
``stall:F@SxD``     decode costs F x for the D steps starting at S.
``cancel:K@S``      the K-th live request (mod live count) is cancelled.
``pagepress:N@SxD`` N pages leave the allocator's free list at S and
                    return D steps later (drives brownout).

CLI (the CI ``serve-chaos`` job):

    PYTHONPATH=src python -m repro.serve.chaos --arch llama3.2-1b \\
        --fault-plan "qflood:6@3,stall:8@6x4,pagepress:12@10x8" \\
        --seed 0 --replay --drain-check --goodput-floor 20
"""
from __future__ import annotations

import json
import zlib

import numpy as np

from repro.fault.inject import SERVE_KINDS, FaultPlan
from repro.telemetry import trace


class VirtualClock:
    """An advance-only clock: ``clock()`` reads, ``advance(dt)`` moves.

    Monotonic by construction (negative advances are rejected), starts at
    0.0 so logged timestamps are run-relative and replay-stable."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self.now += float(dt)


def make_cost_model(state: dict | None = None):
    """The modeled dispatch costs driving the virtual clock.

    Decode: a fixed dispatch overhead plus a per-live-lane term (the CPU
    smoke models are latency- not bandwidth-bound, but the *shape* —
    busier steps cost more — is what the guardrail math needs to see).
    ``state["stall_factor"]`` scales everything while a ``stall`` window
    is open. Returns ``(cost_fn, state)``; mutate ``state`` to steer."""
    state = {"stall_factor": 1.0} if state is None else state

    def cost(kind: str, n: int) -> float:
        f = state.get("stall_factor", 1.0)
        if kind == "decode":
            return (0.002 + 0.0004 * n) * f
        if kind == "prefill_chunk":
            return 0.0008 * f
        return 0.0

    return cost, state


# ---------------------------------------------------------------------------
# deterministic workloads
# ---------------------------------------------------------------------------

def base_workload(seed: int, n: int, vocab: int, *, max_seq: int = 64):
    """The well-behaved arrival stream: one request per early step, short
    prompts, roughly half carrying generous deadlines. Pure function of
    the seed."""
    rng = np.random.default_rng([int(seed), 7])
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, 10))
        max_new = int(rng.integers(4, 12))
        if plen + max_new > max_seq:
            max_new = max_seq - plen
        deadline = float(rng.integers(80, 300)) if i % 2 else None
        reqs.append({"arrive": i, "tokens": rng.integers(
            1, vocab, size=plen).tolist(), "max_new": max_new,
            "deadline_ms": deadline, "max_queue_ms": None})
    return reqs


def _flood_request(rng, vocab: int, *, max_seq: int = 64) -> dict:
    """One adversarial arrival: either a hog (long output, deadline it
    cannot possibly meet under load) or a short tight-deadline request —
    the mix deadline shedding exists to sort out."""
    if rng.random() < 0.4:
        plen = int(rng.integers(4, 12))
        max_new = min(int(rng.integers(24, 48)), max_seq - plen)
        deadline = float(rng.integers(8, 25))          # hopeless under load
    else:
        plen = int(rng.integers(2, 6))
        max_new = int(rng.integers(2, 6))
        deadline = float(rng.integers(30, 120))
    return {"tokens": rng.integers(1, vocab, size=plen).tolist(),
            "max_new": max_new, "deadline_ms": deadline,
            "max_queue_ms": float(rng.integers(40, 160))}


# ---------------------------------------------------------------------------
# the chaos loop
# ---------------------------------------------------------------------------

def run_chaos(make_engine, plan: FaultPlan, *, n_base: int = 8,
              max_steps: int = 500, vocab: int = 251,
              max_seq: int = 64) -> dict:
    """Drive one engine through ``plan``. ``make_engine(clock=,
    cost_model=)`` must return a fresh :class:`~repro.serve.engine.Engine`
    (the factory closes over model/params so replay reuses the weights).

    Returns a plain-JSON result: per-request outputs + finish reasons,
    the per-step log, the guardrail counters, and a crc32 ``digest`` over
    all of it — two runs of the same plan must produce equal digests."""
    for e in plan.events:
        if e.kind not in SERVE_KINDS:
            raise ValueError(f"{e.kind!r} is a training-side fault kind; "
                             f"serve chaos takes {SERVE_KINDS}")
    clock = VirtualClock()
    cost, cstate = make_cost_model()
    eng = make_engine(clock=clock, cost_model=cost)
    base = base_workload(plan.seed, n_base, vocab, max_seq=max_seq)
    arrivals: dict[int, list] = {}
    for r in base:
        arrivals.setdefault(r["arrive"], []).append(r)
    stalls: list[tuple[int, float]] = []   # (last step affected, factor)
    press_release: dict[int, bool] = {}
    last_event = max([e.step + e.rounds for e in plan.events], default=0)
    submitted = rejected = 0
    log = []

    for t in range(max_steps):
        for e in plan.events_at(t):
            if e.kind == "stall":
                stalls.append((t + e.rounds - 1, float(max(2, e.worker))))
                trace.instant("chaos/stall", step=t, factor=e.worker,
                              rounds=e.rounds)
            elif e.kind == "pagepress" and eng.allocator is not None:
                got = eng.allocator.hold_pages(e.worker)
                press_release[t + e.rounds] = True
                trace.instant("chaos/pagepress", step=t, held=got,
                              rounds=e.rounds)
            elif e.kind == "cancel":
                live = sorted(st.req.rid for st in eng.sched.slots
                              if st is not None)
                if live:
                    eng.cancel(live[e.worker % len(live)])
            elif e.kind == "qflood":
                r = plan.event_rng(e)
                for _ in range(e.worker):
                    fr = _flood_request(r, vocab, max_seq=max_seq)
                    res = eng.submit(fr["tokens"], fr["max_new"],
                                     deadline_ms=fr["deadline_ms"],
                                     max_queue_ms=fr["max_queue_ms"])
                    submitted += 1
                    rejected += not res
        if press_release.pop(t, False) and eng.allocator is not None:
            eng.allocator.release_held()
        cstate["stall_factor"] = max(
            [f for (until, f) in stalls if t <= until], default=1.0)
        for r in arrivals.pop(t, ()):
            res = eng.submit(r["tokens"], r["max_new"],
                             deadline_ms=r["deadline_ms"],
                             max_queue_ms=r["max_queue_ms"])
            submitted += 1
            rejected += not res
        eng.step()
        st = eng.stats
        log.append({
            "step": t, "clock_us": int(round(clock.now * 1e6)),
            "active": eng.sched.num_active,
            "queue": eng.sched.queue_depth,
            "finished": eng.sched.finished_total,
            "occupancy_pct": int(round(st.page_occupancy * 100)),
            "brownout": st.brownout_level,
        })
        if (t >= last_event and not eng.sched.has_work()
                and not arrivals and not press_release):
            break
    if eng.allocator is not None:
        eng.allocator.release_held()       # unexpired pressure at exit
        eng.allocator.check_consistency()

    st = eng.stats
    result = {
        "plan": plan.to_spec(), "seed": plan.seed,
        "results": {str(int(r)): list(toks)
                    for r, toks in sorted(eng.sched.results().items())},
        "reasons": {str(int(r)): v
                    for r, v in sorted(eng.sched.finish_reasons().items())},
        "log": log,
        "stats": {
            "submitted": submitted,
            "rejected_at_submit": rejected,
            "finished_total": eng.sched.finished_total,
            "shed": st.shed, "cancelled": st.cancelled,
            "deadline_misses": st.deadline_misses,
            "rejected_queue_full": st.rejected_queue_full,
            "watchdog_stalls": st.watchdog_stalls,
            "brownout_clamped": st.brownout_clamped,
            "goodput_tokens": st.goodput_tokens,
            "decoded_tokens": st.decoded_tokens,
            "steps": st.steps,
        },
        "decode_compiles": eng.trace_counts["decode"],
    }
    result["digest"] = digest(result)
    return result


def digest(result: dict) -> int:
    """crc32 over the canonical JSON of a chaos result (minus any digest
    already stamped on it) — the replay-equality check."""
    clean = {k: v for k, v in result.items() if k != "digest"}
    return zlib.crc32(json.dumps(clean, sort_keys=True).encode())


def verify_replay(make_engine, plan: FaultPlan, **kw) -> tuple[dict, dict]:
    """Run the plan twice against fresh engines; raises if anything —
    outputs, reasons, counters, the step log — differs."""
    a = run_chaos(make_engine, plan, **kw)
    b = run_chaos(make_engine, plan, **kw)
    if a["digest"] != b["digest"]:
        for key in ("results", "reasons", "stats", "log"):
            if a[key] != b[key]:
                raise AssertionError(
                    f"chaos replay diverged in {key!r}: run1={a[key]!r} "
                    f"run2={b[key]!r}")
        raise AssertionError("chaos replay digests differ")
    return a, b


def verify_drain_restore(make_engine, *, seed: int = 0, n: int = 6,
                         drain_after: int = 3, vocab: int = 251,
                         max_seq: int = 64, path: str | None = None) -> dict:
    """Greedy drain->restore parity: run a deterministic workload to
    completion (oracle), then re-run it but drain after ``drain_after``
    steps, restore the snapshot into a fresh engine and finish there.
    The union of outputs must be bit-identical to the oracle's."""
    reqs = base_workload(seed, n, vocab, max_seq=max_seq)

    def feed(eng):
        for r in reqs:
            eng.submit(r["tokens"], r["max_new"])   # no deadlines: greedy
                                                    # parity, not shedding
    oracle = make_engine()
    feed(oracle)
    want = {int(r): list(t) for r, t in oracle.run().items()}

    eng = make_engine()
    feed(eng)
    for _ in range(drain_after):
        eng.step()
    snap = eng.drain(path)
    partial = {int(r): list(t) for r, t in eng.sched.results().items()}
    eng2 = make_engine()
    requeued = eng2.load_snapshot(path if path is not None else snap)
    eng2.run()
    got = {int(r): list(t) for r, t in eng2.sched.results().items()}
    if got != want:
        raise AssertionError(
            f"drain->restore diverged from the uninterrupted run: "
            f"want={want!r} got={got!r}")
    return {"oracle": want, "drained_finished": sorted(partial),
            "requeued": sorted(requeued)}


# ---------------------------------------------------------------------------
# CLI — the CI serve-chaos smoke
# ---------------------------------------------------------------------------

def main(argv=None):
    import argparse

    import jax

    from repro import telemetry
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serve.engine import Engine

    ap = argparse.ArgumentParser(
        description="deterministic serve chaos loop (seeded FaultPlan)")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--fault-plan",
                    default="qflood:6@3,stall:8@6x4,cancel:1@9,"
                            "pagepress:12@10x8")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=8,
                    help="well-behaved base arrivals under the chaos")
    ap.add_argument("--steps", type=int, default=300,
                    help="hard cap on chaos-loop steps")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=0)
    ap.add_argument("--max-queue", type=int, default=16)
    ap.add_argument("--shed-policy", default="reject-no-deadline")
    ap.add_argument("--goodput-floor", type=int, default=1,
                    help="minimum tokens delivered within deadline")
    ap.add_argument("--replay", action="store_true",
                    help="run the plan twice, assert bit-identical")
    ap.add_argument("--drain-check", action="store_true",
                    help="assert drain->restore greedy parity")
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--trace-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    plan = FaultPlan.from_spec(args.fault_plan, seed=args.seed)

    def make_engine(**over):
        return Engine(model, params, max_slots=args.max_slots,
                      max_seq=args.max_seq, prefill_chunk=8,
                      page_size=args.page_size, num_pages=args.num_pages,
                      max_queue=args.max_queue,
                      shed_policy=args.shed_policy, **over)

    kw = dict(n_base=args.requests, max_steps=args.steps,
              vocab=cfg.vocab_size, max_seq=args.max_seq)
    if args.replay:
        result, _ = verify_replay(make_engine, plan, **kw)
        print(f"replay: bit-identical (digest {result['digest']:#010x})")
    else:
        result = run_chaos(make_engine, plan, **kw)
    s = result["stats"]
    print(f"chaos plan [{plan.to_spec()}] seed={plan.seed}: "
          f"{s['submitted']} submitted, {s['finished_total']} terminal "
          f"({s['shed']} shed, {s['cancelled']} cancelled, "
          f"{s['deadline_misses']} deadline misses, "
          f"{s['rejected_queue_full']} queue-rejected)")
    print(f"goodput {s['goodput_tokens']} tokens within deadline "
          f"(of {s['decoded_tokens']} decoded over {s['steps']} steps); "
          f"watchdog flagged {s['watchdog_stalls']} stalls, brownout "
          f"clamped {s['brownout_clamped']}; decode compiled "
          f"{result['decode_compiles']}x")
    failures = []
    if result["decode_compiles"] != 1:
        failures.append(
            f"decode compiled {result['decode_compiles']}x (want exactly 1)")
    if s["goodput_tokens"] < args.goodput_floor:
        failures.append(f"goodput {s['goodput_tokens']} below floor "
                        f"{args.goodput_floor}")
    if args.drain_check:
        verify_drain_restore(make_engine, seed=args.seed,
                             vocab=cfg.vocab_size, max_seq=args.max_seq)
        print("drain->restore: greedy outputs bit-identical to the "
              "uninterrupted run")
    if args.metrics_out:
        telemetry.dump_metrics(args.metrics_out)
        print(f"metrics -> {args.metrics_out}")
    if args.trace_out:
        telemetry.trace.export(args.trace_out)
        print(f"trace -> {args.trace_out}")
    if failures:
        raise SystemExit("serve-chaos FAILED: " + "; ".join(failures))


if __name__ == "__main__":
    main()
