"""Paged KV/SSM cache pool + page allocator.

Two pool layouts back the engine (DESIGN.md "Paged KV cache & prefix
caching"):

- **Paged (default).** Attention leaves hold ``num_pages`` fixed-size
  physical pages — ``(layers, num_pages, page_size, ...)`` — shared by
  every slot through a per-slot *block table* (``(max_slots,
  pages_per_slot)`` int32 of physical page ids). Reads gather lanes (or
  fetch pages tile-wise inside ``flash_decode_paged``), writes scatter
  rows through the table, and the host-side :class:`PageAllocator` owns
  the free list, refcounts, the hashed prefix cache and copy-on-write
  bookkeeping. SSM conv/state leaves have no sequence dimension to page
  and keep one lane per slot: ``(layers, max_slots, ...)``.
- **Contiguous (legacy / oracle).** ``model.init_cache(max_slots,
  max_seq)``: one private ``max_seq`` lane per slot. Kept as the parity
  oracle for the paged engine and for A/B density benchmarks.

Physical page 0 is the **null page**: block tables initialize (and reset)
to 0, idle slots and pad-row scatters land there harmlessly, and it is
never on the free list.

Device ops are all trace-stable: ``copy_page`` / ``reset_slot_ssm`` jit
once per pool structure, and the block tables enter jitted programs as
same-shaped int32 inputs per dispatch — values change under churn,
shapes never do.
"""
from __future__ import annotations

import functools
import hashlib
from collections import OrderedDict, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import cache_shardings

NULL_PAGE = 0


# ---------------------------------------------------------------------------
# pool construction / leaf classification
# ---------------------------------------------------------------------------

def make_pool(model, max_slots: int, max_seq: int):
    """Contiguous pool: one lane per slot, ``max_seq`` rows each."""
    return model.init_cache(max_slots, max_seq)


def make_paged_pool(model, max_slots: int, page_size: int, num_pages: int):
    """Paged pool: attention leaves are (layers, num_pages, page_size, ...)
    physical pages; SSM leaves stay (layers, max_slots, ...) lanes."""
    return model.init_paged_cache(max_slots, page_size, num_pages)


def is_paged_leaf(path) -> bool:
    """True for attention K/V / MLA-latent leaves (page-granular); False
    for SSM conv/state lanes (slot-granular, no sequence dim)."""
    return any(getattr(k, "key", None) == "attn" for k in path)


def has_paged_leaves(pool) -> bool:
    """Whether any pool leaf is page-granular (pure-SSM pools have none —
    paging is a structural no-op there and the engine runs slot-granular)."""
    return any(is_paged_leaf(p)
               for p, _ in jax.tree_util.tree_leaves_with_path(pool))


def slot_axis_of(leaf) -> int:
    """Slot (batch) axis of a slot-granular pool leaf: the decoder stacks
    segment caches as (layer, slot, ...), so it is axis 1 for every leaf.
    (In a paged pool, axis 1 of an attention leaf is the *page* id.)"""
    del leaf
    return 1


def slot_view(pool, slot):
    """Extract slot ``slot`` as a batch-1 cache pytree (traceable)."""
    return jax.tree.map(
        lambda v: jax.lax.dynamic_slice_in_dim(v, slot, 1,
                                               axis=slot_axis_of(v)), pool)


def slot_write(pool, slot, view):
    """Scatter a batch-1 cache pytree back into the pool at ``slot``."""
    return jax.tree.map(
        lambda v, u: jax.lax.dynamic_update_slice_in_dim(
            v, u.astype(v.dtype), slot, axis=slot_axis_of(v)), pool, view)


def paged_view(pool, slot):
    """Prefill view of a paged pool: page-granular leaves pass through
    whole (chunk writes scatter through the block table), slot-granular
    SSM leaves are sliced to the (1, ...) lane the batched path expects."""
    return jax.tree_util.tree_map_with_path(
        lambda p, v: v if is_paged_leaf(p)
        else jax.lax.dynamic_slice_in_dim(v, slot, 1, axis=1), pool)


def paged_write(pool, slot, view):
    """Fold a ``paged_view`` back: pages replace wholesale, SSM lanes
    scatter to their slot."""
    return jax.tree_util.tree_map_with_path(
        lambda p, v, u: u if is_paged_leaf(p)
        else jax.lax.dynamic_update_slice_in_dim(
            v, u.astype(v.dtype), slot, axis=1), pool, view)


# ---------------------------------------------------------------------------
# device ops
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def reset_slot(pool, slot):
    """Zero one slot-granular lane of the pool (all layers, all leaves).
    Contiguous pools only — the engine's admission path uses the
    O(d_state) ``reset_slot_ssm`` instead; this remains as a test utility
    (clean-lane oracles)."""
    def leaf(v):
        ax = slot_axis_of(v)
        zeros = jnp.zeros(v.shape[:ax] + (1,) + v.shape[ax + 1:], v.dtype)
        return jax.lax.dynamic_update_slice_in_dim(v, zeros, slot, axis=ax)
    return jax.tree.map(leaf, pool)


@functools.partial(jax.jit, donate_argnums=(0,))
def reset_slot_ssm(pool, slot):
    """Zero one slot's SSM conv/state lanes only — O(d_state) per
    admission, not the old O(max_seq) full-lane zero. Attention rows need
    no zeroing: a previous occupant's stale rows are causally masked until
    the new request overwrites them in order (and in the paged pool the
    slot starts from freshly allocated pages anyway). The SSM lanes *do*
    need it: conv/state carries across prefill chunks by design, so a
    fresh request must start from zeros. Works on both pool layouts
    (page-granular leaves are untouched either way)."""
    def leaf(p, v):
        if is_paged_leaf(p):
            return v
        zeros = jnp.zeros(v.shape[:1] + (1,) + v.shape[2:], v.dtype)
        return jax.lax.dynamic_update_slice_in_dim(v, zeros, slot, axis=1)
    return jax.tree_util.tree_map_with_path(leaf, pool)


@functools.partial(jax.jit, donate_argnums=(0,))
def copy_page(pool, dst, src):
    """Copy one physical page across all layers of every page-granular
    leaf — the copy-on-write device op. Scalar dst/src keep it a single
    trace; COW is rare (one page per diverging request), so the engine
    loops host-side for multiples."""
    def leaf(p, v):
        if not is_paged_leaf(p):
            return v
        page = jax.lax.dynamic_slice_in_dim(v, src, 1, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(v, page, dst, axis=1)
    return jax.tree_util.tree_map_with_path(leaf, pool)


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

def pool_shardings(mesh, pool, max_slots: int, num_pages: int | None = None):
    """NamedShardings for the pool: the slot dim — and, in a paged pool,
    the page dim — over data axes; KV heads / MLA latent / SSM heads over
    ``model`` (see ``repro.dist.sharding``)."""
    return cache_shardings(mesh, pool, max_slots, page_batch=num_pages)


def place_pool(mesh, pool, max_slots: int, num_pages: int | None = None):
    """Device-put the pool onto its serve-mesh shardings."""
    if mesh is None:
        return pool
    return jax.device_put(
        pool, pool_shardings(mesh, pool, max_slots, num_pages))


# ---------------------------------------------------------------------------
# page allocator (host-side)
# ---------------------------------------------------------------------------

class OutOfPages(RuntimeError):
    """Page pool exhausted: no free page and nothing evictable. Admission
    reservations make this unreachable from the engine loop; hitting it
    means allocator bookkeeping is broken."""


def hash_prefix_chunk(prev: bytes, tokens) -> bytes:
    """One hash-chain step over a page of prompt tokens: ``H(prev ||
    tokens)``. Module-level so tests can monkeypatch it to force
    collisions; collisions are survivable (entries store the full token
    prefix and verify it on hit) — just cache misses."""
    h = hashlib.sha1(prev)
    h.update(np.asarray(tokens, np.int64).tobytes())
    return h.digest()


class PageAllocator:
    """Free-list page allocator + refcounts + hashed prefix cache.

    All host-side numpy/dict state; the engine uploads ``tables`` as a
    same-shaped int32 array per dispatch. Invariants:

    - ``refs[pid]`` counts owners: one per slot whose table maps the page,
      plus one if the prefix cache holds it. Page 0 (the null page) is
      pinned and never allocated or freed.
    - A page registered in the prefix cache is **never written again**
      (registration happens after prefill finishes the prompt; decode
      writes land strictly beyond the prompt's full pages).
    - A write to a shared page (refs > 1) must copy first:
      :meth:`ensure_writable` returns the (dst, src) device copies.
    - Admission reserves its worst-case page count up front
      (:meth:`try_admit`), so mid-flight allocation never fails.
    - Cache-only pages (refs == 1, held only by the prefix cache) are
      evictable, oldest-hit first (LRU).
    """

    def __init__(self, num_pages: int, page_size: int, max_slots: int,
                 pages_per_slot: int, *, prefix_cache: bool = True):
        if num_pages < 2:
            raise ValueError(f"need >= 2 pages (null + 1), got {num_pages}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_slots = max_slots
        self.pages_per_slot = pages_per_slot
        self.prefix_cache = prefix_cache
        self.refs = np.zeros(num_pages, np.int64)
        self.refs[NULL_PAGE] = 1                 # pinned
        self.free: deque[int] = deque(range(1, num_pages))
        self.tables = np.zeros((max_slots, pages_per_slot), np.int32)
        self._reserved = np.zeros(max_slots, np.int64)
        # prefix cache: chain digest -> (pid, full token prefix); LRU over
        # digests orders eviction
        self._entries: dict[bytes, tuple[int, tuple]] = {}
        self._by_pid: dict[int, bytes] = {}
        self._lru: OrderedDict[bytes, None] = OrderedDict()
        # pages withheld from circulation by fault injection (pagepress)
        self.held: list[int] = []
        # counters (pages unless noted; read by EngineStats / bench)
        self.hits = 0
        self.lookups = 0
        self.hit_tokens = 0
        self.cow_copies = 0
        self.evictions = 0
        self.collisions = 0

    # -- capacity -----------------------------------------------------------

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def _evictable(self) -> int:
        return sum(1 for pid in self._by_pid if self.refs[pid] == 1)

    def available(self) -> int:
        """Pages an admission could claim right now: free + evictable,
        minus what already-admitted requests still have reserved."""
        return (len(self.free) + self._evictable()
                - int(self._reserved.sum()))

    @property
    def allocated(self) -> int:
        """Pages holding live or cached rows (excludes the null page)."""
        return self.num_pages - 1 - len(self.free)

    def occupancy(self) -> float:
        return self.allocated / max(self.num_pages - 1, 1)

    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    # -- page ops -----------------------------------------------------------

    def _alloc(self, slot: int | None) -> int:
        if not self.free and not self._evict_one():
            raise OutOfPages(
                f"no free page ({self.allocated}/{self.num_pages - 1} "
                f"allocated, nothing evictable)")
        pid = self.free.popleft()
        assert self.refs[pid] == 0
        self.refs[pid] = 1
        if slot is not None and self._reserved[slot] > 0:
            self._reserved[slot] -= 1
        return pid

    def _evict_one(self) -> bool:
        for key in self._lru:            # oldest-hit first
            pid = self._entries[key][0]
            if self.refs[pid] == 1:      # cache-only: safe to drop
                self._drop_entry(key)
                self.refs[pid] = 0
                self.free.append(pid)
                self.evictions += 1
                return True
        return False

    def _drop_entry(self, key: bytes) -> None:
        pid, _ = self._entries.pop(key)
        self._by_pid.pop(pid, None)
        self._lru.pop(key, None)

    def _unref(self, pid: int) -> None:
        if pid == NULL_PAGE:
            return
        self.refs[pid] -= 1
        assert self.refs[pid] >= 0, f"refcount underflow on page {pid}"
        if self.refs[pid] == 0:
            self.free.append(pid)

    # -- admission ----------------------------------------------------------

    def _match_prefix(self, tokens) -> list[int]:
        """Longest chain of cached full prompt pages (hash-chain walk with
        token verification — a digest collision is a miss, not corruption)."""
        ps = self.page_size
        pids: list[int] = []
        prev = b""
        for j in range(len(tokens) // ps):
            prev = hash_prefix_chunk(prev, tokens[j * ps:(j + 1) * ps])
            self.lookups += 1
            ent = self._entries.get(prev)
            if ent is None:
                break
            pid, prefix = ent
            if tuple(tokens[:(j + 1) * ps]) != prefix:
                self.collisions += 1
                break
            pids.append(pid)
        return pids

    def try_admit(self, slot: int, tokens, max_new: int) -> int | None:
        """Install prefix hits into ``slot``'s table and reserve the
        worst-case remaining page count. Returns the hit token count
        (prefill resumes there), or None — with zero state mutated — if
        the pool can't hold the request yet."""
        ps = self.page_size
        S0 = len(tokens)
        total = self.pages_needed(S0 + max_new)
        hits = self._match_prefix(tokens) if self.prefix_cache else []
        h = len(hits)
        full_hit = h * ps == S0
        # full-prompt hit still re-runs the final prompt token for its
        # sampling logits; that write COWs the shared last page: +1
        need = total - h + (1 if full_hit else 0)
        if need > self.available():
            return None
        row = self.tables[slot]
        assert not row.any() and self._reserved[slot] == 0, \
            f"slot {slot} admitted while holding pages"
        for j, pid in enumerate(hits):
            self.refs[pid] += 1
            row[j] = pid
            self._lru.move_to_end(self._by_pid[pid])
        self._reserved[slot] = need
        self.hits += h
        self.hit_tokens += h * ps
        return h * ps

    def ensure_writable(self, slot: int, position: int) -> list[tuple[int, int]]:
        """Make the page covering ``position`` privately writable before a
        dispatch writes it: allocate on first touch, copy-on-write when
        shared. Returns the (dst, src) device copies to run (at most one)."""
        j = position // self.page_size
        row = self.tables[slot]
        pid = int(row[j])
        if pid == NULL_PAGE:
            row[j] = self._alloc(slot)
            return []
        if self.refs[pid] > 1:           # shared with the cache/other slots
            new = self._alloc(slot)
            row[j] = new
            self.refs[pid] -= 1          # this slot's ref moves to the copy
            self.cow_copies += 1
            return [(new, pid)]
        return []

    def register_prefix(self, slot: int, tokens) -> None:
        """Publish the request's full prompt pages into the prefix cache
        (+1 ref each; cache entries are never written afterwards). Pages
        that arrived as hits, or whose digest is already published by a
        twin request, are skipped."""
        if not self.prefix_cache:
            return
        ps = self.page_size
        prev = b""
        row = self.tables[slot]
        for j in range(len(tokens) // ps):
            prev = hash_prefix_chunk(prev, tokens[j * ps:(j + 1) * ps])
            if prev in self._entries:    # hit-installed or twin (or a
                continue                 # colliding digest: first wins)
            pid = int(row[j])
            if pid == NULL_PAGE or pid in self._by_pid:
                continue
            self.refs[pid] += 1
            self._entries[prev] = (pid, tuple(tokens[:(j + 1) * ps]))
            self._by_pid[pid] = prev
            self._lru[prev] = None
        # hits/twins referenced above stay MRU even when nothing new was
        # published (the loop body touched move_to_end at admission)

    def release_slot(self, slot: int) -> None:
        """Free-list page release at request finish: drop the slot's ref on
        every mapped page (pages the prefix cache still holds survive with
        refs >= 1 for future hits) and clear its table row + reservation."""
        row = self.tables[slot]
        for j in range(self.pages_per_slot):
            pid = int(row[j])
            row[j] = NULL_PAGE
            self._unref(pid)
        self._reserved[slot] = 0

    # -- fault injection: page-pool pressure --------------------------------

    def hold_pages(self, n: int) -> int:
        """Withhold up to ``n`` free pages from circulation (the
        ``pagepress`` fault: a shrunken usable pool). Held pages vanish
        from the free list — ``available()`` drops, ``occupancy()`` rises
        (brownout sees real pressure) — and come back via
        :meth:`release_held`. Takes from the free list's tail so the
        allocation order of the surviving pages is unchanged (replay
        determinism). Returns how many were actually held."""
        took = 0
        while self.free and took < n:
            self.held.append(self.free.pop())
            took += 1
        return took

    def release_held(self) -> int:
        """Return every held page to the free list (tail, reversed — the
        exact inverse of :meth:`hold_pages`)."""
        n = len(self.held)
        while self.held:
            self.free.append(self.held.pop())
        return n

    # -- invariants ---------------------------------------------------------

    def check_consistency(self) -> None:
        """Assert the allocator's global refcount invariant:

        every non-null page is exactly one of {free, held, live}, and a
        live page's refcount equals its slot-table mappings plus its
        prefix-cache hold — i.e. ``free + held + mapped/prefix-held +
        null == num_pages`` with per-page refs exact. Raises
        AssertionError with the first violation; any interleaving of
        finish/cancel/evict/COW must keep this true (property-tested)."""
        expect = np.zeros(self.num_pages, np.int64)
        expect[NULL_PAGE] = 1                      # pinned
        for row in self.tables:
            for pid in row:
                if pid != NULL_PAGE:
                    expect[pid] += 1
        for pid, _ in self._entries.values():
            expect[pid] += 1
        assert np.array_equal(self.refs, expect), (
            f"refcount drift: refs={self.refs.tolist()} "
            f"expected={expect.tolist()}")
        free = set(self.free)
        held = set(self.held)
        assert len(free) == len(self.free), "duplicate page on free list"
        assert len(held) == len(self.held), "duplicate held page"
        assert not (free & held), "page both free and held"
        assert NULL_PAGE not in free | held, "null page left the pool"
        live = {pid for pid in range(self.num_pages)
                if self.refs[pid] > 0}
        assert not (live & (free | held)), (
            f"referenced page on the free/held list: "
            f"{sorted(live & (free | held))}")
        assert len(free) + len(held) + len(live) == self.num_pages, (
            f"page leak: {len(free)} free + {len(held)} held + "
            f"{len(live)} live != {self.num_pages}")
        assert self.refs[NULL_PAGE] == 1, "null page unpinned"
        # prefix entries and the reverse index agree
        assert ({pid for pid, _ in self._entries.values()}
                == set(self._by_pid)), "prefix cache index drift"

    def state_digest(self) -> tuple:
        """Cheap structural fingerprint (tables, refs, free/held order,
        reservations, prefix keys) — rejection paths must leave it
        bit-identical (tested)."""
        return (self.tables.tobytes(), self.refs.tobytes(),
                tuple(self.free), tuple(self.held),
                self._reserved.tobytes(), tuple(self._entries.keys()))
