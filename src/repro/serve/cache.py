"""Slot-indexed KV/SSM cache pool.

The pool is just ``model.init_cache(max_slots, max_seq)`` — a pytree whose
leaves carry (segment-stacked) ``(layers, slots, ...)`` axes — plus the
three operations the engine needs:

- ``slot_view`` / ``slot_write``: gather one slot's (1, ...) cache slice
  out of the pool and scatter it back, so chunked prefill can run the
  batched model path against a single lane via ``dynamic_update_slice``
  (works unchanged for GQA k/v, MLA latent, and SSM conv/state leaves —
  the slot axis is the batch axis everywhere).
- ``reset_slot``: zero one lane — the hand-off between requests. The
  engine runs it at admission: causal masking hides a previous occupant's
  stale attention rows on its own, but the SSM conv/state lane carries
  across prefill chunks by design and must start from zeros.
- ``pool_shardings``: mesh placement through ``repro.dist`` — slots over
  the data axes, head-like dims over ``model``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.sharding import cache_shardings


def make_pool(model, max_slots: int, max_seq: int):
    """Allocate the cache pool: one lane per slot, ``max_seq`` rows each."""
    return model.init_cache(max_slots, max_seq)


def slot_axis_of(leaf) -> int:
    """Slot (batch) axis index of a pool leaf: the decoder stacks segment
    caches as (layer, slot, ...), so it is axis 1 for every leaf."""
    del leaf
    return 1


def slot_view(pool, slot):
    """Extract slot ``slot`` as a batch-1 cache pytree (traceable)."""
    return jax.tree.map(
        lambda v: jax.lax.dynamic_slice_in_dim(v, slot, 1,
                                               axis=slot_axis_of(v)), pool)


def slot_write(pool, slot, view):
    """Scatter a batch-1 cache pytree back into the pool at ``slot``."""
    return jax.tree.map(
        lambda v, u: jax.lax.dynamic_update_slice_in_dim(
            v, u.astype(v.dtype), slot, axis=slot_axis_of(v)), pool, view)


@functools.partial(jax.jit, donate_argnums=(0,))
def reset_slot(pool, slot):
    """Zero one lane of the pool (all layers, all leaves)."""
    def leaf(v):
        ax = slot_axis_of(v)
        zeros = jnp.zeros(v.shape[:ax] + (1,) + v.shape[ax + 1:], v.dtype)
        return jax.lax.dynamic_update_slice_in_dim(v, zeros, slot, axis=ax)
    return jax.tree.map(leaf, pool)


def pool_shardings(mesh, pool, max_slots: int):
    """NamedShardings for the pool: slot dim over data axes, KV heads /
    MLA latent / SSM heads over ``model`` (see ``repro.dist.sharding``)."""
    return cache_shardings(mesh, pool, max_slots)


def place_pool(mesh, pool, max_slots: int):
    """Device-put the pool onto its serve-mesh shardings."""
    if mesh is None:
        return pool
    return jax.device_put(pool, pool_shardings(mesh, pool, max_slots))
