"""Continuous-batching decode engine.

Design contract (the reason this engine never recompiles):

- **Fixed-slot request pool.** The jitted decode step always sees
  ``(max_slots, 1)`` tokens, a ``(max_slots,)`` position vector, the full
  cache pool, and ``(max_slots,)`` sampling-parameter vectors. Requests
  joining or leaving only change the *values* in those arrays, never a
  shape — the step compiles exactly once per process (asserted in
  ``tests/test_serve.py`` via ``trace_counts``).
- **Per-slot positions.** Every lane decodes at its own depth
  (``decoder_decode_step`` with a (B,) position vector); a freed lane is
  reused immediately by the next queued request.
- **Chunked whole-prompt prefill.** A new request's prompt is written into
  its slot's cache lane by ``model.chunk_prefill`` in ``prefill_chunk``-
  token chunks — one model call per chunk instead of one per token, with
  the LM head applied once. For SSM/hybrid families the chunk is rounded
  up to a multiple of ``cfg.ssm.chunk`` so the SSD block decomposition
  aligns with a single-call prefill bit-for-bit.
- **Paged KV cache (default).** Attention lanes live in a shared pool of
  fixed-size pages routed per slot by a block table (``page_size``,
  ``num_pages``); the host-side :class:`~repro.serve.cache.PageAllocator`
  owns the free list, refcounts and the hashed prefix cache, so admission
  capacity follows what the traffic actually holds, not ``max_slots *
  max_seq`` worst case. Block tables enter the jitted programs as
  same-shaped int32 inputs per dispatch — compile-once still holds under
  churn. ``page_size=0`` selects the contiguous per-slot pool (the parity
  oracle). See DESIGN.md "Paged KV cache & prefix caching".
- **Slot-independent numerics.** Greedy decode of a request is bit-exact
  with ``repro.train.serve.generate`` on the same prompt no matter what
  the other slots are doing (MoE routes drop-free at decode/prefill;
  attention/SSM lanes are batch-independent) — the property the parity
  tests pin per family.
- **SLO guardrails are host-side only.** Deadline shedding, in-flight
  cancellation, the bounded queue, brownout degradation, the stuck-step
  watchdog and drain/restore all live between dispatches — the jitted
  decode/prefill programs are byte-identical with guardrails on or off
  and still compile exactly once (tested). See DESIGN.md "Serve
  robustness" for the deadline math and the brownout ladder.

Sampling is fused into the decode dispatch: greedy/temperature/top-k/top-p
with per-request parameters and per-slot PRNG keys in the same jit
(``fused_sampling=True`` additionally routes the greedy/temperature fast
path through the ``slot_gather`` Pallas kernel).

The engine is synchronous: admission and prefill happen between decode
steps (a prefill stall bounded by ``prefill_chunk``), which keeps the loop
deterministic and testable; see DESIGN.md "Serving engine" for the slot
lifecycle diagram.
"""
from __future__ import annotations

import json
import time
import zlib
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.telemetry import anomaly, profile, trace
from repro.telemetry.registry import Registry
from repro.serve import cache as cache_mod
from repro.serve import sampling as sampling_mod
from repro.serve.scheduler import (AdmissionResult, FINISH_SHED,
                                   REJECTED_QUEUE_FULL, Request,
                                   SamplingParams, SlotScheduler, SlotState)


STATS_WINDOW = 4096   # decode steps of latency history kept for percentiles
EWMA_ALPHA = 0.2      # step-time EWMA (the watchdog/deadline-estimate base)

# brownout ladder thresholds on page-pool occupancy (DESIGN.md "Serve
# robustness"): sustained occupancy >= HI1 enters level 1 (prefix-cache
# registration off), >= HI2 level 2 (+ max_new clamp); dropping below LO
# for the same patience leaves brownout entirely
BROWNOUT_HI1 = 0.85
BROWNOUT_HI2 = 0.95
BROWNOUT_LO = 0.60
BROWNOUT_PATIENCE = 3

SNAPSHOT_SCHEMA = 1


class EngineStats:
    """Serve statistics, backed by a telemetry :class:`Registry`.

    The public surface (``prefill_tokens``, ``decode_tok_s()``,
    ``token_latency_percentiles()``, ...) is unchanged from the old
    dataclass, but every scalar now lives in a registry metric
    (``serve/...`` counters/gauges/histograms) owned by this object — so
    a ``--metrics-out`` dump exports exactly the numbers the stats report,
    with no second bookkeeping path. The registry is private and always
    live (the stats must work with ``REPRO_TELEMETRY=0``); when telemetry
    is enabled the engine attaches it to the process-wide export stream.

    Exact (non-bucketed) p50/p99 readbacks keep the bounded deque windows
    the old implementation used; the registry histograms carry the same
    observations for the JSONL view.

    The guardrail layer adds shed/cancel/deadline-miss/queue-rejection
    counters, queue-depth + brownout gauges, a goodput counter (tokens of
    requests that finished inside their deadline), and ``step_ewma`` —
    the always-live step-time EWMA the admission estimate and the stuck-
    step watchdog read (the telemetry-gated PR 8 ``StreamDetector`` sees
    the same stream when enabled).
    """

    def __init__(self):
        r = self.registry = Registry(label="serve")
        self._prefill_tokens = r.counter("serve/prefill_tokens")
        self._prefill_time = r.counter("serve/prefill_time_s")
        self._decoded_tokens = r.counter("serve/decoded_tokens")
        self._decode_time = r.counter("serve/decode_time_s")
        self._steps = r.counter("serve/decode_steps")
        self._admissions = r.counter("serve/admissions")
        self._evictions = r.counter("serve/evictions")
        self._page_occupancy = r.gauge("serve/page_occupancy")
        self._prefix_hit_rate = r.gauge("serve/prefix_hit_rate")
        self._cow_copies = r.gauge("serve/cow_copies")
        # SLO guardrails
        self._shed = r.counter("serve/shed")
        self._cancelled = r.counter("serve/cancelled")
        self._deadline_miss = r.counter("serve/deadline_miss")
        self._rejected_queue_full = r.counter("serve/rejected_queue_full")
        self._watchdog_stalls = r.counter("serve/watchdog_stalls")
        self._brownout_clamped = r.counter("serve/brownout_clamped")
        self._goodput_tokens = r.counter("serve/goodput_tokens")
        self._queue_depth = r.gauge("serve/queue_depth")
        self._brownout_level = r.gauge("serve/brownout_level")
        self._h_step = r.histogram("serve/step_time_s")
        self._h_ttft = r.histogram("serve/ttft_s")
        self._h_queue = r.histogram("serve/queue_wait_s")
        self.step_ewma: float | None = None   # warm steps only
        # bounded windows (a long-running server must not grow per step):
        # seconds per dispatch / live tokens per dispatch / per-request
        self.step_times: deque = deque(maxlen=STATS_WINDOW)
        self.step_tokens: deque = deque(maxlen=STATS_WINDOW)
        self.ttfts: deque = deque(maxlen=STATS_WINDOW)
        self.queue_waits: deque = deque(maxlen=STATS_WINDOW)

    # -- the recording path (engine-internal) -------------------------------

    def record_prefill(self, tokens: int, dt: float) -> None:
        self._prefill_tokens.inc(tokens)
        self._prefill_time.inc(dt)

    def record_admission(self, queue_wait: float) -> None:
        self._admissions.inc()
        self._h_queue.observe(queue_wait)
        self.queue_waits.append(queue_wait)

    def record_first_token(self, ttft: float) -> None:
        self._h_ttft.observe(ttft)
        self.ttfts.append(ttft)

    def record_decode(self, n_active: int, dt: float) -> None:
        self._steps.inc()
        self._decode_time.inc(dt)
        self._decoded_tokens.inc(n_active)
        self._h_step.observe(dt)
        self.step_times.append(dt)
        self.step_tokens.append(n_active)
        if self.steps > 1:       # step 1 was the compile dispatch
            self.step_ewma = (dt if self.step_ewma is None
                              else EWMA_ALPHA * dt
                              + (1 - EWMA_ALPHA) * self.step_ewma)

    def record_evictions(self, n: int) -> None:
        self._evictions.inc(n)

    def record_finish(self, ev: dict) -> None:
        """Fold one scheduler finish-log event into the SLO counters."""
        if ev["slot"] is not None:
            self._evictions.inc()
        reason = ev["reason"]
        if reason == "cancel":
            self._cancelled.inc()
        elif reason == "shed":
            self._shed.inc()
        if ev["had_deadline"]:
            if reason == "stop" and ev["within_deadline"]:
                self._goodput_tokens.inc(ev["tokens"])
            else:
                self._deadline_miss.inc()
        elif reason == "stop":
            self._goodput_tokens.inc(ev["tokens"])

    def record_rejection(self) -> None:
        self._rejected_queue_full.inc()

    def record_watchdog(self) -> None:
        self._watchdog_stalls.inc()

    def record_brownout_clamp(self) -> None:
        self._brownout_clamped.inc()

    def set_queue_depth(self, n: int) -> None:
        self._queue_depth.set(n)

    def set_brownout_level(self, level: int) -> None:
        self._brownout_level.set(level)

    def set_page_stats(self, occupancy: float, hit_rate: float,
                       cow: int) -> None:
        """Cache-health gauges, refreshed per step. In a paged engine they
        come from the :class:`~repro.serve.cache.PageAllocator` (fraction
        of the physical page pool holding live/cached rows, prefix-cache
        hit rate over page lookups, cumulative copy-on-write page copies);
        the contiguous fallback reports slot-pool occupancy and zeros."""
        self._page_occupancy.set(occupancy)
        self._prefix_hit_rate.set(hit_rate)
        self._cow_copies.set(cow)

    # -- the read surface (public, unchanged + TTFT/queue-wait) -------------

    @property
    def prefill_tokens(self) -> int:
        return self._prefill_tokens.value

    @property
    def prefill_time(self) -> float:
        return self._prefill_time.value

    @property
    def decoded_tokens(self) -> int:
        return self._decoded_tokens.value

    @property
    def decode_time(self) -> float:
        return self._decode_time.value

    @property
    def steps(self) -> int:
        return self._steps.value

    @property
    def admissions(self) -> int:
        return self._admissions.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    @property
    def page_occupancy(self) -> float:
        return self._page_occupancy.value

    @property
    def prefix_hit_rate(self) -> float:
        return self._prefix_hit_rate.value

    @property
    def cow_copies(self) -> int:
        return int(self._cow_copies.value)

    @property
    def shed(self) -> int:
        return self._shed.value

    @property
    def cancelled(self) -> int:
        return self._cancelled.value

    @property
    def deadline_misses(self) -> int:
        return self._deadline_miss.value

    @property
    def rejected_queue_full(self) -> int:
        return self._rejected_queue_full.value

    @property
    def watchdog_stalls(self) -> int:
        return self._watchdog_stalls.value

    @property
    def brownout_clamped(self) -> int:
        return self._brownout_clamped.value

    @property
    def goodput_tokens(self) -> int:
        return self._goodput_tokens.value

    @property
    def brownout_level(self) -> int:
        return int(self._brownout_level.value)

    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / max(self.prefill_time, 1e-9)

    def decode_tok_s(self) -> float:
        return self.decoded_tokens / max(self.decode_time, 1e-9)

    def goodput_tok_s(self) -> float:
        """Tokens delivered within deadline per second of engine time."""
        return (self.goodput_tokens
                / max(self.decode_time + self.prefill_time, 1e-9))

    def token_latency_percentiles(self, qs=(50, 99)) -> dict:
        """Per-token latency over the stats window: each live token in a
        step experienced that step's wall time."""
        if not self.step_times:
            return {q: 0.0 for q in qs}
        lats = np.repeat(np.fromiter(self.step_times, np.float64),
                         np.fromiter(self.step_tokens, np.int64))
        return {q: float(np.percentile(lats, q)) for q in qs}

    def ttft_percentiles(self, qs=(50, 99)) -> dict:
        """Submit -> first-token latency (queue wait + prefill) over the
        most recent requests."""
        if not self.ttfts:
            return {q: 0.0 for q in qs}
        arr = np.fromiter(self.ttfts, np.float64)
        return {q: float(np.percentile(arr, q)) for q in qs}

    def queue_wait_percentiles(self, qs=(50, 99)) -> dict:
        if not self.queue_waits:
            return {q: 0.0 for q in qs}
        arr = np.fromiter(self.queue_waits, np.float64)
        return {q: float(np.percentile(arr, q)) for q in qs}


class Engine:
    """Continuous-batching inference engine over a fixed slot pool."""

    def __init__(self, model, params, *, max_slots: int = 8,
                 max_seq: int = 256, prefill_chunk: int = 32,
                 mesh=None, fused_sampling: bool = False,
                 unroll: bool = False, attn_impl: str | None = None,
                 page_size: int = 16, num_pages: int = 0,
                 prefix_cache: bool = True,
                 max_queue: int = 0, shed_policy: str = "reject-newest",
                 watchdog_k: float = 6.0, brownout: bool = True,
                 brownout_max_new: int = 16, finished_keep: int = 4096,
                 guardrails: bool = True, clock=None, cost_model=None):
        """``page_size`` > 0 (the default) runs the paged KV cache: slots
        share a physical page pool through block tables, sized by
        ``num_pages`` (0 = worst-case auto: every slot can still reach
        ``max_seq``). ``page_size=0`` keeps the contiguous per-slot pool —
        the parity oracle and the A/B baseline for density benchmarks.
        ``prefix_cache`` hands shared page-aligned prompt prefixes to new
        requests by refcount (attention families only; SSM state is not
        reconstructible from cache pages, so it is ignored there).

        SLO guardrails (all host-side; see DESIGN.md "Serve robustness"):
        ``max_queue`` bounds the submit queue (0 = unbounded) with
        ``shed_policy`` deciding who loses on overflow; requests may carry
        ``deadline_ms``/``max_queue_ms`` budgets — hopeless queued
        requests are shed at admission time and in-flight requests past
        deadline are cancelled at step boundaries; ``watchdog_k`` flags a
        decode dispatch slower than k x the step-time EWMA; ``brownout``
        degrades service under sustained page-pool pressure (prefix-cache
        registration off, then ``max_new`` clamped to
        ``brownout_max_new``) before admissions start blocking.
        ``guardrails=False`` disables all enforcement (budgets are still
        recorded, so goodput can be measured post-hoc — the A/B baseline).
        ``clock``/``cost_model`` are the determinism seams
        ``repro.serve.chaos`` drives virtual time through; production
        leaves both at None (wall clock)."""
        cfg = model.cfg
        if cfg.family != "decoder":
            raise ValueError(f"serve engine supports decoder models, "
                             f"got family={cfg.family!r}")
        if attn_impl and cfg.attention is not None:
            # pin the attention implementation for this engine (prefill's
            # q-chunk x cache tiles and decode's split-KV both route
            # through it); attention-less families (pure SSM) ignore it
            from repro.configs.base import with_attn_impl
            from repro.models import build_model
            cfg = with_attn_impl(cfg, attn_impl)
            model = build_model(cfg)
        if cfg.ssm is not None and prefill_chunk % cfg.ssm.chunk:
            # SSD block boundaries must align across chunked calls for the
            # cache state to match a single-call prefill bitwise
            prefill_chunk += cfg.ssm.chunk - prefill_chunk % cfg.ssm.chunk
        if max_seq % prefill_chunk:
            # every chunk writes a full [pos0, pos0+C) window; if the last
            # window could cross max_seq, dynamic_update_slice would clamp
            # pos0 and silently overwrite earlier prompt rows — round the
            # pool up so ceil(S0/C)*C <= max_seq for any admissible S0
            max_seq += prefill_chunk - max_seq % prefill_chunk
        if page_size > 0 and max_seq % page_size:
            # block tables cover whole pages; growing max_seq keeps the
            # prefill-chunk invariant above intact
            max_seq += page_size - max_seq % page_size
        self.model = model
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.mesh = mesh
        self.fused_sampling = fused_sampling
        self.unroll = unroll
        self.guardrails = guardrails
        self.watchdog_k = watchdog_k
        self.brownout = brownout and guardrails
        self.brownout_max_new = brownout_max_new
        self._brownout_level = 0
        self._hot = [0, 0]       # consecutive steps above HI1 / HI2
        self._cool = 0           # consecutive steps below LO
        self.draining = False
        self._clock = clock or time.perf_counter
        self._cost_model = cost_model
        self.trace_counts = {"prefill": 0, "decode": 0, "sample": 0}

        if mesh is not None:
            from repro.dist.sharding import param_shardings
            params = jax.device_put(params, param_shardings(mesh, params))
        self.params = params

        # pure-SSM families have no sequence-dim leaves to page: fall back
        # to the slot-granular pool automatically
        self.paged = bool(page_size > 0 and model.init_paged_cache is not None
                          and cfg.attention is not None)
        self.page_size = page_size if self.paged else 0
        self.allocator = None
        sched_kw = dict(max_queue=max_queue if guardrails else 0,
                        shed_policy=shed_policy,
                        finished_keep=finished_keep, clock=self._clock)
        if self.paged:
            pps = max_seq // page_size
            if num_pages <= 0:
                # worst case (every slot at max_seq) + null page + one
                # spare so a full-prompt-hit COW never waits
                num_pages = max_slots * pps + 2
            self.num_pages = num_pages
            pool = cache_mod.make_paged_pool(model, max_slots, page_size,
                                             num_pages)
            assert cache_mod.has_paged_leaves(pool)
            self.pool = cache_mod.place_pool(mesh, pool, max_slots,
                                             num_pages)
            self.allocator = cache_mod.PageAllocator(
                num_pages, page_size, max_slots, pps,
                prefix_cache=prefix_cache and cfg.ssm is None)
            self.sched = SlotScheduler(max_slots, max_seq,
                                       allocator=self.allocator, **sched_kw)
        else:
            self.num_pages = 0
            self.pool = cache_mod.place_pool(
                mesh, cache_mod.make_pool(model, max_slots, max_seq),
                max_slots)
            self.sched = SlotScheduler(max_slots, max_seq, **sched_kw)
        # block tables enter every dispatch as one same-shaped int32 array
        # (a (1, 1) dummy keeps the contiguous signature stable)
        self._no_tables = jnp.zeros((1, 1), jnp.int32)
        self.stats = EngineStats()
        if telemetry.enabled():
            telemetry.attach_registry(self.stats.registry)

        # per-slot sampling state (host mirrors; uploaded per dispatch)
        self._temps = np.zeros((max_slots,), np.float32)
        self._top_ks = np.zeros((max_slots,), np.int32)
        self._top_ps = np.ones((max_slots,), np.float32)
        self._keys = jnp.zeros((max_slots, 2), jnp.uint32)

        # first dispatch captures cost_analysis (lower() shares the jit
        # trace cache, so trace_counts still sees exactly one trace) and
        # records the blocked compile time as compile/serve_* gauges
        self._prefill = profile.instrument(
            "serve/prefill_chunk",
            jax.jit(self._prefill_fn, donate_argnums=(1,)))
        self._decode = profile.instrument(
            "serve/decode_step",
            jax.jit(self._decode_fn, donate_argnums=(1,)))
        self._sample_prefill = jax.jit(self._sample_prefill_fn)
        self._prefill_warm = False  # first chunk dispatch is the compile
        self._det_step = anomaly.StreamDetector(
            "serve/step_time", registry=self.stats.registry)

    # -- traced steps -------------------------------------------------------

    def _prefill_fn(self, params, pool, tokens, slot, pos0, valid, tables):
        """One prompt chunk into one slot's cache lane (contiguous) or its
        block-table pages (paged; ``tables`` row ``slot`` routes the
        chunk's scatter/gather)."""
        self.trace_counts["prefill"] += 1
        if self.paged:
            view = cache_mod.paged_view(pool, slot)
            row = jax.lax.dynamic_slice_in_dim(tables, slot, 1, axis=0)
            logits, view = self.model.chunk_prefill(
                params, view, tokens, pos0, valid, seq_len=self.max_seq,
                unroll=self.unroll, block_tables=row,
                page_size=self.page_size)
            return cache_mod.paged_write(pool, slot, view), logits
        view = cache_mod.slot_view(pool, slot)
        logits, view = self.model.chunk_prefill(
            params, view, tokens, pos0, valid, seq_len=self.max_seq,
            unroll=self.unroll)
        return cache_mod.slot_write(pool, slot, view), logits

    def _sample_prefill_fn(self, logits, valid, temp, top_k, top_p, key):
        """Sample the prompt continuation from the last valid prefill row."""
        self.trace_counts["sample"] += 1
        k_use, k_next = jax.random.split(key)
        if self.fused_sampling:
            from repro.kernels.slot_gather import slot_gather_sample
            C = logits.shape[1]
            onehot = (jnp.arange(C) == valid - 1).astype(jnp.float32)[None]
            noise = jax.random.gumbel(k_use, (1, logits.shape[-1]),
                                      jnp.float32)
            greedy, sampled = slot_gather_sample(logits, onehot,
                                                 temp[None], noise)
            tok = jnp.where(temp <= 0.0, greedy[0], sampled[0])
        else:
            row = jax.lax.dynamic_index_in_dim(logits[0], valid - 1, axis=0,
                                               keepdims=False)
            noise = jax.random.gumbel(k_use, (1, row.shape[-1]), jnp.float32)
            tok = sampling_mod.sample_tokens(
                row[None], temp[None], top_k[None], top_p[None], noise)[0]
        return tok, k_next

    def _decode_fn(self, params, pool, tokens, pos, temps, top_ks, top_ps,
                   keys, tables):
        """One decode step for the whole slot pool + fused sampling."""
        self.trace_counts["decode"] += 1
        if self.paged:
            logits, pool = self.model.decode_step(
                params, pool, {"tokens": tokens}, pos, seq_len=self.max_seq,
                unroll=self.unroll, block_tables=tables,
                page_size=self.page_size)
        else:
            logits, pool = self.model.decode_step(
                params, pool, {"tokens": tokens}, pos, seq_len=self.max_seq,
                unroll=self.unroll)
        ks = jax.vmap(jax.random.split)(keys)        # (S, 2, 2)
        k_use, k_next = ks[:, 0], ks[:, 1]
        # all-greedy steps (the default) skip the (S, V) Gumbel draw
        noise = jax.lax.cond(
            jnp.any(temps > 0.0),
            lambda k: sampling_mod.gumbel_noise(k, logits.shape[-1]),
            lambda k: jnp.zeros((keys.shape[0], logits.shape[-1]),
                                jnp.float32), k_use)
        if self.fused_sampling:
            from repro.kernels.slot_gather import slot_gather_sample
            onehot = jnp.ones((logits.shape[0], 1), jnp.float32)
            greedy, sampled = slot_gather_sample(logits, onehot, temps,
                                                 noise)
            tok = jnp.where(temps <= 0.0, greedy, sampled)
        else:
            tok = sampling_mod.sample_tokens(logits[:, 0, :], temps, top_ks,
                                             top_ps, noise)
        return pool, tok, k_next

    # -- host loop ----------------------------------------------------------

    def submit(self, tokens, max_new: int,
               sampling: SamplingParams | None = None,
               eos: int | None = None, *,
               deadline_ms: float | None = None,
               max_queue_ms: float | None = None) -> AdmissionResult:
        """Queue a request. Returns a typed :class:`AdmissionResult` that
        coerces to the request id when accepted; a full bounded queue (or
        a draining engine) rejects with zero state mutated. Malformed or
        never-fits requests still raise ``ValueError``."""
        sampling = sampling or SamplingParams()
        if self.fused_sampling and sampling_mod.needs_full_path(sampling):
            raise ValueError("fused_sampling engine handles greedy/"
                             "temperature only; top-k/top-p need the full "
                             "path (fused_sampling=False)")
        if self.draining:
            self.stats.record_rejection()
            return AdmissionResult(-1, REJECTED_QUEUE_FULL,
                                   "engine draining")
        req = Request(tokens=list(map(int, tokens)), max_new=max_new,
                      sampling=sampling, eos=eos, deadline_ms=deadline_ms,
                      max_queue_ms=max_queue_ms)
        res = self.sched.submit(req)
        if not res:
            self.stats.record_rejection()
        self._account_finished()    # a displaced victim (reject-no-deadline)
        self.stats.set_queue_depth(self.sched.queue_depth)
        return res

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it is (queued or in-flight); pages and
        refcounts are released exactly as on a natural finish. Returns
        False for unknown/finished rids."""
        ok = self.sched.cancel(rid)
        if ok:
            self._account_finished()
        return ok

    def _bind_slot(self, slot: int, req: Request) -> None:
        s = req.sampling
        self._temps[slot] = s.temperature
        self._top_ks[slot] = s.top_k
        self._top_ps[slot] = s.top_p
        # seed only — a request's sample stream is a pure function of
        # (params, prompt, seed), independent of submission order
        self._keys = self._keys.at[slot].set(jax.random.PRNGKey(s.seed))

    def _tables(self):
        """The block tables for the next dispatch (same-shaped int32 every
        time — values churn, shapes never do)."""
        if self.allocator is None:
            return self._no_tables
        return jnp.asarray(self.allocator.tables)

    def _make_writable(self, slot: int, lo: int, hi: int) -> None:
        """Pages covering rows [lo, hi) of ``slot`` become privately
        writable before a dispatch writes them: first touch allocates off
        the free list, a prefix-shared page copies-on-write."""
        ps = self.page_size
        for j in range(lo // ps, -(-hi // ps)):
            for dst, src in self.allocator.ensure_writable(slot, j * ps):
                self.pool = cache_mod.copy_page(self.pool, jnp.int32(dst),
                                                jnp.int32(src))

    def _prefill_request(self, slot: int, req: Request) -> None:
        self._bind_slot(slot, req)
        toks = np.asarray(req.tokens, np.int32)
        S0, C = len(req.tokens), self.prefill_chunk
        # prefix-cache hits skip their pages entirely; a full-prompt hit
        # still re-runs the last prompt token for its sampling logits (the
        # write COWs the shared final page, keeping the cached copy clean)
        hit = self.sched.slots[slot].hit_tokens
        start = S0 - 1 if hit >= S0 else hit
        t0 = self._clock()
        with trace.span("serve/prefill", slot=slot, rid=req.rid, tokens=S0,
                        cached=hit):
            if self.cfg.ssm is not None:
                # SSM state/conv carry across prefill chunks by design, so
                # a previous occupant's state must not leak in. Attention
                # lanes need no zeroing: stale rows are causally masked
                # until overwritten in order (paged slots start from the
                # null table anyway) — admission cost is O(d_state), not
                # the old O(max_seq) full-lane wipe.
                self.pool = cache_mod.reset_slot_ssm(self.pool,
                                                     jnp.int32(slot))
            logits = None
            for c in range(start, S0, C):
                sl = toks[c:c + C]
                valid = len(sl)
                if valid < C:
                    sl = np.pad(sl, (0, C - valid))
                if self.paged:
                    self._make_writable(slot, c, c + valid)
                t_c = self._clock()
                self.pool, logits = self._prefill(
                    self.params, self.pool, jnp.asarray(sl[None]),
                    jnp.int32(slot), jnp.int32(c), jnp.int32(valid),
                    self._tables())
                if self._cost_model is not None:
                    self._clock.advance(self._cost_model("prefill_chunk", C))
                if self._prefill_warm:
                    profile.observe("serve/prefill_chunk",
                                    self._clock() - t_c)
                else:
                    self._prefill_warm = True
            if self.allocator is not None and self._brownout_level < 1:
                # brownout level >= 1 stops publishing new prefixes —
                # cache holds are exactly the pressure being shed
                self.allocator.register_prefix(slot, toks)
            tok, k_next = self._sample_prefill(
                logits, jnp.int32(valid),
                jnp.float32(req.sampling.temperature),
                jnp.int32(req.sampling.top_k),
                jnp.float32(req.sampling.top_p),
                self._keys[slot])
            tok = int(tok)
        self._keys = self._keys.at[slot].set(k_next)
        self.stats.record_prefill(S0 - start, self._clock() - t0)
        self.sched.record_first_token(slot, tok)
        self.stats.record_first_token(req.ttft)

    def _account_finished(self) -> None:
        """Drain the scheduler's finish-event stream into the stats —
        evictions (a finish/cancel frees its slot mid-flight), shed/cancel
        counters, deadline misses and goodput. Event-driven, so it
        survives ``pop_finished`` hand-offs and drain/restore cycles (the
        old ``len(finished)`` watermark did not)."""
        while self.sched.finish_log:
            self.stats.record_finish(self.sched.finish_log.popleft())

    # -- SLO guardrails (all host-side, between dispatches) -----------------

    def _estimate_service_s(self, req: Request) -> float:
        """Cheap admission-time completion estimate from measured rates:
        prompt tokens over the prefill rate plus ``max_new`` decode steps
        at the step-time EWMA. Unmeasured components contribute 0 — a
        cold engine never sheds on a blind guess."""
        est = 0.0
        st = self.stats
        if st.prefill_tokens > 0 and st.prefill_time > 0:
            est += len(req.tokens) / st.prefill_tok_s()
        if st.step_ewma is not None:
            est += req.max_new * st.step_ewma
        return est

    def _shed_hopeless(self, now: float) -> None:
        """Shed queued requests whose queue budget is blown or whose
        deadline can no longer be met (anywhere in the queue — an
        impossible head must not block feasible work behind it)."""
        for req in list(self.sched.pending):
            over_queue = (req.max_queue_ms is not None
                          and now - req.t_submit > req.max_queue_ms / 1e3)
            dl = req.deadline_at
            hopeless = (dl is not None
                        and now + self._estimate_service_s(req) > dl)
            if over_queue or hopeless:
                self.sched.shed_queued(req, FINISH_SHED)
                trace.instant("serve/shed", rid=req.rid,
                              why="queue_budget" if over_queue
                              else "deadline_unmeetable")

    def _update_brownout(self, occupancy: float) -> None:
        """Walk the brownout ladder on sustained page-pool pressure:
        level 1 stops prefix-cache registration, level 2 additionally
        clamps new admissions' ``max_new`` — degradation before refusal.
        Hysteresis: entering needs ``BROWNOUT_PATIENCE`` consecutive hot
        steps, leaving needs the same below the low watermark."""
        if occupancy >= BROWNOUT_HI1:
            self._hot[0] += 1
            self._hot[1] = self._hot[1] + 1 if occupancy >= BROWNOUT_HI2 \
                else 0
            self._cool = 0
        else:
            self._hot = [0, 0]
            self._cool = self._cool + 1 if occupancy < BROWNOUT_LO else 0
        if self._hot[1] >= BROWNOUT_PATIENCE:
            level = 2
        elif self._hot[0] >= BROWNOUT_PATIENCE:
            level = max(self._brownout_level, 1)
        elif self._cool >= BROWNOUT_PATIENCE:
            level = 0
        else:
            level = self._brownout_level
        if level != self._brownout_level:
            trace.instant("serve/brownout", level=level,
                          occupancy=round(occupancy, 3))
        self._brownout_level = level
        self.stats.set_brownout_level(level)
        if level >= 2:
            for req in self.sched.pending:
                if req.max_new > self.brownout_max_new:
                    req.max_new = self.brownout_max_new
                    self.stats.record_brownout_clamp()

    def step(self) -> int:
        """Admit + prefill new requests, run one decode dispatch over the
        pool. Returns the number of live tokens produced."""
        now = self._clock()
        if self.guardrails:
            if not self.draining:
                self._shed_hopeless(now)
            for rid in self.sched.cancel_past_deadline(now):
                trace.instant("serve/deadline_cancel", rid=rid)
        self._account_finished()
        if not self.draining:
            for slot, req in self.sched.admit():
                self.stats.record_admission(req.queue_wait)
                self._prefill_request(slot, req)
        self._account_finished()       # max_new=1/eos at first token
        n_active = self.sched.num_active
        self.stats.set_queue_depth(self.sched.queue_depth)
        if self.allocator is not None:
            occ = self.allocator.occupancy()
            self.stats.set_page_stats(occ, self.allocator.hit_rate(),
                                      self.allocator.cow_copies)
            if self.brownout:
                self._update_brownout(occ)
        else:
            self.stats.set_page_stats(n_active / self.max_slots, 0.0, 0)
        if n_active == 0:
            return 0
        if self.paged:
            # the step writes cache row st.pos per live slot: make the
            # covering page private first (idle slots park on the null
            # page and need nothing)
            for slot, st in enumerate(self.sched.slots):
                if st is not None:
                    self._make_writable(slot, st.pos, st.pos + 1)
        tokens = jnp.asarray(self.sched.feed_tokens(),
                             jnp.int32)[:, None]
        pos = jnp.asarray(self.sched.positions(), jnp.int32)
        ewma_prior = self.stats.step_ewma
        t0 = self._clock()
        with trace.span("serve/decode_step", active=n_active):
            self.pool, tok, self._keys = self._decode(
                self.params, self.pool, tokens, pos,
                jnp.asarray(self._temps), jnp.asarray(self._top_ks),
                jnp.asarray(self._top_ps), self._keys, self._tables())
            tok = np.asarray(tok)                     # sync point
        if self._cost_model is not None:
            self._clock.advance(self._cost_model("decode", n_active))
        dt = self._clock() - t0
        if self.stats.steps > 0:     # step 0 is the compile dispatch
            profile.observe("serve/decode_step", dt)
            self._det_step.observe(dt)
            if (self.guardrails and ewma_prior is not None
                    and dt > self.watchdog_k * ewma_prior):
                # the stuck-step watchdog: this dispatch blew far past the
                # EWMA the anomaly detector tracks — flag it (host-side;
                # a wedged device shows up here before anything else)
                self.stats.record_watchdog()
                trace.instant("serve/watchdog_stall", dt=round(dt, 6),
                              ewma=round(ewma_prior, 6), k=self.watchdog_k)
        self.sched.record_step(tok)
        self._account_finished()
        self.stats.record_decode(n_active, dt)
        return n_active

    def run(self) -> dict:
        """Drive to completion; returns {request id: generated tokens}."""
        while self.sched.has_work():
            self.step()
        return self.sched.results()

    # -- graceful drain + crash-safe restore --------------------------------

    def drain(self, path: str | None = None, *,
              max_steps: int | None = None) -> dict:
        """Graceful drain: stop admitting, finish what's in flight (up to
        ``max_steps`` dispatches), snapshot the host-side request state.
        Queued — and any still-unfinished in-flight — requests are
        recorded by prompt; a restored engine re-runs them from scratch,
        which is bit-identical for greedy (and for seeded sampling) decode.
        ``path`` writes the snapshot crash-safely (temp file + fsync +
        atomic rename, crc32-stamped — the PR 7 checkpoint idiom)."""
        self.draining = True
        steps = 0
        while (self.sched.num_active > 0
               and (max_steps is None or steps < max_steps)):
            self.step()
            steps += 1
        self._account_finished()
        snap = self._snapshot()
        if path is not None:
            payload = json.dumps(snap, sort_keys=True).encode()
            from repro.checkpoint.ckpt import _atomic_write
            _atomic_write(path, json.dumps(
                {"schema": SNAPSHOT_SCHEMA, "crc": zlib.crc32(payload),
                 "payload": snap}, sort_keys=True).encode())
        trace.instant("serve/drain", steps=steps,
                      queued=len(snap["queued"]),
                      inflight=len(snap["inflight"]))
        return snap

    def _snapshot(self) -> dict:
        sched = self.sched
        return {
            "rid_next": sched._next_rid,
            "queued": [r.to_state() for r in sched.pending],
            "inflight": [st.req.to_state() for st in sched.slots
                         if st is not None],
            "finished": [{"req": st.req.to_state(),
                          "generated": list(st.generated),
                          "reason": st.req.finish_reason}
                         for st in sched.finished.values()],
            "finished_total": sched.finished_total,
            "finished_dropped": sched.finished_dropped,
        }

    def load_snapshot(self, path_or_snap) -> list:
        """Restore a drained engine's unfinished work into THIS (freshly
        constructed) engine: finished results come back verbatim, queued
        and interrupted in-flight requests are re-queued under their
        original rids (outputs stay keyed identically; deadlines restart
        from now). Returns the re-queued rids. A corrupt snapshot file
        fails loudly (crc32 mismatch)."""
        if isinstance(path_or_snap, str):
            with open(path_or_snap, "rb") as f:
                wrapper = json.load(f)
            payload = json.dumps(wrapper["payload"], sort_keys=True).encode()
            if zlib.crc32(payload) != wrapper["crc"]:
                raise ValueError(
                    f"serve snapshot {path_or_snap!r} failed its crc32 "
                    f"integrity check")
            snap = wrapper["payload"]
        else:
            snap = path_or_snap
        sched = self.sched
        if sched.finished or sched.has_work():
            raise ValueError("load_snapshot needs a fresh engine")
        for ent in snap["finished"]:
            req = Request.from_state(ent["req"])
            req.finish_reason = ent["reason"]
            sched.finished[req.rid] = SlotState(
                req=req, generated=list(ent["generated"]), done=True)
        sched.finished_total = int(snap["finished_total"])
        sched.finished_dropped = int(snap["finished_dropped"])
        sched._next_rid = int(snap["rid_next"])
        requeued = []
        # interrupted in-flight requests re-run from their prompts, ahead
        # of the still-queued tail — the original FIFO order survives
        for ent in snap["inflight"] + snap["queued"]:
            req = Request.from_state(ent)
            sched.resubmit(req)
            requeued.append(req.rid)
        self.stats.set_queue_depth(sched.queue_depth)
        return requeued

    def reset_stats(self) -> None:
        """Zero the timing stats (post-warmup). ``trace_counts`` is *not*
        reset: compile-once is a property of the engine's lifetime."""
        telemetry.detach_registry(self.stats.registry)
        self.stats = EngineStats()
        self._det_step = anomaly.StreamDetector(
            "serve/step_time", registry=self.stats.registry)
        if telemetry.enabled():
            telemetry.attach_registry(self.stats.registry)
