"""Continuous-batching decode engine.

Design contract (the reason this engine never recompiles):

- **Fixed-slot request pool.** The jitted decode step always sees
  ``(max_slots, 1)`` tokens, a ``(max_slots,)`` position vector, the full
  cache pool, and ``(max_slots,)`` sampling-parameter vectors. Requests
  joining or leaving only change the *values* in those arrays, never a
  shape — the step compiles exactly once per process (asserted in
  ``tests/test_serve.py`` via ``trace_counts``).
- **Per-slot positions.** Every lane decodes at its own depth
  (``decoder_decode_step`` with a (B,) position vector); a freed lane is
  reused immediately by the next queued request.
- **Chunked whole-prompt prefill.** A new request's prompt is written into
  its slot's cache lane by ``model.chunk_prefill`` in ``prefill_chunk``-
  token chunks — one model call per chunk instead of one per token, with
  the LM head applied once. For SSM/hybrid families the chunk is rounded
  up to a multiple of ``cfg.ssm.chunk`` so the SSD block decomposition
  aligns with a single-call prefill bit-for-bit.
- **Paged KV cache (default).** Attention lanes live in a shared pool of
  fixed-size pages routed per slot by a block table (``page_size``,
  ``num_pages``); the host-side :class:`~repro.serve.cache.PageAllocator`
  owns the free list, refcounts and the hashed prefix cache, so admission
  capacity follows what the traffic actually holds, not ``max_slots *
  max_seq`` worst case. Block tables enter the jitted programs as
  same-shaped int32 inputs per dispatch — compile-once still holds under
  churn. ``page_size=0`` selects the contiguous per-slot pool (the parity
  oracle). See DESIGN.md "Paged KV cache & prefix caching".
- **Slot-independent numerics.** Greedy decode of a request is bit-exact
  with ``repro.train.serve.generate`` on the same prompt no matter what
  the other slots are doing (MoE routes drop-free at decode/prefill;
  attention/SSM lanes are batch-independent) — the property the parity
  tests pin per family.

Sampling is fused into the decode dispatch: greedy/temperature/top-k/top-p
with per-request parameters and per-slot PRNG keys in the same jit
(``fused_sampling=True`` additionally routes the greedy/temperature fast
path through the ``slot_gather`` Pallas kernel).

The engine is synchronous: admission and prefill happen between decode
steps (a prefill stall bounded by ``prefill_chunk``), which keeps the loop
deterministic and testable; see DESIGN.md "Serving engine" for the slot
lifecycle diagram.
"""
from __future__ import annotations

import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.telemetry import anomaly, profile, trace
from repro.telemetry.registry import Registry
from repro.serve import cache as cache_mod
from repro.serve import sampling as sampling_mod
from repro.serve.scheduler import Request, SamplingParams, SlotScheduler


STATS_WINDOW = 4096   # decode steps of latency history kept for percentiles


class EngineStats:
    """Serve statistics, backed by a telemetry :class:`Registry`.

    The public surface (``prefill_tokens``, ``decode_tok_s()``,
    ``token_latency_percentiles()``, ...) is unchanged from the old
    dataclass, but every scalar now lives in a registry metric
    (``serve/...`` counters/gauges/histograms) owned by this object — so
    a ``--metrics-out`` dump exports exactly the numbers the stats report,
    with no second bookkeeping path. The registry is private and always
    live (the stats must work with ``REPRO_TELEMETRY=0``); when telemetry
    is enabled the engine attaches it to the process-wide export stream.

    Exact (non-bucketed) p50/p99 readbacks keep the bounded deque windows
    the old implementation used; the registry histograms carry the same
    observations for the JSONL view.
    """

    def __init__(self):
        r = self.registry = Registry(label="serve")
        self._prefill_tokens = r.counter("serve/prefill_tokens")
        self._prefill_time = r.counter("serve/prefill_time_s")
        self._decoded_tokens = r.counter("serve/decoded_tokens")
        self._decode_time = r.counter("serve/decode_time_s")
        self._steps = r.counter("serve/decode_steps")
        self._admissions = r.counter("serve/admissions")
        self._evictions = r.counter("serve/evictions")
        self._page_occupancy = r.gauge("serve/page_occupancy")
        self._prefix_hit_rate = r.gauge("serve/prefix_hit_rate")
        self._cow_copies = r.gauge("serve/cow_copies")
        self._h_step = r.histogram("serve/step_time_s")
        self._h_ttft = r.histogram("serve/ttft_s")
        self._h_queue = r.histogram("serve/queue_wait_s")
        # bounded windows (a long-running server must not grow per step):
        # seconds per dispatch / live tokens per dispatch / per-request
        self.step_times: deque = deque(maxlen=STATS_WINDOW)
        self.step_tokens: deque = deque(maxlen=STATS_WINDOW)
        self.ttfts: deque = deque(maxlen=STATS_WINDOW)
        self.queue_waits: deque = deque(maxlen=STATS_WINDOW)

    # -- the recording path (engine-internal) -------------------------------

    def record_prefill(self, tokens: int, dt: float) -> None:
        self._prefill_tokens.inc(tokens)
        self._prefill_time.inc(dt)

    def record_admission(self, queue_wait: float) -> None:
        self._admissions.inc()
        self._h_queue.observe(queue_wait)
        self.queue_waits.append(queue_wait)

    def record_first_token(self, ttft: float) -> None:
        self._h_ttft.observe(ttft)
        self.ttfts.append(ttft)

    def record_decode(self, n_active: int, dt: float) -> None:
        self._steps.inc()
        self._decode_time.inc(dt)
        self._decoded_tokens.inc(n_active)
        self._h_step.observe(dt)
        self.step_times.append(dt)
        self.step_tokens.append(n_active)

    def record_evictions(self, n: int) -> None:
        self._evictions.inc(n)

    def set_page_stats(self, occupancy: float, hit_rate: float,
                       cow: int) -> None:
        """Cache-health gauges, refreshed per step. In a paged engine they
        come from the :class:`~repro.serve.cache.PageAllocator` (fraction
        of the physical page pool holding live/cached rows, prefix-cache
        hit rate over page lookups, cumulative copy-on-write page copies);
        the contiguous fallback reports slot-pool occupancy and zeros."""
        self._page_occupancy.set(occupancy)
        self._prefix_hit_rate.set(hit_rate)
        self._cow_copies.set(cow)

    # -- the read surface (public, unchanged + TTFT/queue-wait) -------------

    @property
    def prefill_tokens(self) -> int:
        return self._prefill_tokens.value

    @property
    def prefill_time(self) -> float:
        return self._prefill_time.value

    @property
    def decoded_tokens(self) -> int:
        return self._decoded_tokens.value

    @property
    def decode_time(self) -> float:
        return self._decode_time.value

    @property
    def steps(self) -> int:
        return self._steps.value

    @property
    def admissions(self) -> int:
        return self._admissions.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    @property
    def page_occupancy(self) -> float:
        return self._page_occupancy.value

    @property
    def prefix_hit_rate(self) -> float:
        return self._prefix_hit_rate.value

    @property
    def cow_copies(self) -> int:
        return int(self._cow_copies.value)

    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / max(self.prefill_time, 1e-9)

    def decode_tok_s(self) -> float:
        return self.decoded_tokens / max(self.decode_time, 1e-9)

    def token_latency_percentiles(self, qs=(50, 99)) -> dict:
        """Per-token latency over the stats window: each live token in a
        step experienced that step's wall time."""
        if not self.step_times:
            return {q: 0.0 for q in qs}
        lats = np.repeat(np.fromiter(self.step_times, np.float64),
                         np.fromiter(self.step_tokens, np.int64))
        return {q: float(np.percentile(lats, q)) for q in qs}

    def ttft_percentiles(self, qs=(50, 99)) -> dict:
        """Submit -> first-token latency (queue wait + prefill) over the
        most recent requests."""
        if not self.ttfts:
            return {q: 0.0 for q in qs}
        arr = np.fromiter(self.ttfts, np.float64)
        return {q: float(np.percentile(arr, q)) for q in qs}

    def queue_wait_percentiles(self, qs=(50, 99)) -> dict:
        if not self.queue_waits:
            return {q: 0.0 for q in qs}
        arr = np.fromiter(self.queue_waits, np.float64)
        return {q: float(np.percentile(arr, q)) for q in qs}


class Engine:
    """Continuous-batching inference engine over a fixed slot pool."""

    def __init__(self, model, params, *, max_slots: int = 8,
                 max_seq: int = 256, prefill_chunk: int = 32,
                 mesh=None, fused_sampling: bool = False,
                 unroll: bool = False, attn_impl: str | None = None,
                 page_size: int = 16, num_pages: int = 0,
                 prefix_cache: bool = True):
        """``page_size`` > 0 (the default) runs the paged KV cache: slots
        share a physical page pool through block tables, sized by
        ``num_pages`` (0 = worst-case auto: every slot can still reach
        ``max_seq``). ``page_size=0`` keeps the contiguous per-slot pool —
        the parity oracle and the A/B baseline for density benchmarks.
        ``prefix_cache`` hands shared page-aligned prompt prefixes to new
        requests by refcount (attention families only; SSM state is not
        reconstructible from cache pages, so it is ignored there)."""
        cfg = model.cfg
        if cfg.family != "decoder":
            raise ValueError(f"serve engine supports decoder models, "
                             f"got family={cfg.family!r}")
        if attn_impl and cfg.attention is not None:
            # pin the attention implementation for this engine (prefill's
            # q-chunk x cache tiles and decode's split-KV both route
            # through it); attention-less families (pure SSM) ignore it
            from repro.configs.base import with_attn_impl
            from repro.models import build_model
            cfg = with_attn_impl(cfg, attn_impl)
            model = build_model(cfg)
        if cfg.ssm is not None and prefill_chunk % cfg.ssm.chunk:
            # SSD block boundaries must align across chunked calls for the
            # cache state to match a single-call prefill bitwise
            prefill_chunk += cfg.ssm.chunk - prefill_chunk % cfg.ssm.chunk
        if max_seq % prefill_chunk:
            # every chunk writes a full [pos0, pos0+C) window; if the last
            # window could cross max_seq, dynamic_update_slice would clamp
            # pos0 and silently overwrite earlier prompt rows — round the
            # pool up so ceil(S0/C)*C <= max_seq for any admissible S0
            max_seq += prefill_chunk - max_seq % prefill_chunk
        if page_size > 0 and max_seq % page_size:
            # block tables cover whole pages; growing max_seq keeps the
            # prefill-chunk invariant above intact
            max_seq += page_size - max_seq % page_size
        self.model = model
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.mesh = mesh
        self.fused_sampling = fused_sampling
        self.unroll = unroll
        self.trace_counts = {"prefill": 0, "decode": 0, "sample": 0}

        if mesh is not None:
            from repro.dist.sharding import param_shardings
            params = jax.device_put(params, param_shardings(mesh, params))
        self.params = params

        # pure-SSM families have no sequence-dim leaves to page: fall back
        # to the slot-granular pool automatically
        self.paged = bool(page_size > 0 and model.init_paged_cache is not None
                          and cfg.attention is not None)
        self.page_size = page_size if self.paged else 0
        self.allocator = None
        if self.paged:
            pps = max_seq // page_size
            if num_pages <= 0:
                # worst case (every slot at max_seq) + null page + one
                # spare so a full-prompt-hit COW never waits
                num_pages = max_slots * pps + 2
            self.num_pages = num_pages
            pool = cache_mod.make_paged_pool(model, max_slots, page_size,
                                             num_pages)
            assert cache_mod.has_paged_leaves(pool)
            self.pool = cache_mod.place_pool(mesh, pool, max_slots,
                                             num_pages)
            self.allocator = cache_mod.PageAllocator(
                num_pages, page_size, max_slots, pps,
                prefix_cache=prefix_cache and cfg.ssm is None)
            self.sched = SlotScheduler(max_slots, max_seq,
                                       allocator=self.allocator)
        else:
            self.num_pages = 0
            self.pool = cache_mod.place_pool(
                mesh, cache_mod.make_pool(model, max_slots, max_seq),
                max_slots)
            self.sched = SlotScheduler(max_slots, max_seq)
        # block tables enter every dispatch as one same-shaped int32 array
        # (a (1, 1) dummy keeps the contiguous signature stable)
        self._no_tables = jnp.zeros((1, 1), jnp.int32)
        self.stats = EngineStats()
        self._finished_seen = 0      # eviction accounting watermark
        if telemetry.enabled():
            telemetry.attach_registry(self.stats.registry)

        # per-slot sampling state (host mirrors; uploaded per dispatch)
        self._temps = np.zeros((max_slots,), np.float32)
        self._top_ks = np.zeros((max_slots,), np.int32)
        self._top_ps = np.ones((max_slots,), np.float32)
        self._keys = jnp.zeros((max_slots, 2), jnp.uint32)

        # first dispatch captures cost_analysis (lower() shares the jit
        # trace cache, so trace_counts still sees exactly one trace) and
        # records the blocked compile time as compile/serve_* gauges
        self._prefill = profile.instrument(
            "serve/prefill_chunk",
            jax.jit(self._prefill_fn, donate_argnums=(1,)))
        self._decode = profile.instrument(
            "serve/decode_step",
            jax.jit(self._decode_fn, donate_argnums=(1,)))
        self._sample_prefill = jax.jit(self._sample_prefill_fn)
        self._prefill_warm = False  # first chunk dispatch is the compile
        self._det_step = anomaly.StreamDetector(
            "serve/step_time", registry=self.stats.registry)

    # -- traced steps -------------------------------------------------------

    def _prefill_fn(self, params, pool, tokens, slot, pos0, valid, tables):
        """One prompt chunk into one slot's cache lane (contiguous) or its
        block-table pages (paged; ``tables`` row ``slot`` routes the
        chunk's scatter/gather)."""
        self.trace_counts["prefill"] += 1
        if self.paged:
            view = cache_mod.paged_view(pool, slot)
            row = jax.lax.dynamic_slice_in_dim(tables, slot, 1, axis=0)
            logits, view = self.model.chunk_prefill(
                params, view, tokens, pos0, valid, seq_len=self.max_seq,
                unroll=self.unroll, block_tables=row,
                page_size=self.page_size)
            return cache_mod.paged_write(pool, slot, view), logits
        view = cache_mod.slot_view(pool, slot)
        logits, view = self.model.chunk_prefill(
            params, view, tokens, pos0, valid, seq_len=self.max_seq,
            unroll=self.unroll)
        return cache_mod.slot_write(pool, slot, view), logits

    def _sample_prefill_fn(self, logits, valid, temp, top_k, top_p, key):
        """Sample the prompt continuation from the last valid prefill row."""
        self.trace_counts["sample"] += 1
        k_use, k_next = jax.random.split(key)
        if self.fused_sampling:
            from repro.kernels.slot_gather import slot_gather_sample
            C = logits.shape[1]
            onehot = (jnp.arange(C) == valid - 1).astype(jnp.float32)[None]
            noise = jax.random.gumbel(k_use, (1, logits.shape[-1]),
                                      jnp.float32)
            greedy, sampled = slot_gather_sample(logits, onehot,
                                                 temp[None], noise)
            tok = jnp.where(temp <= 0.0, greedy[0], sampled[0])
        else:
            row = jax.lax.dynamic_index_in_dim(logits[0], valid - 1, axis=0,
                                               keepdims=False)
            noise = jax.random.gumbel(k_use, (1, row.shape[-1]), jnp.float32)
            tok = sampling_mod.sample_tokens(
                row[None], temp[None], top_k[None], top_p[None], noise)[0]
        return tok, k_next

    def _decode_fn(self, params, pool, tokens, pos, temps, top_ks, top_ps,
                   keys, tables):
        """One decode step for the whole slot pool + fused sampling."""
        self.trace_counts["decode"] += 1
        if self.paged:
            logits, pool = self.model.decode_step(
                params, pool, {"tokens": tokens}, pos, seq_len=self.max_seq,
                unroll=self.unroll, block_tables=tables,
                page_size=self.page_size)
        else:
            logits, pool = self.model.decode_step(
                params, pool, {"tokens": tokens}, pos, seq_len=self.max_seq,
                unroll=self.unroll)
        ks = jax.vmap(jax.random.split)(keys)        # (S, 2, 2)
        k_use, k_next = ks[:, 0], ks[:, 1]
        # all-greedy steps (the default) skip the (S, V) Gumbel draw
        noise = jax.lax.cond(
            jnp.any(temps > 0.0),
            lambda k: sampling_mod.gumbel_noise(k, logits.shape[-1]),
            lambda k: jnp.zeros((keys.shape[0], logits.shape[-1]),
                                jnp.float32), k_use)
        if self.fused_sampling:
            from repro.kernels.slot_gather import slot_gather_sample
            onehot = jnp.ones((logits.shape[0], 1), jnp.float32)
            greedy, sampled = slot_gather_sample(logits, onehot, temps,
                                                 noise)
            tok = jnp.where(temps <= 0.0, greedy, sampled)
        else:
            tok = sampling_mod.sample_tokens(logits[:, 0, :], temps, top_ks,
                                             top_ps, noise)
        return pool, tok, k_next

    # -- host loop ----------------------------------------------------------

    def submit(self, tokens, max_new: int,
               sampling: SamplingParams | None = None,
               eos: int | None = None) -> int:
        sampling = sampling or SamplingParams()
        if self.fused_sampling and sampling_mod.needs_full_path(sampling):
            raise ValueError("fused_sampling engine handles greedy/"
                             "temperature only; top-k/top-p need the full "
                             "path (fused_sampling=False)")
        req = Request(tokens=list(map(int, tokens)), max_new=max_new,
                      sampling=sampling, eos=eos)
        return self.sched.submit(req)

    def _bind_slot(self, slot: int, req: Request) -> None:
        s = req.sampling
        self._temps[slot] = s.temperature
        self._top_ks[slot] = s.top_k
        self._top_ps[slot] = s.top_p
        # seed only — a request's sample stream is a pure function of
        # (params, prompt, seed), independent of submission order
        self._keys = self._keys.at[slot].set(jax.random.PRNGKey(s.seed))

    def _tables(self):
        """The block tables for the next dispatch (same-shaped int32 every
        time — values churn, shapes never do)."""
        if self.allocator is None:
            return self._no_tables
        return jnp.asarray(self.allocator.tables)

    def _make_writable(self, slot: int, lo: int, hi: int) -> None:
        """Pages covering rows [lo, hi) of ``slot`` become privately
        writable before a dispatch writes them: first touch allocates off
        the free list, a prefix-shared page copies-on-write."""
        ps = self.page_size
        for j in range(lo // ps, -(-hi // ps)):
            for dst, src in self.allocator.ensure_writable(slot, j * ps):
                self.pool = cache_mod.copy_page(self.pool, jnp.int32(dst),
                                                jnp.int32(src))

    def _prefill_request(self, slot: int, req: Request) -> None:
        self._bind_slot(slot, req)
        toks = np.asarray(req.tokens, np.int32)
        S0, C = len(req.tokens), self.prefill_chunk
        # prefix-cache hits skip their pages entirely; a full-prompt hit
        # still re-runs the last prompt token for its sampling logits (the
        # write COWs the shared final page, keeping the cached copy clean)
        hit = self.sched.slots[slot].hit_tokens
        start = S0 - 1 if hit >= S0 else hit
        t0 = time.perf_counter()
        with trace.span("serve/prefill", slot=slot, rid=req.rid, tokens=S0,
                        cached=hit):
            if self.cfg.ssm is not None:
                # SSM state/conv carry across prefill chunks by design, so
                # a previous occupant's state must not leak in. Attention
                # lanes need no zeroing: stale rows are causally masked
                # until overwritten in order (paged slots start from the
                # null table anyway) — admission cost is O(d_state), not
                # the old O(max_seq) full-lane wipe.
                self.pool = cache_mod.reset_slot_ssm(self.pool,
                                                     jnp.int32(slot))
            logits = None
            for c in range(start, S0, C):
                sl = toks[c:c + C]
                valid = len(sl)
                if valid < C:
                    sl = np.pad(sl, (0, C - valid))
                if self.paged:
                    self._make_writable(slot, c, c + valid)
                t_c = time.perf_counter()
                self.pool, logits = self._prefill(
                    self.params, self.pool, jnp.asarray(sl[None]),
                    jnp.int32(slot), jnp.int32(c), jnp.int32(valid),
                    self._tables())
                if self._prefill_warm:
                    profile.observe("serve/prefill_chunk",
                                    time.perf_counter() - t_c)
                else:
                    self._prefill_warm = True
            if self.allocator is not None:
                self.allocator.register_prefix(slot, toks)
            tok, k_next = self._sample_prefill(
                logits, jnp.int32(valid),
                jnp.float32(req.sampling.temperature),
                jnp.int32(req.sampling.top_k),
                jnp.float32(req.sampling.top_p),
                self._keys[slot])
            tok = int(tok)
        self._keys = self._keys.at[slot].set(k_next)
        self.stats.record_prefill(S0 - start, time.perf_counter() - t0)
        self.sched.record_first_token(slot, tok)
        self.stats.record_first_token(req.ttft)

    def _account_finished(self) -> None:
        """Fold newly finished requests into the eviction counter (a finish
        frees — evicts — its slot mid-flight)."""
        n = len(self.sched.finished)
        if n > self._finished_seen:
            self.stats.record_evictions(n - self._finished_seen)
            self._finished_seen = n

    def step(self) -> int:
        """Admit + prefill new requests, run one decode dispatch over the
        pool. Returns the number of live tokens produced."""
        for slot, req in self.sched.admit():
            self.stats.record_admission(req.queue_wait)
            self._prefill_request(slot, req)
        self._account_finished()       # max_new=1/eos at first token
        n_active = self.sched.num_active
        if self.allocator is not None:
            self.stats.set_page_stats(self.allocator.occupancy(),
                                      self.allocator.hit_rate(),
                                      self.allocator.cow_copies)
        else:
            self.stats.set_page_stats(n_active / self.max_slots, 0.0, 0)
        if n_active == 0:
            return 0
        if self.paged:
            # the step writes cache row st.pos per live slot: make the
            # covering page private first (idle slots park on the null
            # page and need nothing)
            for slot, st in enumerate(self.sched.slots):
                if st is not None:
                    self._make_writable(slot, st.pos, st.pos + 1)
        tokens = jnp.asarray(self.sched.feed_tokens(),
                             jnp.int32)[:, None]
        pos = jnp.asarray(self.sched.positions(), jnp.int32)
        t0 = time.perf_counter()
        with trace.span("serve/decode_step", active=n_active):
            self.pool, tok, self._keys = self._decode(
                self.params, self.pool, tokens, pos,
                jnp.asarray(self._temps), jnp.asarray(self._top_ks),
                jnp.asarray(self._top_ps), self._keys, self._tables())
            tok = np.asarray(tok)                     # sync point
        dt = time.perf_counter() - t0
        if self.stats.steps > 0:     # step 0 is the compile dispatch
            profile.observe("serve/decode_step", dt)
            self._det_step.observe(dt)
        self.sched.record_step(tok)
        self._account_finished()
        self.stats.record_decode(n_active, dt)
        return n_active

    def run(self) -> dict:
        """Drive to completion; returns {request id: generated tokens}."""
        while self.sched.has_work():
            self.step()
        return self.sched.results()

    def reset_stats(self) -> None:
        """Zero the timing stats (post-warmup). ``trace_counts`` is *not*
        reset: compile-once is a property of the engine's lifetime."""
        telemetry.detach_registry(self.stats.registry)
        self.stats = EngineStats()
        self._det_step = anomaly.StreamDetector(
            "serve/step_time", registry=self.stats.registry)
        if telemetry.enabled():
            telemetry.attach_registry(self.stats.registry)
