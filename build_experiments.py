"""Assemble EXPERIMENTS.md from dry-run/perf JSONs + hand-written analysis.

    PYTHONPATH=src python build_experiments.py
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src")
from repro.roofline.report import (dryrun_table, load_results,  # noqa: E402
                                   roofline_table, _fmt_s, _fmt_b)


def perf_rows():
    rows = []
    for p in sorted(glob.glob("experiments/perf/*.json")):
        with open(p) as f:
            r = json.load(f)
        r["tag"] = os.path.basename(p)[:-5]
        rows.append(r)
    return rows


def perf_table(rows, prefix: str) -> str:
    lines = ["| variant | t_compute | t_memory | t_collective | dominant | "
             "coll bytes/dev | Δdominant vs baseline |",
             "|---|---|---|---|---|---|---|"]
    rs = [r for r in rows if r["tag"].startswith(prefix) and r.get("ok")]
    base = next((r for r in rs if r["tag"] == prefix), None)

    def dom_val(r):
        rl = r["roofline"]
        return max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])

    for r in rs:
        rl = r["roofline"]
        delta = ""
        if base is not None:
            delta = f"{dom_val(base) / max(dom_val(r), 1e-12):.2f}x better" \
                if r is not base else "(baseline)"
        name = r["tag"][len(prefix):].lstrip("_") or "baseline"
        lines.append(
            f"| {name} | {_fmt_s(rl['t_compute_s'])} | "
            f"{_fmt_s(rl['t_memory_s'])} | {_fmt_s(rl['t_collective_s'])} | "
            f"{rl['dominant']} | {_fmt_b(rl['coll_bytes'])} | {delta} |")
    return "\n".join(lines)


def bench_csv() -> str:
    for path in ("bench_output.txt", "logs/bench_trial.csv"):
        if os.path.exists(path):
            with open(path) as f:
                return f.read().strip()
    return "(run `PYTHONPATH=src python -m benchmarks.run`)"


HEADER = """# EXPERIMENTS — Theano-MPI on TPU v5e (JAX reproduction)

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
Meshes: single pod (16 data x 16 model = 256 chips), multi-pod
(2 pod x 16 x 16 = 512 chips). CPU-only container: all terms are derived
from compiled artifacts (see Methodology), not wall clock.

## Methodology

- Every (arch x shape) is lowered AND compiled on the production mesh(es);
  memory_analysis/cost_analysis/HLO text are recorded in
  `experiments/dryrun/*.json` (deliverable (e)).
- `cost_analysis()` is per-device, with `while` bodies costed once. The
  single-pod roofline pass therefore compiles each combo at scan unroll=1
  and unroll=2 and extrapolates `total = c1 + (L-1)(c2-c1)` (exact for the
  equal-length scanned segments used by every assigned arch). Validated vs
  a full unroll (llama3.2-1b train_4k): flops within 3%; the memory term
  carries ~1.8x methodology uncertainty (cross-layer fusion).
- Collective bytes: per-shard result sizes of all-gather / all-reduce(x2) /
  reduce-scatter / all-to-all / collective-permute parsed from
  `compiled.as_text()`; the (k-1)/k factor is dropped (<7% at k=16).
- `t_memory` uses XLA "bytes accessed", an upper bound that counts
  intermediates which real TPUs keep in VMEM after fusion — treat it as
  pessimistic; `t_compute`/`t_collective` are tight.
- MODEL/HLO = 6·N_active·D (train) or 2·N_active·D (decode/prefill) over
  compiled flops: the "useful fraction" (remat recompute, dispatch overhead
  and attention S² terms push it below 1).

## §Paper-validation (the paper's own claims, reproduced)

| Paper claim | Our result | Where |
|---|---|---|
| Table 2 parameter counts (AlexNet 60,965,224 / GoogLeNet 13,378,280 / VGG 138,357,544) | **exact match, all three** | `tests/test_models.py::test_paper_table2_param_counts` |
| ASA == Allreduce semantics (Fig 2) | all strategies agree with the worker-mean to fp32 tolerance; fp16/int8 within wire-precision bounds | `tests/test_exchangers.py` (8-dev) |
| Fig 3 / Table 3 on the paper's own models (8 workers) | wire bytes: AR == ASA (1.00x), asa16 = **2.00x**, asa8 = **4.00x** for AlexNet-61M / GoogLeNet-13.4M / VGG-138M gradient pytrees | benchmarks `comm/*` (CSV below) |
| fp16 transfer + fp32 summation (§3.2) | `asa16` wire bytes = 0.5x AR; Pallas `chunk_sum` accumulates fp32 (beats fp16 accumulation in `test_chunk_sum_fp32_accumulation_beats_fp16`) | benchmarks `comm/*` |
| ASA 3x faster than Allreduce (Fig 3) | **does not transfer to TPU/XLA** (expected): XLA's all-reduce is already a fused ring reduce-scatter+all-gather on ICI, so modeled AR bytes ≈ ASA bytes. The paper's win came from OpenMPI's host-staged CUDA Allreduce. The *decomposition insight* survives as ZeRO-1 (below) and the *precision* part transfers fully (asa16/asa8). | `experiments/perf/llama3.2*`, §Perf H3 |
| Parallel loading hides IO (Alg 1) | Mechanically verified (`test_parallel_loader_overlaps`: prefetch runs ahead of the consumer). Wall-clock finding: **JAX's async dispatch already provides most of Alg 1's overlap for free** — a plain generator loop overlaps host IO with device compute because `step()` returns before the device finishes, so parallel~=sync on this host (both ~1.9 steps/s at 400ms simulated remote IO vs 1.99 local). The dedicated loader thread matters when IO exceeds a full step or preprocessing is GIL-heavy — the paper's Theano runtime had no async dispatch, hence their win. | benchmarks `loading/*` |
| EASGD at tau>=1 converges; larger tau trades comm for convergence (§4) | EASGD center converges on synthetic LM; tau sweep in `examples/easgd_async.py`; per-step comm drops ~1/tau | benchmarks `easgd/*` |
| BSP speedup vs k workers (Table 1) | modeled: exchange bytes per device are constant in k (ring), so scaling is compute-bound until the collective term dominates; measured CPU-host wall clock in benchmarks `scaling/*` (1-core host: see `efficiency_vs_serial`) | |

Benchmark CSV (latest run). Note `comm/vggnet/FAILED`: the 138M-param
pytree stacked 8x in fp32 plus XLA-CPU's O(k)-copy all-reduce exceeds this
35 GB single-host simulation — a host limitation, not a code path failure
(the same code passes the 61M AlexNet here, and VGG-sized buffers pass in
the 256-way ShapeDtypeStruct sweep of §Perf H3 which allocates nothing):

```
{BENCH}
```
"""


def main():
    rows = load_results()
    prows = perf_rows()
    parts = [HEADER.replace("{BENCH}", bench_csv())]

    parts.append("\n## §Dry-run (deliverable e)\n")
    parts.append("Every (architecture x input shape) lowers and compiles on "
                 "both production meshes. Failures would appear as FAIL "
                 "rows.\n\n"
                 "**Memory fit (v5e = 16 GB/chip).** The decode shapes and "
                 "the small-arch train shapes fit; 25 of the 40 single-pod "
                 "combos exceed 16 GB of XLA-reported temp+args — almost "
                 "entirely the naive-attention S2 buffers at 32k (cut 10x+ "
                 "by H1's blockwise attention, which is exactly why flash "
                 "attention exists) and the remat-stored residuals of the "
                 "train shapes (cut by the microbatch accumulation option "
                 "in core/bsp.py, at 4 microbatches: /4). The XLA CPU "
                 "backend also does not apply TPU-grade fusion to temp "
                 "buffers, so these numbers are upper bounds. The lowering "
                 "and collective schedules — what the dry-run certifies — "
                 "are unaffected.\n")
    parts.append("### Single pod (16x16 = 256 chips)\n")
    parts.append(dryrun_table(rows, "16x16"))
    parts.append("\n### Multi-pod (2x16x16 = 512 chips)\n")
    parts.append(dryrun_table(rows, "2x16x16"))

    parts.append("\n## §Roofline (single-pod, per device)\n")
    parts.append(roofline_table(rows, "16x16"))
    parts.append("""
### Reading the table

- **train_4k** is collective- or memory-bound everywhere: the BSP gradient
  exchange (fp32, 2 x N bytes/device) plus per-layer TP collectives dominate
  at TP=16 with only 16 sequences/device. Dense archs with clean head
  sharding (minitron, llama3.2, mistral) sit at useful_ratio 0.58-0.81
  (remat accounts for most of the gap: ~1.33x recompute).
- **prefill_32k** is memory-bound under naive attention: the S² score
  tensors dominate bytes (useful_ratio 0.02-0.10 on dense archs). Fixed by
  blockwise attention in §Perf H1.
- **decode** shapes are tiny on compute (1 token) and bound by
  KV-cache reads (memory) or by resharding collectives where the sharding
  fallback is awkward (llama3.2/minitron/mistral decode: kv_heads=8 < 16
  forces head_dim sharding; chameleon/llama4: MoE+vocab gathers).
- **Pathologies surfaced by the baseline** (and attacked in §Perf):
  qwen prefill_32k reshards the full 32k² score tensor (20 heads don't
  divide 16 -> GSPMD all-gathers scores), 215 TB/device; llama4-scout
  prefill reshards MoE dispatch buffers, 524 TB/device.
""")

    parts.append("\n## §Perf — hypothesis -> change -> measure -> validate\n")
    parts.append("Three pairs hillclimbed (worst roofline fraction, most "
                 "collective-bound, most paper-representative); hypotheses "
                 "were recorded before running the variants "
                 "(`experiments/perf_hypotheses.md`).\n")

    parts.append("\n### H1: qwen1.5-4b x prefill_32k (worst fraction, "
                 "collective-bound)\n")
    parts.append(perf_table(prows, "qwen1.5-4b__prefill_32k__single"))
    parts.append("""
**Hypothesis:** blockwise (flash-style) attention removes the S² HBM traffic
-> t_memory drops >=5x. **Result: partially confirmed, and better than
predicted on a different term.** The dominant cost was actually the GSPMD
*reshard of the score tensor* (qwen's 20 heads don't divide the 16-way model
axis, so scores were all-gathered): blockwise attention eliminates the
materialized score tensor entirely, cutting the collective term **224x**
(4314s -> 19.2s) and shifting the bottleneck to memory. The reported
t_memory barely moves because XLA "bytes accessed" still counts each
per-block score tile; on hardware those tiles are VMEM-resident (flash
attention's raison d'etre), so the true memory term is far lower — bounded
below by the KV+activation streams (~40s). block=8192 is worse than
block=2048 as predicted (larger working set). Lesson: at TP boundaries,
*sharding-induced* collectives can dwarf the textbook memory analysis; the
napkin math missed it because it assumed scores stay local.
""")

    parts.append("\n### H2: hymba-1.5b x train_4k (most collective-bound "
                 "BSP arch)\n")
    parts.append(perf_table(prows, "hymba-1.5b__train_4k__single"))
    parts.append("""
*(Parser note: the `noseq`, `asa16`, `asa16__noseq` rows were measured with
an earlier collective parser that missed tuple-result all-to-alls; their
apparent 1.48x delta vs baseline is that artifact, not a real change —
apples-to-apples against the old baseline (83.18s) they were within 0.2%.
`baseline`, `repattn`, `asa16__repattn` use the fixed parser.)*

**Hypothesis 1 (refuted):** the sequence-parallel residual constraint causes
the reshards -> `--no-seq-shard` changed **nothing** (83.18s vs 83.18s,
old parser both sides). **Hypothesis 2 (mostly refuted):** fp16 exchange
-> asa16 moved t_coll <0.2% (the gradient exchange is a tiny share of the
reshard traffic). **Hypothesis 3 (confirmed, 11x):** with d_model=1600,
TP=16 leaves only 100 features (and 5 kv heads force head_dim sharding);
GSPMD reshards the attention AND SSD activations every layer (all-to-all +
all-gather chains — 6.1 TB/device/step!). Replicating the attention/SSM
parameters (`--replicate-attn`, TP kept on FFN/embed/head) removes them:
**t_coll 122.9s -> 10.9s (11x)**; asa16 on top shaves the now-visible
exchange share (546 -> 537 GB). t_compute rises 0.48 -> 1.64s (mixer
compute now replicated) — a good trade: the dominant term drops
122.9 -> 66.1 (memory), **1.86x better end-to-end**, and the remaining
memory term is the naive-attention S² artifact addressed by H1's blockwise
attention. Lesson: for small-d hybrid archs, tensor-parallelism of the
mixers is counterproductive; shard only the FFN.
""")

    parts.append("\n### H3: llama3.2-1b x train_4k (paper-representative: "
                 "exchanger sweep)\n")
    parts.append(perf_table(prows, "llama3.2-1b__train_4k__single"))
    parts.append("""
**Hypothesis (confirmed, including the predicted refutation-of-transfer):**

1. *Full train step at TP=16* (table above): exchanger choice moves total
   collective bytes by <7% — TP activation collectives (~180 GB/device)
   dwarf the ~15 GB gradient exchange. The paper's Fig-3 regime (pure DP)
   must be isolated to see the effect:
2. *Exchange-only, pure-DP 256-way mesh, llama3.2-1b-sized gradients*
   (`experiments/perf/dp256_exchange_sweep.json`), per-device wire bytes:

   | strategy | GB/device | vs AR |
   |---|---|---|
   | ar (psum)          | 9.89 | 1.00x |
   | **asa** (paper C2) | **9.89** | **1.00x — byte-identical** |
   | asa16 (paper C3)   | 4.94 | **2.0x** |
   | asa8 (beyond paper)| 2.47 | **4.0x** |
   | hier (multi-pod)   | 9.89 | 1.00x (its win is DCN-vs-ICI placement, not bytes) |

   The paper's 3x ASA-vs-Allreduce speedup **does not transfer to TPU/XLA**:
   XLA's all-reduce is already a fused ring reduce-scatter+all-gather, so
   the Alltoall-sum-Allgather decomposition is byte- (and schedule-)
   neutral. It was an artifact of OpenMPI 1.8.7 staging CUDA all-reduce
   through host memory. What *does* transfer is the half-precision-transfer
   /full-precision-sum idea (exactly 2x; int8 pushes to 4x) — and the
   decomposition itself resurfaces as ZeRO-1 (grads reduce-scattered, 1/k
   optimizer shards, params all-gathered), which this framework uses for
   the >=34B architectures where replicated-DP cannot fit.
3. *zero1 on this small model* (beyond-paper variant, table above):
   **2.7x WORSE** on collectives (10.4s vs 3.9s) — FSDP re-gathers
   parameters every layer fwd+bwd. ZeRO-1 is a memory play, not a comm
   play; at 1.2B params (replicated fits easily) it strictly loses.
   Confirms the FSDP_THRESHOLD policy in `launch/dryrun.py`.
4. *Iteration on the exchanger itself*: the first asa16 measurement on the
   pure-DP mesh showed only 1.1x (not 2x) — stacked-layer leaves with
   dim0 < k fell back to fp32 psum. Flattening such leaves before chunking
   (`exchanger.py`) recovered the full 2.0x. hypothesis -> measure ->
   fix -> re-measure, kept in the code.
""")

    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts))
    print("wrote EXPERIMENTS.md",
          f"({len(rows)} dryrun rows, {len(prows)} perf rows)")


if __name__ == "__main__":
    main()
