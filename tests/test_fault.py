"""Fault-tolerance stack tests: injection plans, membership/quorum math,
crash-safe checkpoints, loader failure propagation, and the elastic
end-to-end properties (quorum parity, staleness absorption, replay
determinism, preempt->resume) on 8 virtual CPU devices."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.fault.inject import (FaultEvent, FaultPlan, bitflip,
                                payload_checksum)
from repro.fault.membership import MembershipController, WorkerState


# ---------------------------------------------------------------------------
# FaultPlan: spec grammar, ordering, seeded determinism
# ---------------------------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("explode", 0, 1)
    with pytest.raises(ValueError, match=">= 0"):
        FaultEvent("kill", -1, 1)
    with pytest.raises(ValueError, match="rounds"):
        FaultEvent("straggle", 0, 1, rounds=0)


def test_fault_plan_spec_roundtrip():
    spec = "kill:1@9,straggle:2@5x3,corrupt:0@13"
    plan = FaultPlan.from_spec(spec, seed=7)
    # events sort by (step, worker); to_spec reflects that order
    assert plan.to_spec() == "straggle:2@5x3,kill:1@9,corrupt:0@13"
    assert FaultPlan.from_spec(plan.to_spec(), seed=7) == plan
    assert plan.events_at(9) == [FaultEvent("kill", 1, 9)]
    assert plan.events_at(5)[0].rounds == 3
    assert plan.events_at(4) == []
    with pytest.raises(ValueError, match="bad fault spec"):
        FaultPlan.from_spec("kill:1")
    with pytest.raises(ValueError, match="bad fault spec"):
        FaultPlan.from_spec("kill:one@2")


def test_fault_plan_random_is_seed_deterministic():
    a = FaultPlan.random(3, num_workers=4, num_steps=50)
    b = FaultPlan.random(3, num_workers=4, num_steps=50)
    c = FaultPlan.random(4, num_workers=4, num_steps=50)
    assert a == b
    assert a != c
    # kills never empty the fleet
    heavy = FaultPlan.random(0, num_workers=2, num_steps=50, n_events=20,
                             kinds=("kill",))
    assert sum(e.kind == "kill" for e in heavy.events) <= 1


def test_event_rng_and_bitflip_determinism():
    plan = FaultPlan.from_spec("corrupt:0@3,corrupt:1@7", seed=11)
    e0, e1 = plan.events
    x = np.arange(16, dtype=np.float32)
    f1 = bitflip(x, plan.event_rng(e0))
    f2 = bitflip(x, plan.event_rng(e0))
    assert np.array_equal(f1, f2)                      # same event -> same bit
    assert not np.array_equal(f1, bitflip(x, plan.event_rng(e1)))
    assert np.array_equal(x, np.arange(16, dtype=np.float32))  # input intact
    # crc32 catches every single-bit flip (here and for bf16-width dtypes)
    assert payload_checksum(f1) != payload_checksum(x)
    h = np.arange(8, dtype=np.float16)
    hf = bitflip(h, plan.event_rng(e0))
    assert payload_checksum(hf) != payload_checksum(h)
    # list payloads chain the crc
    assert payload_checksum([x, h]) != payload_checksum([f1, h])


# ---------------------------------------------------------------------------
# MembershipController: quorum boundary, staleness, weights, join/leave
# ---------------------------------------------------------------------------

def test_quorum_boundary_exactly_at_vs_one_below():
    c = MembershipController(range(4), alpha=0.5, quorum=3)
    assert c.quorum_count == 3
    assert c.has_quorum([0, 1, 2])           # exactly at
    assert not c.has_quorum([0, 1])          # one below
    # default: majority of the live fleet
    d = MembershipController(range(4), alpha=0.5)
    assert d.quorum_count == 3
    assert MembershipController(range(5), alpha=0.5).quorum_count == 3
    assert MembershipController([7], alpha=0.5).quorum_count == 1


def test_round_weights_hand_computed_staleness():
    alpha = 0.5
    c = MembershipController(range(4), alpha=alpha, quorum=2)
    # age worker 1 one round, worker 3 three rounds
    c.commit_round([0, 2, 3])                # 1 ages to 1
    assert c.staleness_of(1) == 1
    for _ in range(3):
        c.commit_round([0, 1, 2])            # 3 ages to 3, 1 resets
    assert c.staleness_of(3) == 3 and c.staleness_of(1) == 0
    absorb, attract = c.round_weights([0, 1, 3])
    # absorb_i = alpha / (1 + staleness_i); non-reporting row 2 gets 0
    np.testing.assert_allclose(
        absorb, [alpha / 1, alpha / 1, 0.0, alpha / 4], rtol=0, atol=0)
    np.testing.assert_array_equal(absorb, attract)
    assert absorb.dtype == np.float32


def test_skip_round_ages_everyone():
    c = MembershipController(range(3), alpha=0.5, quorum=3)
    c.skip_round()
    c.skip_round()
    assert [c.staleness_of(w) for w in range(3)] == [2, 2, 2]
    a, _ = c.round_weights([0, 1, 2])
    np.testing.assert_allclose(a, [0.5 / 3] * 3)


def test_straggler_lifecycle():
    c = MembershipController(range(3), alpha=0.5, quorum=1)
    assert c.straggle(1, rounds=2)
    assert c.state_of(1) == WorkerState.STRAGGLING
    assert c.reporting() == [0, 2]
    c.commit_round(c.reporting())            # round 1 missed
    assert c.reporting() == [0, 2]
    c.commit_round(c.reporting())            # round 2 missed
    assert c.reporting() == [0, 1, 2]        # straggle expired
    assert c.staleness_of(1) == 2            # absorbed late next round
    assert not c.straggle(99)                # unknown worker


def test_kill_join_at_round_boundary():
    c = MembershipController(range(3), alpha=0.5, num_slots=4)
    assert c.kill(1)
    assert not c.kill(1)                     # idempotent
    assert c.state_of(1) == WorkerState.LEAVING
    assert c.reporting() == [0, 2]           # killed never reports
    assert c.request_join(5)
    assert c.state_of(5) == WorkerState.JOINING
    assert c.workers == (0, 1, 2)            # nothing applied yet
    old, new, left, joined = c.apply_pending()
    assert old == (0, 1, 2) and new == (0, 2, 5)
    assert left == (1,) and joined == (5,)
    assert c.state_of(1) == WorkerState.DEAD
    assert c.staleness_of(5) == 0            # joiner starts at the center
    # slot 1 was freed and reused by the joiner
    assert c.slot_of(5) == 1


def test_join_rejected_when_no_slot_free():
    c = MembershipController(range(2), alpha=0.5, num_slots=2)
    assert c.request_join(9)
    old, new, left, joined = c.apply_pending()
    assert new == (0, 1) and joined == ()
    assert c.rejected_joins == 1


def test_fleet_cannot_empty():
    c = MembershipController([0], alpha=0.5)
    c.kill(0)
    with pytest.raises(RuntimeError, match="emptied the fleet"):
        c.apply_pending()


def test_controller_validation():
    with pytest.raises(ValueError, match="duplicate"):
        MembershipController([1, 1], alpha=0.5)
    with pytest.raises(ValueError, match="at least one"):
        MembershipController([], alpha=0.5)
    with pytest.raises(ValueError, match="quorum"):
        MembershipController([0], alpha=0.5, quorum=0)
    with pytest.raises(ValueError, match="slots"):
        MembershipController(range(3), alpha=0.5, num_slots=2)


def test_trainplan_quorum_validation():
    from repro.train.engine import TrainPlan, build_engine
    with pytest.raises(ValueError, match="quorum"):
        TrainPlan(algo="bsp", quorum=2)
    with pytest.raises(ValueError, match="quorum"):
        TrainPlan(algo="easgd", quorum=0)
    plan = TrainPlan(algo="easgd", quorum=2, exchanger="ar")
    with pytest.raises(ValueError, match="elastic"):
        build_engine(plan, None, None, None, None)


# ---------------------------------------------------------------------------
# crash-safe checkpoints
# ---------------------------------------------------------------------------

def _ck_state(v):
    return {"params": {"w": np.full((4,), float(v), np.float32)},
            "step": np.asarray(v, np.int32)}


def test_ckpt_retention_and_layout(tmp_path):
    from repro.checkpoint.ckpt import latest_step, save_checkpoint
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        save_checkpoint(d, _ck_state(s), step=s, keep=2)
    names = sorted(os.listdir(d))
    assert names == ["meta-00000003.json", "meta-00000004.json",
                     "meta.json", "state-00000003.npz",
                     "state-00000004.npz"]
    assert latest_step(d) == 4


def test_ckpt_truncation_falls_back(tmp_path):
    from repro.checkpoint.ckpt import restore_for_resume, save_checkpoint
    d = str(tmp_path / "ck")
    save_checkpoint(d, _ck_state(3), step=3, algo="bsp")
    save_checkpoint(d, _ck_state(6), step=6, algo="bsp")
    # truncate the latest state file mid-write (simulated torn save)
    p = os.path.join(d, "state-00000006.npz")
    with open(p, "r+b") as f:
        f.truncate(os.path.getsize(p) // 2)
    with pytest.warns(RuntimeWarning, match="falling back"):
        state, step = restore_for_resume(d, _ck_state(0), expect_algo="bsp")
    assert step == 3 and float(state["params"]["w"][0]) == 3.0


def test_ckpt_bit_corruption_detected(tmp_path):
    from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
    d = str(tmp_path / "ck")
    save_checkpoint(d, _ck_state(1), step=1)
    save_checkpoint(d, _ck_state(2), step=2)
    p = os.path.join(d, "state-00000002.npz")
    raw = bytearray(open(p, "rb").read())
    raw[len(raw) // 2] ^= 0x10
    open(p, "wb").write(bytes(raw))
    with pytest.warns(RuntimeWarning, match="falling back"):
        state = restore_checkpoint(d, _ck_state(0))
    assert float(state["params"]["w"][0]) == 1.0


def test_ckpt_no_valid_checkpoint_is_loud(tmp_path):
    from repro.checkpoint.ckpt import save_checkpoint, restore_checkpoint
    d = str(tmp_path / "ck")
    save_checkpoint(d, _ck_state(1), step=1, keep=1)
    os.unlink(os.path.join(d, "state-00000001.npz"))
    with pytest.raises(FileNotFoundError, match="integrity"):
        restore_checkpoint(d, _ck_state(0))


def test_ckpt_legacy_single_file_layout(tmp_path):
    from repro.checkpoint.ckpt import restore_for_resume
    d = tmp_path / "ck"
    d.mkdir()
    st = _ck_state(5)
    np.savez(d / "state.npz", **{"params/w": st["params"]["w"],
                                 "step": st["step"]})
    (d / "meta.json").write_text(json.dumps({"step": 5}))
    state, step = restore_for_resume(str(d), _ck_state(0))
    assert step == 5 and float(state["params"]["w"][0]) == 5.0


def test_ckpt_workers_recorded(tmp_path):
    from repro.checkpoint.ckpt import load_meta, save_checkpoint
    d = str(tmp_path / "ck")
    save_checkpoint(d, _ck_state(1), step=1, algo="easgd",
                    workers=(0, 2, 5))
    meta = load_meta(d)
    assert meta["workers"] == [0, 2, 5] and meta["algo"] == "easgd"


# ---------------------------------------------------------------------------
# ParallelLoader failure propagation (the hang fix)
# ---------------------------------------------------------------------------

def test_loader_worker_exception_propagates(tmp_path):
    from repro.data.prefetch import LoaderError, ParallelLoader
    ok = str(tmp_path / "ok.npz")
    np.savez(ok, x=np.arange(4))
    l = ParallelLoader([ok, str(tmp_path / "missing.npz"), ok], timeout=30)
    got = list()
    with pytest.raises(LoaderError, match="FileNotFoundError"):
        for b in l:
            got.append(b)
    assert len(got) == 1
    with pytest.raises(LoaderError):         # failure is terminal
        l.get()
    l.stop()                                 # and stop() still returns


def test_loader_get_times_out_with_diagnosis(tmp_path):
    from repro.data.prefetch import ParallelLoader
    ok = str(tmp_path / "ok.npz")
    np.savez(ok, x=np.arange(4))
    l = ParallelLoader([ok], io_delay_ms=60_000, timeout=0.2)
    with pytest.raises(TimeoutError, match="loader thread"):
        l.get()


def test_loader_normal_stream_unaffected(tmp_path):
    from repro.data.prefetch import ParallelLoader
    ok = str(tmp_path / "ok.npz")
    np.savez(ok, x=np.arange(4))
    l = ParallelLoader([ok, ok, ok], timeout=30)
    assert len(list(l)) == 3


# ---------------------------------------------------------------------------
# elastic end-to-end properties (8 virtual devices, subprocess)
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import json, os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.data.synthetic import LMTokenSource
from repro.models import build_model
from repro.optim import constant, sgd_momentum
from repro.train.engine import TrainPlan, build_engine
from repro.fault.elastic import Preempted, elastic_train

cfg = get_smoke_config("llama3.2-1b").with_overrides(
    vocab_size=64, d_ff=128, num_layers=2, dtype="float32")
model = build_model(cfg)
opt = sgd_momentum(weight_decay=0.0)
src = LMTokenSource(cfg.vocab_size, 16, seed=0)
batch_fn = lambda step, k: src.batch(4 * k, step)

def center_of(state):
    return [np.asarray(l, np.float32) for l in jax.tree.leaves(state["center"])]

def maxerr(a, b):
    return max(float(np.abs(x - y).max()) for x, y in zip(a, b))

out = {}

# 1) quorum path at full participation == the fixed sync step, exactly
plan_q = TrainPlan(algo="easgd", tau=2, alpha=0.5, exchanger="ar", quorum=4)
sq, _ = elastic_train(model, opt, constant(0.05), batch_fn, plan=plan_q,
                      num_workers=4, num_steps=8, seed=0, print_fn=None)
mesh = jax.make_mesh((4,), ("data",))
jax.set_mesh(mesh)
eng = build_engine(TrainPlan(algo="easgd", tau=2, alpha=0.5, exchanger="ar"),
                   model, opt, constant(0.05), mesh)
st = eng.init_state(jax.random.key(0))
rng = jax.random.key(1)
for i in range(8):
    st, _ = eng.step(st, batch_fn(i, 4), jax.random.fold_in(rng, i),
                     step_idx=i)
out["quorum_parity_err"] = maxerr(center_of(sq), center_of(st))

# 2) one quorum round against a numpy reference: c' = c + sum_i w_i*(x_i-c)
from repro.train.engine import build_elastic_programs
progs = build_elastic_programs(plan_q, model, opt, constant(0.0), mesh)
state = progs.init_state(jax.random.key(2))
state, _ = progs.local(state, batch_fn(0, 4), jax.random.fold_in(rng, 0))
pre_stack = [np.asarray(l, np.float32)
             for l in jax.tree.leaves(state["params"])]
pre_center = center_of(state)
absorb = np.asarray([0.5, 0.25, 0.0, 0.125], np.float32)  # staleness 0,1,-,3
# lr=0 -> the sync step's local update is a no-op, params stay pre_stack
state2, _ = progs.sync(state, batch_fn(1, 4), jax.random.fold_in(rng, 1),
                       absorb, absorb)
expect = [c + sum(absorb[i] * (s[i] - c) for i in range(4))
          for s, c in zip(pre_stack, pre_center)]
out["absorb_math_err"] = maxerr(center_of(state2), expect)
# non-reporting row 2 kept its params bit-identically
post_stack = [np.asarray(l, np.float32)
              for l in jax.tree.leaves(state2["params"])]
out["nonreporting_untouched"] = bool(all(
    np.array_equal(a[2], b[2]) for a, b in zip(pre_stack, post_stack)))

# 3) chaos replay determinism + kill/rejoin convergence
plan = TrainPlan(algo="easgd", tau=4, alpha=0.5, exchanger="ar", quorum=2)
spec = "kill:3@9,straggle:2@13x2,corrupt:1@21,drop:0@29,join:3@33"
def chaos(**kw):
    return elastic_train(model, opt, constant(0.05), batch_fn, plan=plan,
                         num_workers=4, num_steps=40, seed=0,
                         fault_plan=spec, print_fn=None, **kw)
s1, r1 = chaos()
s2, r2 = chaos()
out["replay_bitwise"] = bool(all(
    np.array_equal(a, b) for a, b in zip(center_of(s1), center_of(s2))))
out["replay_round_log"] = r1.round_log == r2.round_log
out["chaos_first_loss"] = r1.losses[0]
out["chaos_last_loss"] = r1.losses[-1]
out["chaos_counts"] = dict(kills=r1.kills, joins=r1.joins,
                           rebuilds=r1.rebuilds, corrupt=r1.payloads_corrupt,
                           dropped=r1.payloads_dropped,
                           skipped=r1.rounds_skipped_quorum)
out["final_workers"] = list(r1.final_workers)
# staleness audit: at the step-23 round the returning straggler (worker 2,
# staleness 2) is absorbed with alpha/(1+2) while worker 1's payload is
# corrupt-excluded (weight 0); row order is (0, 1, 2) after the kill
out["late_absorb_weight"] = [w for s, rep, w in r1.round_log if s == 23][0]

# 4) preempt -> resume loss band, per algo
bands = {}
for algo, lr in (("easgd", 0.05), ("asgd", 0.02)):
    p = TrainPlan(algo=algo, tau=4, alpha=0.5 if algo == "easgd" else None,
                  exchanger="ar", quorum=2)
    def run(**kw):
        return elastic_train(model, opt, constant(lr), batch_fn, plan=p,
                             num_workers=4, num_steps=32, seed=0,
                             fault_plan="kill:3@9", print_fn=None, **kw)
    _, ref = run()
    d = tempfile.mkdtemp()
    try:
        run(ckpt_path=d, ckpt_every=8, stop_at_step=18)
        bands[algo] = dict(preempted=False)
        continue
    except Preempted:
        pass
    _, res = run(resume_from=d)
    bands[algo] = dict(preempted=True, ref=ref.losses[-1],
                       resumed=res.losses[-1], steps=res.steps)
out["resume"] = bands
print("RESULTS_JSON:" + json.dumps(out))
"""


def test_elastic_properties_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON:"):
            out = json.loads(line[len("RESULTS_JSON:"):])
    assert out is not None, proc.stdout[-2000:]
    # full participation at staleness 0 == the fixed sync step, exactly
    assert out["quorum_parity_err"] == 0.0, out
    # center math matches the numpy reference, non-reporters untouched
    assert out["absorb_math_err"] < 1e-5, out
    assert out["nonreporting_untouched"], out
    # seeded chaos replay is bit-identical
    assert out["replay_bitwise"] and out["replay_round_log"], out
    # every injected fault kind actually fired, and the fleet healed
    assert out["chaos_counts"] == dict(kills=1, joins=1, rebuilds=2,
                                       corrupt=1, dropped=1, skipped=0), out
    assert out["final_workers"] == [0, 1, 2, 3], out
    # chaos run still trains through kill/corrupt/drop/rejoin
    assert out["chaos_last_loss"] < 0.6 * out["chaos_first_loss"], out
    # the straggler's delta was absorbed late at alpha/(1+2); the
    # corrupt-excluded worker contributed nothing that round
    w = out["late_absorb_weight"]
    assert abs(w[2] - 0.5 / 3) < 1e-6, out
    assert abs(w[0] - 0.5) < 1e-6 and w[1] == 0.0, out
    # preempt -> resume: full step count, same band as uninterrupted
    for algo in ("easgd", "asgd"):
        r = out["resume"][algo]
        assert r["preempted"], out
        assert r["steps"] == 32, out
        assert abs(r["resumed"] - r["ref"]) <= 0.05 * max(
            1.0, abs(r["ref"])), out


def test_bsp_restart_after_corrupt_checkpoint(tmp_path):
    """bsp/gspmd fault tolerance is checkpoint restart: corrupting the
    latest checkpoint must fall back to an earlier valid one and the
    resumed run must still land where the uninterrupted run does."""
    import jax
    from repro.configs import get_smoke_config
    from repro.data.synthetic import LMTokenSource
    from repro.models import build_model
    from repro.optim import constant, sgd_momentum
    from repro.train.loop import train

    cfg = get_smoke_config("llama3.2-1b").with_overrides(
        vocab_size=64, d_ff=128, num_layers=2, dtype="float32")
    model = build_model(cfg)
    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    opt = sgd_momentum(weight_decay=0.0)
    src = LMTokenSource(cfg.vocab_size, 16, seed=0)
    batches = [src.batch(8, i) for i in range(12)]

    _, ref = train(model, opt, constant(0.05), mesh, batches,
                   num_steps=12, log_every=0, print_fn=None)
    d = str(tmp_path / "ck")
    train(model, opt, constant(0.05), mesh, batches[:8], num_steps=8,
          log_every=0, ckpt_path=d, ckpt_every=4, print_fn=None)
    # the step-8 save is torn by the crash; step 4 must carry the resume
    p = os.path.join(d, "state-00000008.npz")
    with open(p, "r+b") as f:
        f.truncate(os.path.getsize(p) // 2)
    with pytest.warns(RuntimeWarning, match="falling back"):
        _, rep = train(model, opt, constant(0.05), mesh, batches,
                       num_steps=12, log_every=0, resume_from=d,
                       print_fn=None)
    assert rep.steps == 12
    assert abs(rep.losses[-1] - ref.losses[-1]) < 1e-5
