"""Exchanger equivalence on an 8-device host mesh.

Needs >1 device, so runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (keeps the main pytest
process at 1 device per the dry-run contract).
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.exchanger import EXCHANGERS, get_exchanger
from repro.kernels import ops

results = {}

def run_mesh(mesh, axes, tag):
    jax.set_mesh(mesh)
    k = int(np.prod([mesh.shape[a] for a in axes]))
    key = jax.random.key(0)
    grads = {
        "big": jax.random.normal(key, (k, 1000, 3)) * 2,          # stacked
        "mat": jax.random.normal(jax.random.fold_in(key, 1), (k, 33, 7)),
        "small": jax.random.normal(jax.random.fold_in(key, 2), (k, 5)),
        "odd": jax.random.normal(jax.random.fold_in(key, 3), (k, 1237)),
    }
    # reference: mean over the worker axis
    want = {n: np.asarray(v.mean(0)) for n, v in grads.items()}
    ax = axes[0] if len(axes) == 1 else tuple(axes)

    for name in ["ar", "asa", "asabf16", "asa16", "asa8", "ring", "ring16",
                 "hier", "hier16"]:
        ex = get_exchanger(name)
        def f(gs):
            per = {n: v[0] for n, v in gs.items()}
            out = ex.exchange(per, ax)
            return {n: v[None] for n, v in out.items()}
        got = jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=P(axes), out_specs=P(axes),
            axis_names=frozenset(axes), check_vma=False))(grads)
        tol = {"ar": 1e-6, "asa": 1e-6, "ring": 1e-6, "hier": 1e-6,
               "asabf16": 2e-2, "asa16": 2e-3, "ring16": 5e-3,
               "hier16": 2e-3, "asa8": 5e-2}[name]
        errs = {}
        for n in grads:
            g0 = np.asarray(got[n][0])
            scale = np.abs(want[n]).max() + 1e-9
            errs[n] = float(np.abs(g0 - want[n]).max() / scale)
        results[f"{tag}:{name}"] = {"errs": errs, "tol": tol,
                                    "ok": all(e <= tol for e in errs.values())}

    # pallas chunk_sum plugged into ASA
    ex = get_exchanger("asa")
    def f2(gs):
        per = {n: v[0] for n, v in gs.items()}
        out = ex.exchange(per, ax, sum_fn=ops.chunk_sum)
        return {n: v[None] for n, v in out.items()}
    got = jax.jit(jax.shard_map(
        f2, mesh=mesh, in_specs=P(axes), out_specs=P(axes),
        axis_names=frozenset(axes), check_vma=False))(grads)
    err = max(float(np.abs(np.asarray(got[n][0]) - want[n]).max()
                    / (np.abs(want[n]).max() + 1e-9)) for n in grads)
    results[f"{tag}:asa+pallas_chunk_sum"] = {"errs": {"max": err},
                                              "tol": 1e-6,
                                              "ok": err <= 1e-6}

run_mesh(jax.make_mesh((8,), ("data",)), ("data",), "dp8")
run_mesh(jax.make_mesh((2, 4), ("pod", "data")), ("pod", "data"), "pod2x4")
print("RESULTS_JSON:" + json.dumps(results))
"""


def _run_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON:"):
            return json.loads(line[len("RESULTS_JSON:"):])
    raise AssertionError(f"no results in output: {proc.stdout[-2000:]}")


_results_cache = {}


@pytest.fixture(scope="module")
def results():
    if not _results_cache:
        _results_cache.update(_run_subprocess())
    return _results_cache


@pytest.mark.parametrize("strategy", [
    "ar", "asa", "asabf16", "asa16", "asa8", "ring", "ring16", "hier",
    "hier16", "asa+pallas_chunk_sum"])
def test_strategy_matches_mean_dp8(results, strategy):
    r = results[f"dp8:{strategy}"]
    assert r["ok"], f"{strategy}: errors {r['errs']} > tol {r['tol']}"


@pytest.mark.parametrize("strategy", ["ar", "asa", "hier", "hier16"])
def test_strategy_matches_mean_multipod(results, strategy):
    r = results[f"pod2x4:{strategy}"]
    assert r["ok"], f"{strategy}: errors {r['errs']} > tol {r['tol']}"


def test_bucketed_exchange_single_device():
    """Bucketing packs/unpacks losslessly (k=1 host: exchange == identity
    mean over a single worker)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.exchanger import get_exchanger

    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    grads = {"a": jnp.arange(100.0), "b": jnp.ones((7, 3)),
             "c": jnp.full((2049,), 2.0)}
    ex = get_exchanger("asa")

    def f(gs):
        return ex.exchange(gs, "data", bucket_bytes=1 << 10)

    got = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                                axis_names=frozenset({"data"}),
                                check_vma=False))(grads)
    for k in grads:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(grads[k]),
                                   rtol=1e-6)
