"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
sweeping shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # no hypothesis in this env: deterministic fallback
    from repro.testing.hypofallback import given, settings, st

from repro.kernels import default_interpret, ops, ref
from repro.kernels.chunk_sum import chunk_sum as raw_chunk_sum
from repro.kernels.fused_rs_update import fused_rs_update as raw_rs_update
from repro.kernels.fused_sgd import fused_sgd as raw_fused_sgd
from repro.kernels.quantize import (quant_int8 as raw_quant_int8,
                                    dequant_int8 as raw_dequant_int8)
from repro.kernels.slot_gather import slot_gather_sample


@pytest.mark.parametrize("k", [2, 4, 8, 16])
@pytest.mark.parametrize("n", [100, 2048, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float16, jnp.bfloat16])
def test_chunk_sum_matches_ref(k, n, dtype):
    x = (jax.random.normal(jax.random.key(k * n), (k, n)) * 3).astype(dtype)
    got = raw_chunk_sum(x, interpret=True)
    want = ref.chunk_sum_ref(x)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("block_n", [256, 2048])
def test_chunk_sum_block_sizes(block_n):
    x = jax.random.normal(jax.random.key(0), (4, 3333)).astype(jnp.bfloat16)
    got = raw_chunk_sum(x, block_n=block_n, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.chunk_sum_ref(x)), rtol=1e-6)


def test_chunk_sum_fp32_accumulation_beats_fp16():
    # many small fp16 values: fp16 accumulation would lose precision
    k, n = 16, 512
    x = jnp.full((k, n), 0.1, jnp.float16)
    got = raw_chunk_sum(x, interpret=True)
    fp16_sum = x.sum(axis=0)  # fp16 accumulate
    exact = k * np.float32(np.float16(0.1))
    assert abs(float(got[0]) - exact) <= abs(float(fp16_sum[0]) - exact)


@pytest.mark.parametrize("n", [100, 2048, 4096 + 17])
def test_quant_int8_roundtrip_and_ref(n):
    x = jax.random.normal(jax.random.key(n), (n,)) * 5
    q, s = raw_quant_int8(x, interpret=True)
    qr, sr = ref.quant_int8_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    d = raw_dequant_int8(q, s, interpret=True)
    # error bounded by scale/2 per block
    err = np.max(np.abs(np.asarray(d) - np.asarray(x)))
    assert err <= float(jnp.max(s)) * 0.5 + 1e-6


@pytest.mark.parametrize("n", [128, 5000])
@pytest.mark.parametrize("nesterov", [False, True])
def test_fused_sgd_matches_ref(n, nesterov):
    key = jax.random.key(n)
    p = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    m = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    po, mo = raw_fused_sgd(p, g, m, 0.05, momentum=0.9, nesterov=nesterov,
                           interpret=True)
    pr, mr = ref.fused_sgd_ref(p, g, m, 0.05, momentum=0.9, nesterov=nesterov)
    np.testing.assert_allclose(np.asarray(po), np.asarray(pr), rtol=2e-5,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(mr), rtol=2e-5,
                               atol=1e-7)


def test_ops_wrappers_nd_shapes():
    x = jax.random.normal(jax.random.key(0), (4, 8, 16)).astype(jnp.bfloat16)
    got = ops.chunk_sum(x)
    assert got.shape == (8, 16)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.chunk_sum_ref(x.reshape(4, -1))
                                          .reshape(8, 16)), rtol=1e-6)
    p = jax.random.normal(jax.random.key(1), (8, 16))
    po, mo = ops.fused_sgd(p, p, jnp.zeros_like(p), 0.1)
    assert po.shape == (8, 16)


@pytest.mark.parametrize("k", [2, 8])
@pytest.mark.parametrize("n", [128, 5000])
@pytest.mark.parametrize("nesterov", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float16])
def test_fused_rs_update_matches_ref(k, n, nesterov, dtype):
    key = jax.random.key(k * n + nesterov)
    recv = (jax.random.normal(key, (k, n)) * 2).astype(dtype)
    p = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    m = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    mask = (jax.random.uniform(jax.random.fold_in(key, 3), (n,))
            > 0.5).astype(jnp.float32)
    kw = dict(momentum=0.9, nesterov=nesterov, scale=1.0 / k,
              weight_decay=5e-4)
    po, mo = raw_rs_update(recv, p, m, mask, 0.05, interpret=True, **kw)
    pr, mr = ref.fused_rs_update_ref(recv, p, m, mask, 0.05, **kw)
    np.testing.assert_allclose(np.asarray(po), np.asarray(pr), rtol=2e-5,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(mr), rtol=2e-5,
                               atol=1e-7)


def test_fused_rs_update_matches_chunk_sum_plus_fused_sgd():
    """The fused kernel == default_chunk_sum -> (wd) -> fused_sgd chain."""
    k, n = 8, 4000
    key = jax.random.key(7)
    recv = (jax.random.normal(key, (k, n)) * 2).astype(jnp.float16)
    p = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    m = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    mask = jnp.ones((n,), jnp.float32)
    po, mo = raw_rs_update(recv, p, m, mask, 0.05, momentum=0.9,
                           nesterov=True, scale=1.0 / k, weight_decay=5e-4,
                           interpret=True)
    g = ref.chunk_sum_ref(recv) / k + 5e-4 * p
    pc, mc = ops.fused_sgd(p, g, m, 0.05, momentum=0.9, nesterov=True)
    np.testing.assert_allclose(np.asarray(po), np.asarray(pc), rtol=1e-6,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(mc), rtol=1e-6,
                               atol=1e-7)


def test_fused_rs_update_int8_dequant():
    """int8 wire variant dequantizes with one fp32 scale per rank chunk."""
    k, n = 4, 3001
    key = jax.random.key(3)
    q = jax.random.randint(key, (k, n), -127, 128, dtype=jnp.int8)
    scales = jax.random.uniform(jax.random.fold_in(key, 1), (k,)) * 0.01
    p = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    m = jnp.zeros((n,))
    mask = jnp.zeros((n,), jnp.float32)
    po, mo = raw_rs_update(q, p, m, mask, 0.1, scale=1.0 / k, scales=scales,
                           interpret=True)
    pr, mr = ref.fused_rs_update_ref(q, p, m, mask, 0.1, scale=1.0 / k,
                                     scales=scales)
    np.testing.assert_allclose(np.asarray(po), np.asarray(pr), rtol=1e-6,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(mr), rtol=1e-6,
                               atol=1e-7)


def test_default_interpret_cpu_and_env(monkeypatch):
    """Backend autodetect: interpret on CPU; env overrides win."""
    assert default_interpret() is True   # this container is CPU-only
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert default_interpret() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert default_interpret() is True
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    monkeypatch.setenv("REPRO_PALLAS_COMPILED", "1")
    assert default_interpret() is False


@settings(max_examples=20, deadline=None)
@given(k=st.integers(2, 8), n=st.integers(1, 600))
def test_chunk_sum_property(k, n):
    x = (jax.random.normal(jax.random.key(k + 31 * n), (k, n)) * 2).astype(
        jnp.float16)
    got = raw_chunk_sum(x, block_n=256, interpret=True)
    want = np.asarray(x, np.float32).sum(axis=0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 3000))
def test_int8_error_bound_property(n):
    x = jax.random.normal(jax.random.key(n), (n,)) * 10
    q, s = ref.quant_int8_ref(x)
    d = ref.dequant_int8_ref(q, s)
    err = np.max(np.abs(np.asarray(d) - np.asarray(x)))
    assert err <= float(jnp.max(s)) * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# slot_gather: fused per-slot logit gather + sampling transform
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,C,V", [(1, 1, 500), (4, 1, 512),
                                   (5, 8, 700), (3, 16, 130)])
def test_slot_gather_sample_matches_ref(S, C, V):
    key = jax.random.key(S * 1000 + C * 10 + V)
    logits = jax.random.normal(key, (S, C, V), jnp.float32) * 3
    idx = jax.random.randint(jax.random.fold_in(key, 1), (S,), 0, C)
    onehot = jax.nn.one_hot(idx, C)
    temps = jax.random.uniform(jax.random.fold_in(key, 2), (S,)) * 2
    temps = temps.at[0].set(0.0)              # one greedy slot
    noise = jax.random.gumbel(jax.random.fold_in(key, 3), (S, V))
    g1, s1 = slot_gather_sample(logits, onehot, temps, noise,
                                interpret=True, block_v=256)
    g2, s2 = ref.slot_gather_sample_ref(logits, onehot, temps, noise)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_slot_gather_bf16_logits_and_tie_breaking():
    # bf16 decode logits produce ties; argmax must pick the first (ref
    # semantics) in compiled-grid accumulation too
    S, V = 3, 600
    logits = jnp.zeros((S, 1, V), jnp.bfloat16)
    logits = logits.at[:, 0, 37].set(2.0).at[:, 0, 412].set(2.0)
    onehot = jnp.ones((S, 1))
    temps = jnp.zeros((S,))
    noise = jnp.zeros((S, V))
    g, _ = slot_gather_sample(logits, onehot, temps, noise,
                              interpret=True, block_v=128)
    assert np.asarray(g).tolist() == [37, 37, 37]


def test_slot_gather_gathers_correct_row():
    # each slot picks a different chunk row; greedy index must follow it
    S, C, V = 4, 4, 256
    base = jnp.full((S, C, V), -1.0, jnp.float32)
    idx = jnp.asarray([0, 1, 2, 3])
    want = jnp.asarray([10, 20, 30, 40])
    logits = base
    for s in range(S):
        logits = logits.at[s, idx[s], want[s]].set(5.0)
        # decoy max in a row the slot must NOT gather
        logits = logits.at[s, (idx[s] + 1) % C, (want[s] + 1) % V].set(9.0)
    onehot = jax.nn.one_hot(idx, C)
    g, _ = slot_gather_sample(logits, onehot, jnp.zeros((S,)),
                              jnp.zeros((S, V)), interpret=True)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(want))
