"""RS->update->AG (sharded fused update) equivalence.

Property: training with ``sharded_update=True`` (and with
``overlap="buckets"``) is bitwise/tolerance-equivalent to the existing
exchange-then-update path for every strategy on an 8-way host mesh — with
deliberately non-divisible leaf sizes so the pad/shard/unpad plumbing is
exercised. Lossy-wire strategies (fp16/int8) differ only by where the
rounding lands (reduced gradient vs gathered parameters), so they get
per-strategy tolerances.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(keeps the main pytest process at 1 device per the dry-run contract).
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import (get_exchanger, init_sharded_train_state,
                        init_train_state, make_bsp_step)
from repro.models.registry import Model
from repro.optim import adamw, constant, sgd_momentum

# leaf sizes chosen to be non-divisible by k=8 and to cover all plan
# classes: bucketed 2-D (2541, 3080), bucketed 1-D (1237), small (5, 17)
def init(key):
    r = lambda i, s: jax.random.normal(jax.random.fold_in(key, i), s) * 0.05
    return {"w1": r(0, (33, 77)), "w2": r(1, (77, 40)), "b1": r(2, (1237,)),
            "small": r(3, (5,)), "norm": r(4, (17,))}

def loss_fn(params, batch, rng=None, unroll=False):
    h = jnp.tanh(batch["x"] @ params["w1"]) @ params["w2"]
    loss = (0.5 * jnp.mean(jnp.square(h))
            + 1e-3 * jnp.sum(jnp.square(params["b1"]))
            + 1e-3 * jnp.sum(jnp.square(params["norm"]))
            + jnp.sum(jnp.square(params["small"])))
    return loss, {"loss": loss, "aux": jnp.zeros(())}

model = Model(cfg=None, init=init, loss_fn=loss_fn, forward=None)
mesh = jax.make_mesh((8,), ("data",))
jax.set_mesh(mesh)
batch = {"x": np.random.default_rng(0).normal(0, 1, (32, 33)).astype(
    np.float32)}
STEPS = 3
results = {}


def run(opt, strat, **kw):
    sharded = kw.get("sharded_update") or kw.get("overlap")
    if sharded:
        state = init_sharded_train_state(
            model, opt, jax.random.key(0), mesh,
            bucket_bytes=kw.get("bucket_bytes", 0))
    else:
        state = init_train_state(model, opt, jax.random.key(0))
    step = jax.jit(make_bsp_step(model, opt, get_exchanger(strat),
                                 constant(0.05), mesh, **kw))
    for i in range(STEPS):
        state, metrics = step(state, batch, jax.random.key(100 + i))
    return state


def rel_err(a, b):
    errs = {}
    for k in a["params"]:
        x = np.asarray(a["params"][k], np.float32)
        y = np.asarray(b["params"][k], np.float32)
        errs[k] = float(np.abs(x - y).max() / (np.abs(y).max() + 1e-9))
    return errs


sgd = sgd_momentum(momentum=0.9, weight_decay=5e-4)
for strat in ["ar", "asa", "asa16", "asa8", "ring", "hier"]:
    base = run(sgd, strat)
    for tag, kw in [
        ("sharded", dict(sharded_update=True)),
        ("sharded+buckets", dict(sharded_update=True, bucket_bytes=4096)),
        ("overlap", dict(overlap="buckets", microbatches=4)),
    ]:
        if tag == "overlap":
            base_cmp = run(sgd, strat, microbatches=4)
        else:
            base_cmp = base
        got = run(sgd, strat, **kw)
        errs = rel_err(got, base_cmp)
        fin = all(bool(jnp.isfinite(l).all())
                  for l in jax.tree.leaves(got["opt"]))
        results[f"{strat}:{tag}"] = {"errs": errs, "finite_opt": fin}

# sharded path must also shard the momentum: global bucket state is
# (k * shard_len,) and the per-bucket shards reassemble the replicated
# momentum of the baseline path (fp32 strategy => tight tolerance)
st = run(sgd, "asa", sharded_update=True)
m0 = np.asarray(st["opt"]["buckets"][0]["m"])
results["momentum_shape"] = {"shape": list(m0.shape)}

# adamw flat path
ad = adamw(weight_decay=0.0)
base = run(ad, "asa")
got = run(ad, "asa", sharded_update=True)
results["adamw:sharded"] = {"errs": rel_err(got, base),
                            "finite_opt": True}

# sub-ulp updates must accumulate in the fp32 master shard: with lr*grad
# ~2% of the fp16 ulp at w=1.0, a path that fed the fp16 param gather back
# into the update would never move the weights at all
def init2(key):
    return {"w": jnp.ones((2000,), jnp.float32)}

def loss2(params, batch, rng=None, unroll=False):
    loss = 0.1 * jnp.mean(params["w"]) + 0.0 * jnp.mean(batch["x"])
    return loss, {"loss": loss, "aux": jnp.zeros(())}

m2 = Model(cfg=None, init=init2, loss_fn=loss2, forward=None)
opt2 = sgd_momentum(momentum=0.0, weight_decay=0.0)
st2 = init_sharded_train_state(m2, opt2, jax.random.key(0), mesh)
step2 = jax.jit(make_bsp_step(m2, opt2, get_exchanger("asa16"),
                              constant(0.2), mesh, sharded_update=True))
for i in range(100):
    st2, _ = step2(st2, batch, jax.random.key(i))
results["master_accum"] = {
    "delta": float(1.0 - np.asarray(st2["params"]["w"]).mean())}

# nesterov + fused kernel path agree with the unfused flat update
# (fuse forced on: auto mode keeps it off in Pallas interpreter mode)
sgd_n = sgd_momentum(momentum=0.9, weight_decay=5e-4, nesterov=True)
a = run(sgd_n, "asa16", sharded_update=True, fuse_rs_update=True)
b = run(sgd_n, "asa16", sharded_update=True, fuse_rs_update=False)
results["fused_vs_flat"] = {"errs": rel_err(a, b)}
print("RESULTS_JSON:" + json.dumps(results))
"""

_TOL = {"ar": 2e-6, "asa": 2e-6, "ring": 2e-6, "hier": 2e-6,
        "asa16": 3e-3, "asa8": 3e-2}


def _run_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON:"):
            return json.loads(line[len("RESULTS_JSON:"):])
    raise AssertionError(f"no results in output: {proc.stdout[-2000:]}")


_results_cache = {}


@pytest.fixture(scope="module")
def results():
    if not _results_cache:
        _results_cache.update(_run_subprocess())
    return _results_cache


@pytest.mark.parametrize("strategy",
                         ["ar", "asa", "asa16", "asa8", "ring", "hier"])
@pytest.mark.parametrize("mode", ["sharded", "sharded+buckets", "overlap"])
def test_sharded_update_matches_exchange_then_update(results, strategy,
                                                     mode):
    r = results[f"{strategy}:{mode}"]
    tol = _TOL[strategy]
    bad = {k: e for k, e in r["errs"].items() if e > tol}
    assert not bad, f"{strategy}:{mode} errors {bad} > tol {tol}"
    assert r["finite_opt"]


def test_momentum_state_is_sharded(results):
    # leaves flatten alphabetically: the first bucket packs b1 (1237):
    # shard_len = ceil(1237/8) = 155, global extent 155 * 8
    assert results["momentum_shape"]["shape"] == [155 * 8]


def test_sub_ulp_updates_accumulate_in_master(results):
    # 100 steps x 1e-5/step = 1e-3 expected drop; without fp32 master
    # weights the fp16 gather would round every step away (delta == 0)
    assert results["master_accum"]["delta"] > 5e-4


def test_adamw_sharded_matches(results):
    errs = results["adamw:sharded"]["errs"]
    assert max(errs.values()) <= 2e-6, errs


def test_fused_kernel_matches_flat_update(results):
    errs = results["fused_vs_flat"]["errs"]
    assert max(errs.values()) <= 1e-6, errs


def test_rs_plan_invariants():
    """Every leaf lands in exactly one bucket or the small set; shards
    cover the bucket; plan is deterministic for shapes."""
    import jax
    import jax.numpy as jnp
    from repro.core.exchanger import make_rs_plan

    tree = {"a": jnp.zeros((33, 77)), "b": jnp.zeros((1237,)),
            "c": jnp.zeros((5,)), "d": jnp.zeros((2048, 3))}
    for bb in [0, 4096, 1 << 20]:
        plan = make_rs_plan(tree, 8, bucket_bytes=bb)
        seen = sorted(i for b in plan.buckets for i in b.leaves)
        seen += sorted(plan.small)
        assert sorted(seen) == list(range(4))
        for b in plan.buckets:
            assert b.padded == b.shard_len * 8
            assert b.padded >= sum(b.sizes)
        abs_tree = jax.eval_shape(lambda: tree)
        plan2 = make_rs_plan(abs_tree, 8, bucket_bytes=bb)
        assert plan2.buckets == plan.buckets and plan2.small == plan.small


def test_pack_unpack_roundtrip():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.exchanger import Exchanger, make_rs_plan

    key = jax.random.key(0)
    tree = {"a": jax.random.normal(key, (33, 77)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (1237,)),
            "c": jax.random.normal(jax.random.fold_in(key, 2), (5,)).astype(
                jnp.float16)}
    plan = make_rs_plan(tree, 8, bucket_bytes=1 << 20)
    flats, smalls, _ = Exchanger.pack(tree, plan)
    back = Exchanger.unpack(flats, smalls, plan)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_allclose(np.asarray(back[k], np.float32),
                                   np.asarray(tree[k], np.float32),
                                   rtol=1e-6)
