"""Serving engine: greedy parity vs the generate() oracle per family,
compile-once under request churn, chunked-prefill bit-exactness, sampling
determinism, scheduler lifecycle, and checkpoint round-trip onto the serve
mesh."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import Engine, Request, SamplingParams, SlotScheduler
from repro.serve import cache as cache_mod
from repro.serve import sampling as sampling_mod
from repro.train.serve import generate, _generate_stepwise

FAMILIES = ["llama3.2-1b", "mamba2-1.3b", "deepseek-v2-lite-16b"]


@functools.lru_cache(maxsize=None)
def _setup(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _mixed_workload(cfg, n_req=4, seed=0):
    rng = np.random.RandomState(seed)
    lens = [5, 12, 9, 17, 7, 14][:n_req]
    news = [6, 3, 9, 5, 8, 4][:n_req]
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in lens]
    return prompts, news


# ---------------------------------------------------------------------------
# greedy parity: engine == generate() per request, under churn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMILIES)
def test_engine_greedy_parity_with_generate(arch):
    """Mixed prompt/output lengths over fewer slots than requests: slots
    churn (evict + refill mid-flight) and every request's greedy tokens
    must still be bit-exact with the whole-batch-free oracle."""
    cfg, model, params = _setup(arch)
    prompts, news = _mixed_workload(cfg)
    eng = Engine(model, params, max_slots=3, max_seq=64, prefill_chunk=16)
    rids = [eng.submit(p, m) for p, m in zip(prompts, news)]
    res = eng.run()
    for rid, p, m in zip(rids, prompts, news):
        want = generate(model, params, jnp.asarray([p], jnp.int32),
                        max_new=m, seq_len=len(p) + m)
        assert res[rid] == np.asarray(want)[0, len(p):].tolist(), \
            f"{arch}: engine diverged from generate() for rid={rid}"


@pytest.mark.parametrize("arch", FAMILIES)
def test_generate_one_call_prefill_matches_stepwise(arch):
    """Satellite guard: the one-call prefill rewrite of generate() keeps
    outputs identical to the old token-by-token forced-decode loop."""
    cfg, model, params = _setup(arch)
    prompt = jax.random.randint(jax.random.key(3), (2, 11), 0,
                                cfg.vocab_size)
    new = generate(model, params, prompt, max_new=6, seq_len=17)
    old = _generate_stepwise(model, params, prompt, max_new=6, seq_len=17)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))


# ---------------------------------------------------------------------------
# static-shape contract: one compile across churn
# ---------------------------------------------------------------------------

def test_decode_compiles_once_across_churn():
    cfg, model, params = _setup("llama3.2-1b")
    prompts, news = _mixed_workload(cfg, n_req=6)
    eng = Engine(model, params, max_slots=2, max_seq=64, prefill_chunk=8)
    for p, m in zip(prompts, news):
        eng.submit(p, m)
    eng.run()
    # 6 requests over 2 slots: many joins/evictions happened
    assert eng.stats.steps > 6
    assert eng.trace_counts["decode"] == 1, \
        f"decode retraced {eng.trace_counts['decode']}x under churn"
    assert eng.trace_counts["prefill"] == 1
    assert eng.trace_counts["sample"] == 1


def test_engine_late_submissions_no_retrace():
    """Requests arriving while the engine is mid-flight reuse the same
    compiled step."""
    cfg, model, params = _setup("llama3.2-1b")
    prompts, news = _mixed_workload(cfg, n_req=4)
    eng = Engine(model, params, max_slots=2, max_seq=64, prefill_chunk=8)
    eng.submit(prompts[0], news[0])
    for _ in range(2):
        eng.step()
    eng.submit(prompts[1], news[1])      # joins mid-decode
    eng.submit(prompts[2], news[2])
    res = eng.run()
    assert len(res) == 3
    assert eng.trace_counts["decode"] == 1


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b"])
def test_chunked_prefill_cache_bitwise(arch):
    """Prefilling a prompt in aligned chunks leaves the cache bit-identical
    to a single-call prefill (SSM state/conv tail included)."""
    cfg, model, params = _setup(arch)
    total, S0, C = 48, 24, 16
    prompt = jax.random.randint(jax.random.key(1), (1, S0), 0,
                                cfg.vocab_size)
    pf = jax.jit(functools.partial(model.chunk_prefill, seq_len=total))
    cc = model.init_cache(1, total)
    lg = None
    for c in range(0, S0, C):
        sl = prompt[:, c:c + C]
        v = sl.shape[1]
        sl = jnp.pad(sl, ((0, 0), (0, C - v)))
        lg, cc = pf(params, cc, sl, jnp.int32(c), jnp.int32(v))
    cr = model.init_cache(1, total)
    lgr, cr = pf(params, cr, prompt, jnp.int32(0), jnp.int32(S0))
    np.testing.assert_array_equal(np.asarray(lg[:, v - 1]),
                                  np.asarray(lgr[:, -1]))
    if cfg.ssm is not None:
        # SSM cache must match on every leaf (state carries across chunks)
        for a, b in zip(jax.tree.leaves(cc), jax.tree.leaves(cr)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_engine_rounds_prefill_chunk_to_ssd_blocks():
    cfg, model, params = _setup("mamba2-1.3b")
    eng = Engine(model, params, max_slots=1, max_seq=48, prefill_chunk=10)
    assert eng.prefill_chunk % cfg.ssm.chunk == 0


def test_last_chunk_window_cannot_clobber_prompt_rows():
    """Regression: an 18-token prompt on max_seq=20, prefill_chunk=16 puts
    the second chunk's write window [16, 32) past the pool edge; an
    unclamped pool would let dynamic_update_slice clamp pos0 to 4 and
    silently overwrite prompt K/V rows (engine returned garbage). The
    engine rounds max_seq up to a chunk multiple so every window fits."""
    cfg, model, params = _setup("llama3.2-1b")
    eng = Engine(model, params, max_slots=1, max_seq=20, prefill_chunk=16)
    assert eng.max_seq % eng.prefill_chunk == 0
    prompt = jax.random.randint(jax.random.key(5), (1, 18), 0,
                                cfg.vocab_size)
    rid = eng.submit(np.asarray(prompt)[0].tolist(), 2)
    got = eng.run()[rid]
    want = generate(model, params, prompt, max_new=2, seq_len=20)
    assert got == np.asarray(want)[0, 18:].tolist()


def test_submit_rejects_degenerate_requests():
    cfg, model, params = _setup("llama3.2-1b")
    eng = Engine(model, params, max_slots=1, max_seq=32, prefill_chunk=8)
    with pytest.raises(ValueError):
        eng.submit([], 4)
    with pytest.raises(ValueError):
        eng.submit([1, 2], 0)


def test_prefill_overwrites_stale_slot_state():
    """A reused slot must behave as if freshly reset: run a request on a
    dirty lane and on an explicitly reset lane, outputs match."""
    cfg, model, params = _setup("mamba2-1.3b")
    prompts, news = _mixed_workload(cfg, n_req=3)
    eng = Engine(model, params, max_slots=1, max_seq=64, prefill_chunk=16)
    r0 = eng.submit(prompts[0], news[0])
    res_dirty = eng.run()
    # same request on a zeroed pool
    eng.pool = cache_mod.reset_slot(eng.pool, jnp.int32(0))
    r1 = eng.submit(prompts[0], news[0])
    res_clean = eng.run()
    assert res_dirty[r0] == res_clean[r1]
    # and after serving a different request in between (dirty lane)
    r2 = eng.submit(prompts[1], news[1])
    eng.run()
    r3 = eng.submit(prompts[0], news[0])
    assert eng.run()[r3] == res_dirty[r0]


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_sampling_deterministic_under_fixed_keys():
    cfg, model, params = _setup("llama3.2-1b")
    prompts, news = _mixed_workload(cfg, n_req=2)
    sp = SamplingParams(temperature=0.9, seed=42)

    def run_once():
        eng = Engine(model, params, max_slots=2, max_seq=48,
                     prefill_chunk=8)
        rids = [eng.submit(p, m, sp) for p, m in zip(prompts, news)]
        return [eng.run()[r] for r in rids]

    assert run_once() == run_once()


def test_fused_sampling_matches_full_path():
    """slot_gather kernel path == jnp path for greedy and temperature."""
    cfg, model, params = _setup("llama3.2-1b")
    prompts, news = _mixed_workload(cfg, n_req=2)
    for temp in (0.0, 0.9):
        outs = []
        for fused in (False, True):
            eng = Engine(model, params, max_slots=2, max_seq=48,
                         prefill_chunk=8, fused_sampling=fused)
            rids = [eng.submit(p, m, SamplingParams(temperature=temp,
                                                    seed=7))
                    for p, m in zip(prompts, news)]
            res = eng.run()
            outs.append([res[r] for r in rids])
        assert outs[0] == outs[1], f"fused != full at temperature {temp}"


def test_fused_engine_rejects_topk_topp():
    cfg, model, params = _setup("llama3.2-1b")
    eng = Engine(model, params, max_slots=1, max_seq=32, prefill_chunk=8,
                 fused_sampling=True)
    with pytest.raises(ValueError):
        eng.submit([1, 2, 3], 2, SamplingParams(temperature=1.0, top_k=5))


def test_top_k_one_is_greedy():
    cfg, model, params = _setup("llama3.2-1b")
    prompts, news = _mixed_workload(cfg, n_req=1)

    def run_once(sp):
        eng = Engine(model, params, max_slots=1, max_seq=48,
                     prefill_chunk=8)
        rid = eng.submit(prompts[0], news[0], sp)
        return eng.run()[rid]

    assert run_once(SamplingParams(temperature=1.0, top_k=1, seed=5)) \
        == run_once(SamplingParams())


def test_sample_tokens_masks():
    """Unit checks of the fused sampler math on a hand-built distribution."""
    logits = jnp.log(jnp.asarray([[0.5, 0.25, 0.15, 0.07, 0.03]]))
    noise = jnp.zeros((1, 5))
    temps = jnp.ones((1,), jnp.float32)
    # top_p = 0.6: nucleus is {0, 1} (0.5 < 0.6 <= 0.75); noise=0 -> argmax
    tok = sampling_mod.sample_tokens(logits, temps, jnp.zeros((1,), jnp.int32),
                                     jnp.asarray([0.6]), noise)
    assert int(tok[0]) == 0
    # huge noise on a token outside the top_p nucleus cannot select it
    noise2 = jnp.zeros((1, 5)).at[0, 4].set(100.0)
    tok2 = sampling_mod.sample_tokens(logits, temps,
                                      jnp.zeros((1,), jnp.int32),
                                      jnp.asarray([0.6]), noise2)
    assert int(tok2[0]) in (0, 1)
    # same noise with top_p off selects it
    tok3 = sampling_mod.sample_tokens(logits, temps,
                                      jnp.zeros((1,), jnp.int32),
                                      jnp.asarray([1.0]), noise2)
    assert int(tok3[0]) == 4
    # top_k = 2 masks index >= 2 even with huge noise
    noise3 = jnp.zeros((1, 5)).at[0, 2].set(100.0)
    tok4 = sampling_mod.sample_tokens(logits, temps,
                                      jnp.asarray([2], jnp.int32),
                                      jnp.asarray([1.0]), noise3)
    assert int(tok4[0]) in (0, 1)


# ---------------------------------------------------------------------------
# scheduler lifecycle
# ---------------------------------------------------------------------------

def test_scheduler_fifo_and_slot_reuse():
    s = SlotScheduler(max_slots=2, max_seq=32)
    rids = [s.submit(Request(tokens=[1, 2], max_new=2)) for _ in range(4)]
    placed = s.admit()
    assert [r.rid for _, r in placed] == rids[:2]
    assert s.num_active == 2 and len(s.pending) == 2
    # finish slot 0's request -> evicted, refilled FIFO
    s.record_first_token(0, 9)
    s.record_first_token(1, 9)
    s.record_step([9, 9])      # both reach max_new=2 -> both freed
    assert s.num_active == 0
    placed = s.admit()
    assert [r.rid for _, r in placed] == rids[2:]
    assert sorted(sl for sl, _ in placed) == [0, 1]


def test_scheduler_eos_and_overflow():
    s = SlotScheduler(max_slots=1, max_seq=16)
    with pytest.raises(ValueError):
        s.submit(Request(tokens=[0] * 10, max_new=10))
    rid = s.submit(Request(tokens=[1, 2, 3], max_new=8, eos=7))
    s.admit()
    s.record_first_token(0, 4)
    s.record_step([7])         # eos fires mid-flight
    assert s.results()[rid] == [4, 7]
    assert s.num_active == 0


def test_scheduler_positions_track_cache_rows():
    s = SlotScheduler(max_slots=2, max_seq=32)
    s.submit(Request(tokens=[1, 2, 3], max_new=4))
    s.admit()
    assert s.positions() == [3, 0]
    s.record_first_token(0, 5)
    assert s.feed_tokens() == [5, 0]
    s.record_step([6, 0])
    assert s.positions() == [4, 0]


# ---------------------------------------------------------------------------
# mesh placement + checkpoint round-trip
# ---------------------------------------------------------------------------

def test_engine_on_mesh_matches_unsharded():
    cfg, model, params = _setup("llama3.2-1b")
    prompts, news = _mixed_workload(cfg, n_req=2)
    mesh = jax.make_mesh((1,), ("data",))
    eng_m = Engine(model, params, max_slots=2, max_seq=48,
                   prefill_chunk=8, mesh=mesh)
    eng_u = Engine(model, params, max_slots=2, max_seq=48, prefill_chunk=8)
    rids_m = [eng_m.submit(p, m) for p, m in zip(prompts, news)]
    rids_u = [eng_u.submit(p, m) for p, m in zip(prompts, news)]
    res_m, res_u = eng_m.run(), eng_u.run()
    assert [res_m[r] for r in rids_m] == [res_u[r] for r in rids_u]


def test_checkpoint_roundtrip_into_serving(tmp_path):
    """ckpt.save params -> restore onto the serve-mesh sharding -> engine
    output matches pre-save."""
    from repro.checkpoint.ckpt import save_checkpoint, restore_checkpoint
    from repro.dist.sharding import param_shardings

    cfg, model, params = _setup("llama3.2-1b")
    prompts, news = _mixed_workload(cfg, n_req=2)
    save_checkpoint(str(tmp_path / "ck"), params, step=7)

    mesh = jax.make_mesh((1,), ("data",))
    like = jax.device_put(params, param_shardings(mesh, params))
    restored = restore_checkpoint(str(tmp_path / "ck"), like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    eng0 = Engine(model, params, max_slots=2, max_seq=48, prefill_chunk=8)
    eng1 = Engine(model, restored, max_slots=2, max_seq=48,
                  prefill_chunk=8, mesh=mesh)
    r0 = [eng0.submit(p, m) for p, m in zip(prompts, news)]
    r1 = [eng1.submit(p, m) for p, m in zip(prompts, news)]
    out0, out1 = eng0.run(), eng1.run()
    assert [out0[r] for r in r0] == [out1[r] for r in r1]


# ---------------------------------------------------------------------------
# MoE slot independence (the drop-free routing contract)
# ---------------------------------------------------------------------------

def test_moe_decode_independent_of_batch_composition():
    """A request's greedy tokens must not depend on what other slots are
    doing — deepseek routes through MoE layers where capacity drops would
    couple lanes; drop-free decode routing removes that."""
    cfg, model, params = _setup("deepseek-v2-lite-16b")
    prompts, news = _mixed_workload(cfg, n_req=3)
    solo = Engine(model, params, max_slots=1, max_seq=64, prefill_chunk=16)
    rid_s = solo.submit(prompts[0], news[0])
    want = solo.run()[rid_s]
    crowd = Engine(model, params, max_slots=3, max_seq=64, prefill_chunk=16)
    rids = [crowd.submit(p, m) for p, m in zip(prompts, news)]
    got = crowd.run()[rids[0]]
    assert got == want
