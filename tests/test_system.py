"""End-to-end behaviour: multi-step training decreases loss (BSP subgd &
awagd, EASGD), generation runs, GSPMD/ZeRO-1 path agrees with BSP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import get_exchanger, init_train_state, make_bsp_step
from repro.core.gspmd import make_gspmd_step
from repro.data.synthetic import LMTokenSource
from repro.models import build_model
from repro.optim import constant, sgd_momentum
from repro.train.engine import TrainPlan
from repro.train.loop import train
from repro.train.serve import generate


def _tiny_lm():
    cfg = get_smoke_config("llama3.2-1b").with_overrides(
        vocab_size=64, d_ff=128, num_layers=2)
    return cfg, build_model(cfg)


def _batches(cfg, n, bsz=8, seq=32):
    src = LMTokenSource(cfg.vocab_size, seq, seed=0)
    return [src.batch(bsz, i) for i in range(n)]


def test_bsp_training_decreases_loss():
    cfg, model = _tiny_lm()
    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    opt = sgd_momentum(weight_decay=0.0)
    _, report = train(model, opt, constant(0.02), mesh,
                      _batches(cfg, 40), exchanger="asa", num_steps=40,
                      log_every=0, print_fn=lambda *_: None)
    first = np.mean(report.losses[:5])
    last = np.mean(report.losses[-5:])
    assert last < first - 0.1, f"no learning: {first:.3f} -> {last:.3f}"


def test_awagd_scheme_trains():
    cfg, model = _tiny_lm()
    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    opt = sgd_momentum(weight_decay=0.0)
    _, report = train(model, opt, constant(0.02), mesh,
                      _batches(cfg, 25), exchanger="ar", scheme="awagd",
                      num_steps=25, log_every=0, print_fn=lambda *_: None)
    assert np.mean(report.losses[-5:]) < np.mean(report.losses[:5])


def test_easgd_trains_center():
    cfg, model = _tiny_lm()
    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    opt = sgd_momentum(weight_decay=0.0)
    state, report = train(model, opt, constant(0.02), mesh,
                          _batches(cfg, 30),
                          plan=TrainPlan(algo="easgd", alpha=0.5, tau=2),
                          num_steps=30, log_every=0,
                          print_fn=lambda *_: None)
    assert np.mean(report.losses[-5:]) < np.mean(report.losses[:5])
    # center was pulled toward workers
    c = jax.tree.leaves(state["center"])[0]
    assert bool(jnp.isfinite(c).all())


def test_gspmd_zero1_matches_bsp_ar_one_step():
    cfg, model = _tiny_lm()
    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    opt = sgd_momentum(weight_decay=0.0)
    state = init_train_state(model, opt, jax.random.key(0))
    batch = _batches(cfg, 1)[0]
    bsp = jax.jit(make_bsp_step(model, opt, get_exchanger("ar"),
                                constant(0.1), mesh))
    gsp = jax.jit(make_gspmd_step(model, opt, constant(0.1), mesh,
                                  mode="zero1"))
    s1, m1 = bsp(state, batch, jax.random.key(1))
    s2, m2 = gsp(state, batch, jax.random.key(1))
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_generation_shapes_and_determinism():
    cfg, model = _tiny_lm()
    params = model.init(jax.random.key(0))
    prompt = jnp.ones((2, 4), jnp.int32)
    out1 = generate(model, params, prompt, max_new=6, seq_len=10)
    out2 = generate(model, params, prompt, max_new=6, seq_len=10)
    assert out1.shape == (2, 10)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :4]),
                                  np.asarray(prompt))


def test_microbatch_accumulation_matches_full_batch():
    """grad(mean over batch) == mean of microbatch grads (linearity).

    fp32 compute: bf16 matmul accumulation order differs between the split
    and unsplit batch shapes and would mask real errors."""
    cfg, model = _tiny_lm()
    from repro.models import build_model
    cfg = cfg.with_overrides(dtype="float32")
    model = build_model(cfg)
    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    opt = sgd_momentum(weight_decay=0.0)
    state = init_train_state(model, opt, jax.random.key(0))
    batch = _batches(cfg, 1, bsz=8)[0]
    s_full = jax.jit(make_bsp_step(model, opt, get_exchanger("ar"),
                                   constant(0.05), mesh))
    s_micro = jax.jit(make_bsp_step(model, opt, get_exchanger("ar"),
                                    constant(0.05), mesh, microbatches=4))
    a, ma = s_full(state, batch, jax.random.key(1))
    b, mb = s_micro(state, batch, jax.random.key(1))
    assert float(ma["loss"]) == pytest.approx(float(mb["loss"]), rel=1e-4)
    for x, y in zip(jax.tree.leaves(a["params"]),
                    jax.tree.leaves(b["params"])):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=1e-4, atol=1e-6)
