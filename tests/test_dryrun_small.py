"""Dry-run machinery at reduced scale: sharding rules, spec sanitizer,
collective-bytes parser, and a subprocess mini dry-run on an 8-device mesh
(mirrors launch/dryrun.py without locking the main process to 512 devices).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.roofline.analysis import (Roofline, model_flops_6nd,
                                     parse_collectives)


def test_parse_collectives_known_hlo():
    hlo = """
  %ag = bf16[16,128,4096]{2,1,0} all-gather(%x), replica_groups={{0,1}}
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = (f32[8,16]{1,0}) all-to-all(%w)
  %cp = bf16[256]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %ags = bf16[4,4]{1,0} all-gather-start(%q)
"""
    st = parse_collectives(hlo)
    assert st.counts["all-gather"] == 2
    assert st.counts["all-reduce"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["all-to-all"] == 1
    assert st.counts["collective-permute"] == 1
    assert st.bytes_by_kind["all-gather"] == 16 * 128 * 4096 * 2 + 4 * 4 * 2
    assert st.bytes_by_kind["all-reduce"] == 1024 * 4 * 2  # 2x for AR
    assert st.bytes_by_kind["collective-permute"] == 256 * 2


def test_roofline_terms_and_dominance():
    rl = Roofline(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=50e9 * 0.5,
                  model_flops=100e12)
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(2.0)
    assert rl.t_collective == pytest.approx(0.5)
    assert rl.dominant == "memory"
    assert rl.useful_ratio == pytest.approx(100 / 197, rel=1e-3)
    assert model_flops_6nd(10, 5, "train") == 300
    assert model_flops_6nd(10, 5, "infer") == 100


def test_sanitize_spec_relocation():
    import numpy as np
    os.environ.setdefault("XLA_FLAGS", "")
    from repro.dist.sharding import sanitize_spec
    mesh = jax.make_mesh((1,), ("model",))

    class M:  # fake mesh with model=16 for divisibility logic
        axis_names = ("model",)
        shape = {"model": 16}
    # 20 heads not divisible by 16 -> moved to hd=128
    spec = sanitize_spec(P(None, None, "model", None), (40, 2560, 20, 128), M)
    assert tuple(spec) == (None, None, None, "model")
    # nothing divisible -> dropped
    spec = sanitize_spec(P("model"), (20,), M)
    assert tuple(spec) == ()
    # divisible stays
    spec = sanitize_spec(P(None, "model"), (5, 32), M)
    assert tuple(spec) == (None, "model")


_MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config, get_shape
from repro.configs.base import InputShape
from repro.core.bsp import make_bsp_step
from repro.core.exchanger import get_exchanger
from repro.core.gspmd import make_gspmd_step, fsdp_state_shardings
from repro.dist.sharding import (batch_shardings, cache_shardings,
                                 state_shardings)
from repro.launch.specs import (abstract_cache, abstract_state,
                                decode_batch_specs, train_batch_specs, sds)
from repro.models import build_model
from repro.optim import sgd_momentum, constant
from repro.roofline.analysis import analyze

mesh = jax.make_mesh((4, 2), ("data", "model"))
jax.set_mesh(mesh)
out = {}
for arch in ["llama3.2-1b", "mamba2-1.3b", "deepseek-v2-lite-16b"]:
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    opt = sgd_momentum(weight_decay=0.0)
    shape = InputShape("tiny_train", 64, 8, "train")
    state = abstract_state(model, opt)
    batch = train_batch_specs(cfg, shape)
    for mode in ["bsp", "zero1"]:
        if mode == "bsp":
            step = make_bsp_step(model, opt, get_exchanger("asa"),
                                 constant(0.01), mesh, data_axes=("data",))
            sh = state_shardings(mesh, state)
        else:
            step = make_gspmd_step(model, opt, constant(0.01), mesh)
            sh = fsdp_state_shardings(mesh, state)
        def fn(s, b, seed, _step=step):
            return _step(s, b, jax.random.wrap_key_data(seed))
        lowered = jax.jit(fn, in_shardings=(
            sh, batch_shardings(mesh, batch),
            NamedSharding(mesh, P()))).lower(state, batch,
                                             sds((2,), jnp.uint32))
        compiled = lowered.compile()
        res = analyze(compiled)
        out[f"{arch}:{mode}"] = {
            "ok": True,
            "colls": res["collectives"]["counts"],
            "coll_bytes": res["roofline"]["coll_bytes"],
        }
    # decode
    dshape = InputShape("tiny_decode", 64, 8, "decode")
    cache = abstract_cache(model, cfg, dshape)
    dbatch = decode_batch_specs(cfg, dshape)
    def dfn(params, cache, b, pos):
        lg, nc = model.decode_step(params, cache, b, pos, seq_len=64)
        return jnp.argmax(lg[:, -1], -1), nc
    from repro.dist.sharding import param_shardings
    params = state["params"]
    lowered = jax.jit(dfn, in_shardings=(
        param_shardings(mesh, params),
        cache_shardings(mesh, cache, 8),
        batch_shardings(mesh, dbatch),
        NamedSharding(mesh, P()))).lower(params, cache, dbatch,
                                         sds((), jnp.int32))
    compiled = lowered.compile()
    out[f"{arch}:decode"] = {"ok": True}
print("RESULTS_JSON:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def mini_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _MINI_DRYRUN], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON:"):
            return json.loads(line[len("RESULTS_JSON:"):])
    raise AssertionError(proc.stdout[-2000:])


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b",
                                  "deepseek-v2-lite-16b"])
def test_mini_dryrun_lowers(mini_results, arch):
    assert mini_results[f"{arch}:bsp"]["ok"]
    assert mini_results[f"{arch}:zero1"]["ok"]
    assert mini_results[f"{arch}:decode"]["ok"]


def test_bsp_path_emits_asa_collectives(mini_results):
    """The ASA exchanger must appear as all-to-all + all-gather in HLO."""
    colls = mini_results["llama3.2-1b:bsp"]["colls"]
    assert colls.get("all-to-all", 0) >= 1, colls
    assert colls.get("all-gather", 0) >= 1, colls
