"""Per-assigned-architecture smoke tests: reduced variant (2 layers,
d_model<=512, <=4 experts), one forward/train step on CPU, asserting output
shapes and no NaNs. Decode smoke for decoder/encdec families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED_ARCHS, PAPER_ARCHS, get_smoke_config)
from repro.core import get_exchanger, init_train_state, make_bsp_step
from repro.models import build_model
from repro.optim import constant, sgd_momentum

ALL = ASSIGNED_ARCHS + PAPER_ARCHS


def make_batch(cfg, B=2, S=32):
    key = jax.random.key(7)
    if cfg.family == "conv":
        return {"images": jax.random.normal(
                    key, (B, cfg.image_size, cfg.image_size, 3)),
                "labels": jnp.zeros((B,), jnp.int32)}
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(
                    key, (B, cfg.encoder_seq_len, cfg.d_model)),
                "tokens": jnp.ones((B, S), jnp.int32),
                "labels": jnp.ones((B, S), jnp.int32)}
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.modality == "vlm":
        b["image_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model)) * 0.02
    return b


@pytest.mark.parametrize("arch", ALL)
def test_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    loss, metrics = model.loss_fn(params, batch, rng=jax.random.key(1))
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    logits = model.forward(params, batch)
    assert logits.ndim in (2, 3) and not bool(jnp.isnan(logits).any())
    if cfg.family != "conv":
        B, S = batch["tokens"].shape
        assert logits.shape == (B, S, cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    opt = sgd_momentum(weight_decay=0.0)
    state = init_train_state(model, opt, jax.random.key(0))
    step = jax.jit(make_bsp_step(model, opt, get_exchanger("asa"),
                                 constant(0.05), mesh))
    batch = make_batch(cfg)
    new_state, metrics = step(state, batch, jax.random.key(1))
    assert jnp.isfinite(metrics["loss"])
    assert int(new_state["step"]) == 1
    # parameters changed and stayed finite
    moved = 0
    for old, new in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])):
        assert bool(jnp.isfinite(new).all()), f"{arch}: non-finite params"
        if not np.array_equal(np.asarray(old), np.asarray(new)):
            moved += 1
    assert moved > 0, f"{arch}: no parameter moved"


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS])
def test_decode_step_smoke(arch):
    cfg = get_smoke_config(arch)
    if cfg.family == "conv":
        pytest.skip("no decode for conv")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    cache = model.init_cache(B, S)
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.key(1),
                                   (B, cfg.encoder_seq_len, cfg.d_model))
        cache = model.prefill(params, frames, cache)
    tokens = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, {"tokens": tokens},
                                       jnp.int32(0), seq_len=S)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: decode NaN/inf"
    # second step with updated cache
    logits2, _ = model.decode_step(params, cache2, {"tokens": tokens},
                                   jnp.int32(1), seq_len=S)
    assert bool(jnp.isfinite(logits2).all())
