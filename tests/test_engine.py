"""Unified training engine (repro.train.engine): plan validation, canonical
state layout, cross-algo parity, resumable runs, loop accounting.

Parity anchors (derivations in DESIGN.md "Training engine"):

- EASGD at ``alpha=1, tau=1`` is synchronous model averaging, which from a
  synced start equals BSP gradient averaging with the learning rate scaled
  by ``k`` (momentum states stay per-worker but their mean tracks the BSP
  momentum by linearity). Exercised at k=1 here and k=8 in the subprocess
  test (which also checks the fp16-wire center exchange).
- GSPMD ``zero1`` and BSP ``sharded_update`` are the same ASA/ZeRO-1
  schedule, declarative vs explicit — losses and params must agree.
- A run restored from a mid-run checkpoint replays the uninterrupted run
  bitwise (state + step + rng fold offset), for every algo.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.synthetic import LMTokenSource
from repro.models import build_model
from repro.optim import adamw, constant, sgd_momentum
from repro.train.engine import TrainPlan, build_engine
from repro.train.loop import train


def _tiny_lm(dtype=None):
    over = dict(vocab_size=64, d_ff=128, num_layers=2)
    if dtype:
        over["dtype"] = dtype
    cfg = get_smoke_config("llama3.2-1b").with_overrides(**over)
    return cfg, build_model(cfg)


def _batches(cfg, n, bsz=8, seq=32):
    src = LMTokenSource(cfg.vocab_size, seq, seed=0)
    return [src.batch(bsz, i) for i in range(n)]


def _mesh1():
    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    return mesh


# ---------------------------------------------------------------------------
# plan validation
# ---------------------------------------------------------------------------

def test_trainplan_validation():
    with pytest.raises(ValueError, match="unknown algo"):
        TrainPlan(algo="hogwild")
    with pytest.raises(ValueError, match="unknown scheme"):
        TrainPlan(scheme="avg")
    with pytest.raises(ValueError, match="unknown gspmd mode"):
        TrainPlan(mode="zero3")
    with pytest.raises(ValueError, match="tau"):
        TrainPlan(algo="easgd", tau=0)
    with pytest.raises(ValueError, match="BSP-only"):
        TrainPlan(algo="easgd", sharded_update=True)
    with pytest.raises(ValueError, match="BSP-only"):
        TrainPlan(algo="gspmd", microbatches=4)
    with pytest.raises(ValueError, match="exchanger"):
        TrainPlan(algo="asgd", exchanger="none")
    # non-applicable knobs fail loudly instead of being silently ignored
    with pytest.raises(ValueError, match="easgd/asgd knob"):
        TrainPlan(algo="bsp", tau=4)
    with pytest.raises(ValueError, match="does not apply"):
        TrainPlan(algo="gspmd", exchanger="asa16")
    with pytest.raises(ValueError, match="gspmd knob"):
        TrainPlan(algo="easgd", mode="ar")
    with pytest.raises(ValueError, match="BSP-only"):
        TrainPlan(algo="gspmd", scheme="awagd")
    with pytest.raises(ValueError, match="async knob"):
        TrainPlan(algo="bsp", alpha=0.9)
    with pytest.raises(ValueError, match="pinned to alpha=1"):
        TrainPlan(algo="asgd", alpha=0.3)
    with pytest.raises(ValueError, match="pinned to alpha=1"):
        TrainPlan(algo="asgd", alpha=0.5)   # no sentinel collision
    # alpha=None resolves to the algo default (self-describing plans)
    assert TrainPlan(algo="asgd").alpha == 1.0
    assert TrainPlan(algo="asgd", alpha=1.0).alpha == 1.0
    assert TrainPlan(algo="easgd").alpha == 0.5
    assert TrainPlan(algo="easgd", tau=4).is_async
    assert not TrainPlan().is_async


# ---------------------------------------------------------------------------
# canonical layout: one entry point drives every algo
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plan", [
    TrainPlan(algo="bsp"),
    TrainPlan(algo="bsp", sharded_update=True),
    TrainPlan(algo="easgd", tau=2),
    TrainPlan(algo="asgd", tau=2),
    TrainPlan(algo="gspmd"),
], ids=lambda p: p.algo + ("+sharded" if p.sharded_update else ""))
def test_engine_canonical_layout(plan):
    cfg, model = _tiny_lm()
    mesh = _mesh1()
    opt = sgd_momentum(weight_decay=0.0)
    eng = build_engine(plan, model, opt, constant(0.02), mesh)
    state = eng.init_state(jax.random.key(0))
    assert {"params", "opt", "step"} <= set(state)
    assert ("center" in state) == plan.is_async
    state, m = eng.step(state, _batches(cfg, 1)[0], jax.random.key(1),
                        step_idx=0)
    assert int(state["step"]) == 1
    assert np.isfinite(float(m["loss"]))
    sh = eng.state_shardings(state)
    assert jax.tree.structure(sh) == jax.tree.structure(state)


def test_easgd_adamw_first_class():
    """Per-worker updates go through the shared Optimizer interface: adamw
    (with its t counter) trains under the async scaffolding."""
    cfg, model = _tiny_lm()
    mesh = _mesh1()
    eng = build_engine(TrainPlan(algo="easgd", tau=2, alpha=0.5), model,
                       adamw(weight_decay=0.0), constant(2e-3), mesh)
    state = eng.init_state(jax.random.key(0))
    losses = []
    for i, b in enumerate(_batches(cfg, 20)):
        state, m = eng.step(state, b, jax.random.fold_in(jax.random.key(1), i),
                            step_idx=i)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    # adamw's t advanced once per step on the worker replica
    assert int(np.asarray(state["opt"]["t"])[0]) == 20


# ---------------------------------------------------------------------------
# parity
# ---------------------------------------------------------------------------

def _run_engine(plan, model, opt, lr, batches, mesh):
    eng = build_engine(plan, model, opt, constant(lr), mesh)
    state = eng.init_state(jax.random.key(0))
    losses = []
    for i, b in enumerate(batches):
        state, m = eng.step(state, b, jax.random.fold_in(jax.random.key(1), i),
                            step_idx=i)
        losses.append(float(m["loss"]))
    return state, losses


def test_easgd_tau1_parity_with_bsp():
    """alpha=1, tau=1 elastic averaging == BSP all-reduce momentum-SGD
    (k=1: no lr rescale needed)."""
    cfg, model = _tiny_lm(dtype="float32")
    mesh = _mesh1()
    opt = sgd_momentum(weight_decay=0.0)
    batches = _batches(cfg, 6)
    sb, lb = _run_engine(TrainPlan(algo="bsp", exchanger="ar"), model, opt,
                         0.05, batches, mesh)
    se, le = _run_engine(TrainPlan(algo="easgd", exchanger="ar", tau=1,
                                   alpha=1.0), model, opt, 0.05, batches,
                         mesh)
    assert lb == pytest.approx(le, rel=1e-5)
    for a, b in zip(jax.tree.leaves(sb["params"]),
                    jax.tree.leaves(se["center"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
    # workers re-fetched the center (alpha=1 snap)
    for w, c in zip(jax.tree.leaves(se["params"]),
                    jax.tree.leaves(se["center"])):
        np.testing.assert_array_equal(np.asarray(w)[0], np.asarray(c))


def test_asgd_is_the_alpha1_point():
    """asgd == easgd with alpha forced to 1 (same scaffolding, bitwise)."""
    cfg, model = _tiny_lm()
    mesh = _mesh1()
    opt = sgd_momentum(weight_decay=0.0)
    batches = _batches(cfg, 5)
    s1, l1 = _run_engine(TrainPlan(algo="asgd", tau=2), model, opt, 0.02,
                         batches, mesh)
    s2, l2 = _run_engine(TrainPlan(algo="easgd", tau=2, alpha=1.0), model,
                         opt, 0.02, batches, mesh)
    assert l1 == l2
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gspmd_zero1_parity_with_bsp_sharded_update():
    """The declarative (GSPMD) and explicit (RS->update->AG) ZeRO-1 paths
    compute the same training trajectory."""
    cfg, model = _tiny_lm(dtype="float32")
    mesh = _mesh1()
    opt = sgd_momentum(weight_decay=0.0)
    batches = _batches(cfg, 6)
    ss, ls = _run_engine(TrainPlan(algo="bsp", exchanger="asa",
                                   sharded_update=True), model, opt, 0.05,
                         batches, mesh)
    sg, lg = _run_engine(TrainPlan(algo="gspmd", mode="zero1"), model, opt,
                         0.05, batches, mesh)
    assert ls == pytest.approx(lg, rel=1e-5)
    for a, b in zip(jax.tree.leaves(ss["params"]),
                    jax.tree.leaves(sg["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# resumable runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plan", [
    TrainPlan(algo="bsp", sharded_update=True),
    TrainPlan(algo="easgd", tau=2),
    TrainPlan(algo="asgd", tau=3),
    TrainPlan(algo="gspmd"),
], ids=lambda p: p.algo + ("+sharded" if p.sharded_update else ""))
def test_resume_is_bitwise(plan, tmp_path):
    """save at step 4 -> resume -> identical to the uninterrupted 8-step
    run, for every algo (state, losses, step counter). Exercises the
    global-step rng fold, the batch skip, tau phase alignment, and the
    sharded opt-state placement on restore."""
    cfg, model = _tiny_lm()
    mesh = _mesh1()
    opt = sgd_momentum(weight_decay=0.0)
    batches = _batches(cfg, 8)
    kw = dict(num_steps=8, log_every=0, print_fn=lambda *_: None)
    ck = str(tmp_path / "ck")
    s_full, r_full = train(model, opt, constant(0.02), mesh, batches,
                           plan=plan, **kw)
    train(model, opt, constant(0.02), mesh, batches, plan=plan,
          num_steps=4, log_every=0, ckpt_path=ck, print_fn=lambda *_: None)
    s_res, r_res = train(model, opt, constant(0.02), mesh, batches,
                         plan=plan, resume_from=ck, **kw)
    assert r_res.steps == 8
    assert r_res.losses == r_full.losses[4:]
    for a, b in zip(jax.tree.leaves(s_full), jax.tree.leaves(s_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_layout_mismatch_fails_cleanly(tmp_path):
    """A checkpoint with no recorded algo (pre-engine) and a different
    state layout dies on the key check, not a cryptic KeyError."""
    from repro.checkpoint.ckpt import save_checkpoint
    cfg, model = _tiny_lm()
    mesh = _mesh1()
    opt = sgd_momentum(weight_decay=0.0)
    batches = _batches(cfg, 4)
    ck = str(tmp_path / "ck")
    state, _ = train(model, opt, constant(0.02), mesh, batches,
                     plan=TrainPlan(), num_steps=2, log_every=0,
                     print_fn=lambda *_: None)
    save_checkpoint(ck, state, step=2)   # no algo recorded
    with pytest.raises(ValueError, match="layout mismatch"):
        train(model, opt, constant(0.02), mesh, batches,
              plan=TrainPlan(algo="easgd"), num_steps=4, log_every=0,
              resume_from=ck, print_fn=lambda *_: None)


def test_resume_algo_mismatch_fails_cleanly(tmp_path):
    """bsp and gspmd checkpoints are layout-identical; the recorded algo
    meta is what refuses the cross-resume."""
    cfg, model = _tiny_lm()
    mesh = _mesh1()
    opt = sgd_momentum(weight_decay=0.0)
    batches = _batches(cfg, 4)
    ck = str(tmp_path / "ck")
    train(model, opt, constant(0.02), mesh, batches, plan=TrainPlan(),
          num_steps=2, log_every=0, ckpt_path=ck, print_fn=lambda *_: None)
    with pytest.raises(ValueError, match="algo mismatch"):
        train(model, opt, constant(0.02), mesh, batches,
              plan=TrainPlan(algo="gspmd"), num_steps=4, log_every=0,
              resume_from=ck, print_fn=lambda *_: None)


def test_resume_at_end_is_noop(tmp_path):
    cfg, model = _tiny_lm()
    mesh = _mesh1()
    opt = sgd_momentum(weight_decay=0.0)
    batches = _batches(cfg, 4)
    ck = str(tmp_path / "ck")
    train(model, opt, constant(0.02), mesh, batches, num_steps=4,
          log_every=0, ckpt_path=ck, print_fn=lambda *_: None)
    _, report = train(model, opt, constant(0.02), mesh, batches,
                      num_steps=4, log_every=0, resume_from=ck,
                      print_fn=lambda *_: None)
    assert report.steps == 4 and report.losses == []


# ---------------------------------------------------------------------------
# loop accounting (the satellite fixes)
# ---------------------------------------------------------------------------

def test_final_checkpoint_saved_once(tmp_path, monkeypatch):
    """ckpt_every dividing the last step used to save the same step twice
    (in-loop + final)."""
    import repro.train.loop as loop_mod
    calls = []
    monkeypatch.setattr(loop_mod, "save_checkpoint",
                        lambda path, state, step=None, algo=None, **kw:
                        calls.append(step))
    cfg, model = _tiny_lm()
    mesh = _mesh1()
    opt = sgd_momentum(weight_decay=0.0)
    train(model, opt, constant(0.02), mesh, _batches(cfg, 6),
          num_steps=6, log_every=0, ckpt_path=str(tmp_path / "ck"),
          ckpt_every=3, print_fn=lambda *_: None)
    assert calls == [3, 6]


def test_losses_flushed_at_log_boundaries():
    """device_losses is flushed to host floats in bounded windows; the
    report still carries one loss per step, in order."""
    cfg, model = _tiny_lm()
    mesh = _mesh1()
    opt = sgd_momentum(weight_decay=0.0)
    _, report = train(model, opt, constant(0.02), mesh, _batches(cfg, 7),
                      num_steps=7, log_every=2, print_fn=lambda *_: None)
    assert len(report.losses) == 7
    assert all(np.isfinite(l) for l in report.losses)


# ---------------------------------------------------------------------------
# 8-worker parity + fp16-wire center exchange (subprocess)
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, numpy as np
from repro.configs import get_smoke_config
from repro.data.synthetic import LMTokenSource
from repro.models import build_model
from repro.optim import constant, sgd_momentum
from repro.train.engine import TrainPlan, build_engine

cfg = get_smoke_config("llama3.2-1b").with_overrides(
    vocab_size=64, d_ff=128, num_layers=2, dtype="float32")
model = build_model(cfg)
mesh = jax.make_mesh((8,), ("data",))
jax.set_mesh(mesh)
src = LMTokenSource(cfg.vocab_size, 16, seed=0)
batches = [src.batch(32, i) for i in range(4)]
opt = sgd_momentum(weight_decay=0.0)

def run(plan, lr):
    eng = build_engine(plan, model, opt, constant(lr), mesh)
    st = eng.init_state(jax.random.key(0))
    losses = []
    for i, b in enumerate(batches):
        st, m = eng.step(st, b, jax.random.fold_in(jax.random.key(1), i),
                         step_idx=i)
        losses.append(float(m["loss"]))
    return st, losses

def maxerr(ta, tb):
    errs = []
    for a, b in zip(jax.tree.leaves(ta), jax.tree.leaves(tb)):
        a = np.asarray(a, np.float32); b = np.asarray(b, np.float32)
        errs.append(float(np.abs(a - b).max() / (np.abs(a).max() + 1e-9)))
    return max(errs)

out = {}
# EASGD(alpha=1, tau=1, lr=eta/k) == BSP(lr=eta) across 8 workers
sb, lb = run(TrainPlan(algo="bsp", exchanger="ar"), 0.16)
se, le = run(TrainPlan(algo="easgd", exchanger="ar", tau=1, alpha=1.0),
             0.16 / 8)
out["parity_err"] = maxerr(sb["params"], se["center"])
out["parity_loss_err"] = max(abs(a - b) for a, b in zip(lb, le))
# the fp16-wire center exchange (asa16) stays close to the fp32 one
s16, _ = run(TrainPlan(algo="easgd", exchanger="asa16", tau=1, alpha=1.0),
             0.16 / 8)
out["fp16_wire_err"] = maxerr(se["center"], s16["center"])
# asgd at tau=2: staleness-bounded async still trains
sa, la = run(TrainPlan(algo="asgd", exchanger="asa16", tau=2), 0.02)
out["asgd_losses"] = la
out["asgd_finite"] = bool(np.isfinite(
    np.asarray(jax.tree.leaves(sa["center"])[0], np.float32)).all())
print("RESULTS_JSON:" + json.dumps(out))
"""


def test_engine_multiworker_parity_and_wire():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON:"):
            out = json.loads(line[len("RESULTS_JSON:"):])
    assert out is not None, proc.stdout[-2000:]
    assert out["parity_err"] < 1e-4, out
    assert out["parity_loss_err"] < 1e-4, out
    assert out["fp16_wire_err"] < 5e-3, out
    assert out["asgd_finite"] and np.isfinite(out["asgd_losses"]).all(), out
