"""repro.telemetry: histogram/percentile math vs numpy, sink round-trips,
span nesting + Chrome-trace schema, the no-op fast path, analytic wire
accounting, and the on-vs-off parity contracts (serve outputs bit-identical,
compile-once guards hold with telemetry enabled)."""
import functools
import json

import jax
import numpy as np
import pytest

from repro import telemetry
from repro.telemetry import metrics, trace
from repro.telemetry.registry import (NOOP, Histogram, JsonlSink, MemorySink,
                                      Registry, exp_buckets)
from repro.telemetry.schema import (SCHEMA_VERSION, validate_metrics_jsonl,
                                    validate_record, validate_trace)


@pytest.fixture(autouse=True)
def _fresh_telemetry():
    """Each test gets a clean default registry, empty trace buffer, and the
    enabled switch restored afterwards."""
    was = telemetry.enabled()
    telemetry.reset()
    trace.reset()
    yield
    telemetry.set_enabled(was)
    telemetry.reset()
    trace.reset()


# ---------------------------------------------------------------------------
# histogram bucket math + percentiles vs numpy
# ---------------------------------------------------------------------------

def test_exp_buckets_cover_range():
    b = exp_buckets(1e-5, 100.0, 8)
    assert b[0] == pytest.approx(1e-5)
    assert b[-1] >= 100.0
    assert list(b) == sorted(b)
    # 8 per decade over 7 decades
    assert len(b) == 7 * 8 + 1


def test_histogram_bucket_assignment():
    h = Histogram("t", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 3.0, 100.0):
        h.observe(v)
    # bisect_right: buckets are left-closed, a boundary value starts the
    # bucket above it
    assert h.counts == [1, 2, 1, 1]
    assert h.count == 5 == sum(h.counts)
    assert h.sum == pytest.approx(106.0)
    assert h.min == 0.5 and h.max == 100.0
    assert h.mean == pytest.approx(106.0 / 5)


def test_histogram_percentiles_within_one_bucket_width():
    """The interpolated percentile must land within one bucket width of
    numpy's exact percentile, across distributions."""
    rng = np.random.RandomState(0)
    bounds = exp_buckets(1e-4, 10.0, 16)
    for dist in (rng.lognormal(-3, 1.0, 5000),
                 rng.uniform(1e-3, 1.0, 5000),
                 np.full(100, 0.01)):
        h = Histogram("t", buckets=bounds)
        for v in dist:
            h.observe(v)
        for q in (10, 50, 90, 99):
            exact = float(np.percentile(dist, q))
            got = h.percentile(q)
            i = int(np.searchsorted(bounds, exact))
            lo = bounds[i - 1] if i > 0 else 0.0
            hi = bounds[i] if i < len(bounds) else h.max
            width = hi - lo
            assert abs(got - exact) <= width + 1e-12, \
                f"p{q}: got {got}, exact {exact}, bucket width {width}"
            assert h.min <= got <= h.max


def test_histogram_percentile_edge_cases():
    h = Histogram("t", buckets=(1.0, 2.0))
    assert h.percentile(50) == 0.0                    # empty
    h.observe(1.5)
    assert h.percentile(0) == pytest.approx(1.5)      # single observation
    assert h.percentile(100) == pytest.approx(1.5)
    h2 = Histogram("t2", buckets=(1.0,))
    h2.observe(5.0)                                   # overflow bucket only
    assert h2.percentile(99) == pytest.approx(5.0)


def test_histogram_rejects_unsorted_bounds():
    with pytest.raises(ValueError):
        Histogram("t", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        exp_buckets(1.0, 0.5)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_registry_get_or_create_and_kind_check():
    r = Registry()
    c = r.counter("a/b")
    assert r.counter("a/b") is c
    c.inc(3)
    c.inc()
    assert c.value == 4
    with pytest.raises(TypeError):
        r.gauge("a/b")
    g = r.gauge("a/g")
    g.set(2.5)
    g.inc()
    assert g.value == 3.5
    r.info("a/i", strategy="asa", dtype="int8")
    assert r["a/i"].labels == {"strategy": "asa", "dtype": "int8"}
    assert "a/b" in r and "missing" not in r
    assert r.names() == ["a/b", "a/g", "a/i"]


def test_registry_snapshot_records_validate():
    r = Registry(label="x")
    r.counter("c").inc(7)
    r.histogram("h", buckets=(1.0,)).observe(0.5)
    for rec in r.snapshot():
        assert validate_record(rec) == []
        assert rec["schema_version"] == SCHEMA_VERSION
        assert rec["reg"] == "x"


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------

def test_memory_sink_round_trip():
    r = Registry()
    sink = MemorySink()
    r.add_sink(sink)
    r.counter("n").inc(2)
    r.flush()
    r.counter("n").inc(3)
    r.flush()
    assert [s[0]["value"] for s in sink.snapshots] == [2, 5]


def test_jsonl_sink_round_trip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    r = Registry()
    r.add_sink(JsonlSink(path))
    r.counter("train/steps").inc(10)
    r.gauge("train/loss").set(1.25)
    h = r.histogram("train/step_time_s", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    r.close()
    assert validate_metrics_jsonl(path) == []
    recs = [json.loads(l) for l in open(path)]
    assert recs[0]["kind"] == "run" and "backend" in recs[0]["run"]
    by_name = {r0["name"]: r0 for r0 in recs[1:]}
    assert by_name["train/steps"]["value"] == 10
    assert by_name["train/loss"]["value"] == 1.25
    hr = by_name["train/step_time_s"]
    assert hr["counts"] == [1, 1, 0] and hr["count"] == 2


def test_jsonl_sink_periodic_interval_skips_unforced(tmp_path):
    path = str(tmp_path / "m.jsonl")
    r = Registry()
    r.add_sink(JsonlSink(path, every_s=3600))
    r.counter("c").inc()
    r.flush(force=False)        # first emit: interval starts
    r.flush(force=False)        # within interval -> skipped
    r.flush(force=True)         # force always writes
    r.close()                   # close forces one more
    recs = [json.loads(l) for l in open(path)]
    assert sum(1 for x in recs if x["kind"] == "counter") == 3


def test_dump_metrics_includes_attached_registries(tmp_path):
    path = str(tmp_path / "m.jsonl")
    eng_reg = Registry(label="serve")
    eng_reg.counter("serve/decoded_tokens").inc(42)
    telemetry.attach_registry(eng_reg)
    metrics.counter("train/steps").inc(1)
    telemetry.dump_metrics(path)
    telemetry.detach_registry(eng_reg)
    assert validate_metrics_jsonl(path) == []
    names = {json.loads(l).get("name") for l in open(path)}
    assert {"serve/decoded_tokens", "train/steps"} <= names


# ---------------------------------------------------------------------------
# spans + chrome trace export
# ---------------------------------------------------------------------------

def test_span_nesting_and_export_schema(tmp_path):
    with trace.span("outer", step=1):
        with trace.span("inner"):
            pass
    trace.instant("marker", note="here")
    trace.async_begin("req", 7, prompt=3)
    trace.async_end("req", 7)
    evs = trace.events()
    assert [e[0] for e in evs] == ["X", "X", "i", "b", "e"]
    # inner closes first and must nest inside outer's [t0, t0+dur] window
    inner, outer = evs[0], evs[1]
    assert inner[1] == "inner" and outer[1] == "outer"
    assert outer[2] <= inner[2]
    assert inner[2] + inner[3] <= outer[2] + outer[3] + 1e-9

    path = str(tmp_path / "t.json")
    trace.export(path)
    assert validate_trace(path) == []
    obj = json.load(open(path))
    assert obj["otherData"]["schema_version"] == SCHEMA_VERSION
    assert obj["otherData"]["dropped_events"] == 0
    by_name = {e["name"]: e for e in obj["traceEvents"]}
    assert by_name["outer"]["args"] == {"step": 1}
    assert by_name["outer"]["dur"] >= by_name["inner"]["dur"]
    req_evs = [e for e in obj["traceEvents"] if e["name"] == "req"]
    assert [e["ph"] for e in req_evs] == ["b", "e"]
    assert all(e["id"] == 7 for e in req_evs)


def test_trace_buffer_bounded(monkeypatch):
    monkeypatch.setattr(trace, "MAX_EVENTS", 4)
    for _ in range(6):
        trace.instant("x")
    assert len(trace.events()) == 4
    assert trace.dropped() == 2
    trace.reset()
    assert trace.events() == [] and trace.dropped() == 0


# ---------------------------------------------------------------------------
# the no-op fast path
# ---------------------------------------------------------------------------

def test_disabled_accessors_share_noop_singleton():
    telemetry.set_enabled(False)
    assert metrics.counter("x") is NOOP
    assert metrics.gauge("x") is NOOP
    assert metrics.histogram("x") is NOOP
    assert metrics.info("x", a=1) is NOOP
    NOOP.inc()
    NOOP.set(3)
    NOOP.observe(1.0)
    assert NOOP.value == 0 and NOOP.percentile(50) == 0.0
    # nothing was created in the registry
    assert telemetry.default_registry().names() == []


def test_disabled_spans_record_nothing_and_allocate_nothing():
    telemetry.set_enabled(False)
    s1 = trace.span("a", big=list(range(10)))
    s2 = trace.span("b")
    assert s1 is s2                       # the shared no-op span object
    with s1:
        pass
    trace.instant("i")
    trace.async_begin("r", 1)
    trace.async_end("r", 1)
    assert trace.events() == []


def test_disabled_path_is_allocation_free():
    """The off path must not allocate per call (the <1% contract's
    mechanism): after warmup, a tracemalloc window around 1000 disabled
    record calls shows no growth attributable to telemetry."""
    import tracemalloc
    telemetry.set_enabled(False)

    def hot():
        for _ in range(1000):
            metrics.counter("k").inc()
            metrics.histogram("h").observe(0.1)
            with trace.span("s"):
                pass

    hot()                                 # warm caches/interned state
    tracemalloc.start()
    before = tracemalloc.take_snapshot()
    hot()
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    here = __file__.rstrip("co")
    grown = [d for d in after.compare_to(before, "lineno")
             if d.size_diff > 0 and any(
                 fr.filename.endswith(("registry.py", "metrics.py",
                                       "trace.py", "_runtime.py"))
                 or fr.filename == here
                 for fr in d.traceback)]
    # 3000 record calls: even 2 bytes/call would trip this — what passes
    # is O(1) interpreter noise (a few cached frames), not per-call growth
    assert sum(d.size_diff for d in grown) < 4096, \
        f"disabled telemetry allocated: {[str(d) for d in grown[:5]]}"


def test_enabled_switch_round_trip():
    telemetry.set_enabled(True)
    metrics.counter("on/c").inc(2)
    telemetry.set_enabled(False)
    metrics.counter("on/c").inc(5)        # no-op: different object
    telemetry.set_enabled(True)
    assert metrics.counter("on/c").value == 2


# ---------------------------------------------------------------------------
# analytic wire accounting (exchange/bytes_wire source)
# ---------------------------------------------------------------------------

def test_wire_summary_matches_hand_computation():
    import jax.numpy as jnp
    from repro.core.exchanger import get_exchanger, make_rs_plan, \
        wire_summary

    grads = {"w": jnp.zeros((1024,), jnp.float32),
             "b": jnp.zeros((4,), jnp.float32)}       # below min_leaf -> psum
    k = 4
    for strat, g_bytes in (("asa", 4), ("asa16", 2), ("asa8", 1)):
        ex = get_exchanger(strat)
        plan = make_rs_plan(grads, k, small_leaf=64)
        ws = wire_summary(ex, plan)
        b = plan.buckets[0]
        want_rs = (k - 1) * b.shard_len * g_bytes
        if g_bytes == 1:                               # int8 rows carry scales
            want_rs += (k - 1) * 4
        want_ag = (k - 1) * b.shard_len * g_bytes
        if g_bytes == 1:
            want_ag += (k - 1) * 4
        small = int(2 * (k - 1) / k * 4 * 4)
        assert ws["rs_bytes"] == want_rs, strat
        assert ws["ag_bytes"] == want_ag, strat
        assert ws["small_bytes"] == small
        assert ws["bytes_per_exchange"] == want_rs + want_ag + small
        assert ws["bytes_per_step"] == ws["bytes_per_exchange"]
        assert ws["k"] == k and ws["strategy"] == strat

    # ar: fused allreduce volume 2(k-1)/k at fp32, split rs/ag halves
    ex = get_exchanger("ar")
    plan = make_rs_plan(grads, k, small_leaf=64)
    ws = wire_summary(ex, plan)
    full = int(2 * (k - 1) / k * plan.buckets[0].padded * 4)
    assert ws["rs_bytes"] + ws["ag_bytes"] == pytest.approx(full, abs=2)

    # tau scales per-step traffic down, not per-exchange
    ws_tau = wire_summary(get_exchanger("asa"), plan, sync_every=4)
    assert ws_tau["bytes_per_step"] * 4 == ws_tau["bytes_per_exchange"]


def test_engine_exposes_wire_and_gspmd_does_not():
    from repro.optim import constant, sgd_momentum
    from repro.train.engine import TrainPlan, build_engine
    from tests.test_engine import _mesh1, _tiny_lm

    cfg, model = _tiny_lm()
    mesh = _mesh1()
    eng = build_engine(TrainPlan(algo="bsp", exchanger="asa16"), model,
                       sgd_momentum(), constant(0.01), mesh)
    assert eng.wire is not None
    assert eng.wire["strategy"] == "asa16"
    assert eng.wire["wire_dtype"] == "float16"
    assert eng.wire["k"] == 1
    # one worker: nothing moves on the wire (egress accounting is per-rank)
    assert eng.wire["bytes_per_step"] == 0
    assert len(eng.wire["per_bucket"]) == eng.wire["num_buckets"]
    if len(jax.devices()) >= 8:   # k>1 wire accounting needs a real 8-mesh
        mesh8 = jax.make_mesh((8,), ("data",))
        jax.set_mesh(mesh8)
        try:
            eng8 = build_engine(TrainPlan(algo="bsp", exchanger="asa16"),
                                model, sgd_momentum(), constant(0.01), mesh8)
            assert eng8.wire["k"] == 8
            assert eng8.wire["bytes_per_step"] > 0
        finally:
            jax.set_mesh(mesh)
    g = build_engine(TrainPlan(algo="gspmd"), model, sgd_momentum(),
                     constant(0.01), mesh)
    assert g.wire is None


# ---------------------------------------------------------------------------
# train loop integration: metrics recorded, first step split out
# ---------------------------------------------------------------------------

def test_train_loop_records_metrics_and_compile_split(capsys):
    from repro.optim import constant, sgd_momentum
    from repro.train.loop import train
    from tests.test_engine import _batches, _mesh1, _tiny_lm

    telemetry.set_enabled(True)
    cfg, model = _tiny_lm()
    mesh = _mesh1()
    n = 5
    _, report = train(model, sgd_momentum(), constant(0.01), mesh,
                      _batches(cfg, n), num_steps=n, log_every=2,
                      print_fn=lambda *a: None)
    assert report.steps == n
    assert report.compile_time > 0
    assert report.steady_examples_per_s > 0
    # steady-state rate excludes the compile step, so it beats the
    # total-wall-clock rate on a short run
    assert report.steady_examples_per_s > report.examples_per_s
    reg = telemetry.default_registry()
    assert reg["train/steps"].value == n
    assert reg["train/examples"].value == n * 8
    assert reg["train/tokens"].value == n * 8 * 32
    # the first (compile) step is excluded from the step-time histogram
    assert reg["train/step_time_s"].count == n - 1
    assert reg["train/data_time_s"].count == n
    assert reg["train/loss"].value == pytest.approx(report.losses[-1])
    # k=1 mesh: the analytic per-rank egress is zero, but the exchange
    # metrics/info are still published (nonzero-k math is pinned in
    # test_wire_summary_matches_hand_computation)
    assert reg["exchange/bytes_wire"].value == 0
    assert reg["exchange/config"].labels["strategy"] == "asa"
    assert reg["train/examples_per_s"].value > 0
    assert reg["train/model_flops_s"].value > 0
    assert reg["train/plan"].labels["algo"] == "bsp"
    # spans made it into the trace buffer (data/step per step + flushes)
    names = {e[1] for e in trace.events()}
    assert {"train/data", "train/step", "train/compile_block",
            "train/flush", "train/final_block"} <= names


def test_train_loop_telemetry_off_identical_losses():
    from repro.optim import constant, sgd_momentum
    from repro.train.loop import train
    from tests.test_engine import _batches, _mesh1, _tiny_lm

    cfg, model = _tiny_lm()
    mesh = _mesh1()

    def run():
        _, rep = train(model, sgd_momentum(), constant(0.01), mesh,
                       _batches(cfg, 3), num_steps=3, log_every=0,
                       print_fn=lambda *a: None)
        return rep.losses

    telemetry.set_enabled(True)
    on = run()
    telemetry.set_enabled(False)
    off = run()
    assert on == off
    assert trace.events() == [] or not telemetry.enabled()


# ---------------------------------------------------------------------------
# serve parity + compile-once with telemetry on
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _serve_setup():
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _serve_run():
    from repro.serve import Engine
    cfg, model, params = _serve_setup()
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (5, 12, 9, 17)]
    news = [6, 3, 9, 5]
    eng = Engine(model, params, max_slots=2, max_seq=64, prefill_chunk=8)
    rids = [eng.submit(p, m) for p, m in zip(prompts, news)]
    res = eng.run()
    return [res[r] for r in rids], eng


def test_serve_outputs_identical_telemetry_on_vs_off():
    """The whole point of host-side-only: enabling telemetry must not
    change a single generated token, and the decode step still compiles
    exactly once under churn."""
    telemetry.set_enabled(True)
    out_on, eng_on = _serve_run()
    assert eng_on.trace_counts["decode"] == 1
    assert eng_on.trace_counts["prefill"] == 1
    telemetry.set_enabled(False)
    out_off, eng_off = _serve_run()
    assert eng_off.trace_counts["decode"] == 1
    assert out_on == out_off


def test_serve_stats_live_with_telemetry_off():
    """EngineStats owns a private registry: TTFT/queue-wait/throughput must
    work with the global switch off (bench_serve depends on this)."""
    telemetry.set_enabled(False)
    outs, eng = _serve_run()
    st = eng.stats
    # each request's first token is sampled at prefill, the rest in decode
    assert st.decoded_tokens == sum(len(o) for o in outs) - len(outs)
    assert st.admissions == 4
    ttft = st.ttft_percentiles()
    qw = st.queue_wait_percentiles()
    assert ttft[99] >= ttft[50] > 0
    assert qw[99] >= qw[50] >= 0
    for st_slot in eng.sched.finished.values():
        assert st_slot.req.ttft >= st_slot.req.queue_wait >= 0


def test_serve_request_lifecycle_spans():
    telemetry.set_enabled(True)
    outs, eng = _serve_run()
    evs = trace.events()
    by_name = {}
    for ph, name, *_ in evs:
        by_name.setdefault(name, []).append(ph)
    # every admitted request opens and closes each lifecycle stage
    for stage in ("serve/req/queued", "serve/req/prefill",
                  "serve/req/decode"):
        assert by_name[stage].count("b") == 4, stage
        assert by_name[stage].count("e") == 4, stage
    assert "serve/prefill" in by_name and "serve/decode_step" in by_name
    # registry-side accounting agrees with the scheduler's view
    st = eng.stats
    reg = st.registry
    assert reg["serve/admissions"].value == 4
    assert reg["serve/evictions"].value == 4       # all requests finished
    assert reg["serve/decoded_tokens"].value == st.decoded_tokens
    assert reg["serve/ttft_s"].count == 4
