"""Model-internals correctness: SSD chunked vs naive recurrence, GQA vs
repeated-KV MHA reference, MLA decode==forward, decode==forward consistency,
sliding-window masks, Table-2 parameter parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.configs.base import AttentionConfig, SSMConfig
from repro.models import build_model
from repro.models.attention import (causal_window_mask, decode_keep,
                                    gqa_attend, gqa_forward, gqa_init_cache,
                                    gqa_decode, init_gqa)
from repro.models.ssm import ssd_chunked, ssd_naive


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(32, 8), (48, 16), (40, 16), (17, 8)])
def test_ssd_chunked_matches_naive(S, chunk):
    key = jax.random.key(S * chunk)
    b, h, p, g, n = 2, 4, 8, 1, 16
    x = jax.random.normal(key, (b, S, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, S, h)) - 1)
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, S, g, n))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, S, g, n))
    y1, _ = ssd_chunked(x, dt, A, B, C, chunk)
    y2, _ = ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_ssd_final_state_matches():
    key = jax.random.key(0)
    b, S, h, p, n = 1, 32, 2, 4, 8
    x = jax.random.normal(key, (b, S, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, S, h)))
    A = -jnp.exp(jnp.zeros((h,)))
    B = jax.random.normal(jax.random.fold_in(key, 2), (b, S, 1, n))
    C = jax.random.normal(jax.random.fold_in(key, 3), (b, S, 1, n))
    _, f1 = ssd_chunked(x, dt, A, B, C, 8)
    _, f2 = ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _mha_reference(q, k, v, keep):
    """Plain MHA with kv repeated to q heads."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    k = jnp.repeat(k, H // KV, axis=2)
    v = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bshk,bthk->bhst", q, k) / np.sqrt(hd)
    s = jnp.where(keep[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthk->bshk", w, v)


def test_gqa_attend_matches_repeated_kv_mha():
    key = jax.random.key(0)
    B, S, H, KV, hd = 2, 16, 8, 2, 32
    a = AttentionConfig(num_heads=H, num_kv_heads=KV, head_dim=hd)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    pos = jnp.arange(S)
    keep = causal_window_mask(pos, pos, 0)
    got = gqa_attend(q, k, v, keep, a)
    want = _mha_reference(q, k, v, keep)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_mask():
    pos = jnp.arange(6)
    m = causal_window_mask(pos, pos, 3)
    want = np.tril(np.ones((6, 6), bool)) & (
        (pos[:, None] - pos[None, :]) < 3).astype(bool)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(want))
    # traced window equals static window
    m2 = causal_window_mask(pos, pos, jnp.int32(3))
    np.testing.assert_array_equal(np.asarray(m), np.asarray(m2))
    # window 0 == plain causal, both static and traced
    np.testing.assert_array_equal(
        np.asarray(causal_window_mask(pos, pos, 0)),
        np.asarray(causal_window_mask(pos, pos, jnp.int32(0))))
    np.testing.assert_array_equal(np.asarray(decode_keep(pos, 4, 2)),
                                  np.asarray((pos <= 4) & (4 - pos < 2)))


@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("block", [16, 48])
def test_blockwise_attention_matches_naive(window, block):
    from repro.models.attention import gqa_attend_blockwise
    key = jax.random.key(0)
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    a = AttentionConfig(num_heads=H, num_kv_heads=KV, head_dim=hd)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    pos = jnp.arange(S)
    naive = gqa_attend(q, k, v, causal_window_mask(pos, pos, window), a)
    bw = gqa_attend_blockwise(q, k, v, pos, pos, window, a, block=block)
    np.testing.assert_allclose(np.asarray(bw), np.asarray(naive),
                               rtol=1e-5, atol=1e-5)


def test_gqa_decode_matches_forward():
    """Token-by-token decode reproduces the full forward pass."""
    key = jax.random.key(0)
    B, S, d = 2, 10, 64
    a = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16)

    class Cfg:
        d_model = d
    p = init_gqa(key, Cfg, a, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 5), (B, S, d)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = gqa_forward(p, x, pos, a, 0)

    cache = gqa_init_cache(B, S, a, jnp.float32)
    outs = []
    for t in range(S):
        y, cache = gqa_decode(p, cache, x[:, t:t + 1], jnp.int32(t), a, 0)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# end-to-end decode == forward (exercises caches incl. SSM recurrence & MLA)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b",
                                  "deepseek-v2-lite-16b", "qwen1.5-4b"])
def test_decode_consistency_with_forward(arch):
    import dataclasses
    # fp32 compute so decode/forward parity is tight (bf16 near-ties flip
    # argmax legitimately)
    cfg = get_smoke_config(arch).with_overrides(remat=False, dtype="float32")
    if cfg.moe is not None:
        # ample capacity: capacity-dropping is a prefill-only effect and
        # would (legitimately) break decode==forward parity
        cfg = cfg.with_overrides(
            moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 1, 12
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    full_logits = model.forward(params, batch)          # (B,S,V)

    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache,
                                      {"tokens": tokens[:, t:t + 1]},
                                      jnp.int32(t), seq_len=S)
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(full_logits, -1)),
        np.asarray(jnp.argmax(dec_logits, -1)))
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Table 2 parameter parity (the paper's own models)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,count", [("alexnet", 60_965_224),
                                        ("vggnet", 138_357_544),
                                        ("googlenet", 13_378_280)])
def test_paper_table2_param_counts(arch, count):
    cfg = get_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    assert n == count, f"{arch}: {n:,} != Table 2's {count:,}"


def test_moe_router_topk_and_aux():
    from repro.configs.base import MoEConfig
    from repro.models.moe import init_moe, moe_forward
    m = MoEConfig(num_experts=4, top_k=2, expert_dim=32, capacity_factor=2.0)
    p = init_moe(jax.random.key(0), 16, m, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, 16))
    y, aux = moe_forward(p, x, m)
    assert y.shape == x.shape
    assert jnp.isfinite(aux) and float(aux) >= 0
    # permutation of tokens only permutes outputs (capacity ample)
    perm = jax.random.permutation(jax.random.key(2), 8)
    y2, _ = moe_forward(p, x[:, perm], m)
    np.testing.assert_allclose(np.asarray(y[:, perm]), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
