"""Optimizers, LR schedules, data pipeline (Alg 1), and checkpointing."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
from repro.data.prefetch import ParallelLoader, SyncLoader, preprocess_images
from repro.data.synthetic import (ImageSource, LMTokenSource,
                                  materialize_batch_files)
from repro.kernels import ops
from repro.optim import (adamw, constant, poly_decay, sgd_momentum,
                         step_decay, warmup_cosine)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def test_sgd_momentum_hand_check():
    opt = sgd_momentum(momentum=0.5, weight_decay=0.0)
    params = {"w": jnp.array([[1.0, 2.0]])}
    st = opt.init(params)
    g = {"w": jnp.array([[0.5, -1.0]])}
    p1, st = opt.update(params, g, st, 0.1)
    np.testing.assert_allclose(np.asarray(p1["w"]), [[0.95, 2.1]], rtol=1e-6)
    p2, st = opt.update(p1, g, st, 0.1)
    # m = 0.5*0.5+0.5 = 0.75 ; p = 0.95 - 0.075
    np.testing.assert_allclose(np.asarray(p2["w"])[0, 0], 0.875, rtol=1e-6)


def test_sgd_fused_kernel_path_equivalence():
    params = {"w": jax.random.normal(jax.random.key(0), (64, 8))}
    g = {"w": jax.random.normal(jax.random.key(1), (64, 8))}
    o1 = sgd_momentum(momentum=0.9, weight_decay=0.0)
    o2 = sgd_momentum(momentum=0.9, weight_decay=0.0,
                      fused_kernel=ops.fused_sgd)
    p1, s1 = o1.update(params, g, o1.init(params), 0.05)
    p2, s2 = o2.update(params, g, o2.init(params), 0.05)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=2e-5, atol=1e-7)


def test_adamw_decreases_quadratic():
    opt = adamw(weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    st = opt.init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, st = opt.update(params, g, st, 0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_schedules():
    sd = step_decay(0.1, steps_per_drop=10)
    assert float(sd(jnp.int32(0))) == pytest.approx(0.1)
    assert float(sd(jnp.int32(10))) == pytest.approx(0.01)
    pd = poly_decay(0.1, 100, power=0.5)  # the paper's GoogLeNet policy
    assert float(pd(jnp.int32(0))) == pytest.approx(0.1)
    assert float(pd(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)
    vals = [float(pd(jnp.int32(s))) for s in range(0, 100, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))
    wc = warmup_cosine(0.1, 10, 100)
    assert float(wc(jnp.int32(0))) == pytest.approx(0.0)
    assert float(wc(jnp.int32(10))) == pytest.approx(0.1, rel=0.05)


# ---------------------------------------------------------------------------
# data pipeline (paper Alg 1)
# ---------------------------------------------------------------------------

def test_parallel_loader_matches_sync(tmp_path):
    src = ImageSource(32, 4, seed=1)
    files = materialize_batch_files(src, str(tmp_path), 6, batch_size=4)
    mean = np.zeros((32, 32, 3), np.float32)
    sync = list(SyncLoader(files, image_mean=mean, crop=28, seed=9))
    par = list(ParallelLoader(files, image_mean=mean, crop=28, seed=9))
    assert len(sync) == len(par) == 6
    for a, b in zip(sync, par):
        np.testing.assert_allclose(np.asarray(a["images"]),
                                   np.asarray(b["images"]))
        np.testing.assert_array_equal(np.asarray(a["labels"]),
                                      np.asarray(b["labels"]))


def test_parallel_loader_overlaps(tmp_path):
    """Alg 1's contract: loading runs ahead while the consumer computes."""
    src = ImageSource(16, 4)
    files = materialize_batch_files(src, str(tmp_path), 4, batch_size=2)
    loader = ParallelLoader(files, depth=2)
    time.sleep(0.5)  # give the thread time to prefetch depth batches
    t0 = time.perf_counter()
    b = loader.get()
    dt = time.perf_counter() - t0
    assert b is not None
    assert dt < 0.2, f"first get() blocked {dt:.3f}s — no prefetch happened"
    loader.stop()


def test_parallel_loader_stop_mid_stream(tmp_path):
    src = ImageSource(16, 4)
    files = materialize_batch_files(src, str(tmp_path), 50, batch_size=2)
    loader = ParallelLoader(files, depth=2)
    assert loader.get() is not None
    loader.stop()  # must not hang


def test_preprocess_crop_mirror_deterministic():
    rng1 = np.random.default_rng(3)
    rng2 = np.random.default_rng(3)
    batch = {"images": np.arange(2 * 16 * 16 * 3, dtype=np.float32)
             .reshape(2, 16, 16, 3)}
    mean = np.ones((16, 16, 3), np.float32)
    a = preprocess_images(batch, mean, 12, rng1)
    b = preprocess_images(batch, mean, 12, rng2)
    np.testing.assert_array_equal(a["images"], b["images"])
    assert a["images"].shape == (2, 12, 12, 3)


def test_lm_source_next_token_structure():
    src = LMTokenSource(100, 16, seed=0)
    b = src.batch(4, 0)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    # labels are the shifted sequence
    b2 = src.batch(4, 0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {
        "params": {"a": jnp.arange(6.0).reshape(2, 3),
                   "blocks": [{"w": jnp.ones((4,), jnp.bfloat16)},
                              {"w": jnp.zeros((4,), jnp.bfloat16)}]},
        "opt": {"m": {"a": jnp.full((2, 3), 0.5)}},
        "step": jnp.int32(17),
    }
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, state, step=17)
    like = jax.tree.map(jnp.zeros_like, state)
    restored = restore_checkpoint(path, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_mismatch_raises(tmp_path):
    state = {"params": {"a": jnp.zeros((2,))}}
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, state)
    with pytest.raises(ValueError):
        restore_checkpoint(path, {"params": {"b": jnp.zeros((2,))}})
