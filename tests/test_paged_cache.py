"""Paged KV cache + hashed prefix caching: allocator lifecycle (refcounts,
reservations, LRU eviction, OutOfPages), hash-collision safety, COW
isolation, paged-vs-contiguous greedy bit-identity across GQA and
absorbed-MLA layouts under request churn, compile-once with block tables,
the paged flash-decode kernel vs gathered-lane oracle, paged pool
shardings, and the SSM clean-lane regression for the O(d_state) admission
reset."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import Engine, SamplingParams
from repro.serve import cache as cache_mod
from repro.serve.cache import NULL_PAGE, OutOfPages, PageAllocator
from repro.train.serve import generate


@functools.lru_cache(maxsize=None)
def _setup(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _mixed_workload(cfg, n_req=4, seed=0):
    rng = np.random.RandomState(seed)
    lens = [5, 12, 9, 17, 7, 14][:n_req]
    news = [6, 3, 9, 5, 8, 4][:n_req]
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in lens]
    return prompts, news


# ---------------------------------------------------------------------------
# allocator: refcounts, reservations, eviction, exhaustion
# ---------------------------------------------------------------------------

def test_alloc_refcount_lifecycle():
    al = PageAllocator(num_pages=9, page_size=4, max_slots=2,
                       pages_per_slot=4)
    # admit a 6-token prompt + 3 new -> ceil(9/4) = 3 pages reserved
    assert al.try_admit(0, list(range(6)), 3) == 0      # no cache yet
    assert al._reserved[0] == 3
    assert al.available() == 8 - 3
    # first-touch allocation walks the reservation down
    assert al.ensure_writable(0, 0) == []
    assert al.ensure_writable(0, 4) == []
    assert al.tables[0, 0] != NULL_PAGE
    assert al._reserved[0] == 1
    assert al.ensure_writable(0, 2) == []     # same page: already private
    al.register_prefix(0, list(range(6)))     # publishes 1 full page
    pid = int(al.tables[0, 0])
    assert al.refs[pid] == 2                  # slot + cache
    al.release_slot(0)
    assert al.refs[pid] == 1                  # cache keeps it
    assert not al.tables[0].any() and al._reserved[0] == 0
    # the second allocated page went back to the free list
    assert al.allocated == 1


def test_alloc_admission_reserves_and_blocks():
    al = PageAllocator(num_pages=5, page_size=4, max_slots=2,
                       pages_per_slot=4, prefix_cache=False)
    assert al.try_admit(0, list(range(8)), 4) is not None   # 3 pages
    assert al.try_admit(1, list(range(8)), 4) is None       # 3 > 4-3
    # zero mutation on refusal
    assert not al.tables[1].any() and al._reserved[1] == 0
    al.release_slot(0)
    assert al.try_admit(1, list(range(8)), 4) is not None


def test_alloc_out_of_pages_is_guarded():
    al = PageAllocator(num_pages=2, page_size=4, max_slots=1,
                       pages_per_slot=2, prefix_cache=False)
    assert al.ensure_writable(0, 0) == []
    with pytest.raises(OutOfPages):
        al.ensure_writable(0, 4)


def test_alloc_lru_eviction_of_cache_pages():
    al = PageAllocator(num_pages=4, page_size=2, max_slots=1,
                       pages_per_slot=3)
    # request A: 4-token prompt -> 2 cached pages after release
    assert al.try_admit(0, [1, 2, 3, 4], 1) == 0
    al.ensure_writable(0, 0), al.ensure_writable(0, 2)
    al.register_prefix(0, [1, 2, 3, 4])
    al.release_slot(0)
    assert al.allocated == 2 and al._evictable() == 2
    # request B needs all 3 pages -> evicts the oldest cache pages
    assert al.try_admit(0, [9, 8, 7, 6], 2) == 0
    al.ensure_writable(0, 0), al.ensure_writable(0, 2)
    al.ensure_writable(0, 4)
    assert al.evictions >= 1
    al.release_slot(0)


def test_prefix_hit_and_full_hit_accounting():
    al = PageAllocator(num_pages=8, page_size=2, max_slots=2,
                       pages_per_slot=3)
    toks = [5, 6, 7, 8]
    assert al.try_admit(0, toks, 2) == 0
    al.ensure_writable(0, 0), al.ensure_writable(0, 2)
    al.register_prefix(0, toks)
    # partial hit: same 2-page head, longer tail
    got = al.try_admit(1, toks + [9, 9], 1)
    assert got == 4
    assert al.tables[1, 0] == al.tables[0, 0]
    assert al.tables[1, 1] == al.tables[0, 1]
    al.release_slot(1)
    al.release_slot(0)
    # full hit: entire prompt cached -> re-run 1 token, need = +1 COW page
    got = al.try_admit(0, toks, 2)
    assert got == 4
    assert al._reserved[0] == 2               # 1 decode page + 1 COW


def test_hash_collision_is_miss_not_corruption(monkeypatch):
    al = PageAllocator(num_pages=8, page_size=2, max_slots=2,
                       pages_per_slot=3)
    monkeypatch.setattr(cache_mod, "hash_prefix_chunk",
                        lambda prev, tokens: b"same-digest")
    assert al.try_admit(0, [1, 2], 1) == 0
    al.ensure_writable(0, 0)
    al.register_prefix(0, [1, 2])
    # different tokens, same digest: token verification rejects the entry
    assert al.try_admit(1, [3, 4], 1) == 0
    assert al.collisions == 1
    # identical tokens still hit through the colliding digest
    al.release_slot(1)
    assert al.try_admit(1, [1, 2], 1) == 2


def test_release_refcounts_under_shared_pages():
    """Two slots sharing hit pages + the cache ref: releases in any order
    never underflow and the cache copy survives for the next hit."""
    al = PageAllocator(num_pages=10, page_size=2, max_slots=3,
                       pages_per_slot=3)
    toks = [4, 4, 4, 4]
    al.try_admit(0, toks, 2)
    al.ensure_writable(0, 0), al.ensure_writable(0, 2)
    al.register_prefix(0, toks)
    assert al.try_admit(1, toks + [1, 1], 1) == 4
    assert al.try_admit(2, toks + [2, 2], 1) == 4
    pid = int(al.tables[0, 0])
    assert al.refs[pid] == 4                  # cache + 3 slots
    al.release_slot(0)
    al.release_slot(2)
    al.release_slot(1)
    assert al.refs[pid] == 1
    assert al.try_admit(0, toks, 2) == 4      # still serves hits


# ---------------------------------------------------------------------------
# engine: paged vs contiguous bit-identity under churn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v2-lite-16b"])
def test_paged_engine_bit_identical_to_contiguous(arch):
    """Greedy tokens from the paged engine (default) match the contiguous
    oracle engine AND generate(), under slot churn, for GQA and absorbed
    MLA — and the paged decode step compiles exactly once."""
    cfg, model, params = _setup(arch)
    prompts, news = _mixed_workload(cfg)
    eng_p = Engine(model, params, max_slots=3, max_seq=64,
                   prefill_chunk=16, page_size=8)
    eng_c = Engine(model, params, max_slots=3, max_seq=64,
                   prefill_chunk=16, page_size=0)
    assert eng_p.paged and not eng_c.paged
    rp = [eng_p.submit(p, m) for p, m in zip(prompts, news)]
    rc = [eng_c.submit(p, m) for p, m in zip(prompts, news)]
    res_p, res_c = eng_p.run(), eng_c.run()
    for a, b, p, m in zip(rp, rc, prompts, news):
        assert res_p[a] == res_c[b], f"{arch}: paged != contiguous"
        want = generate(model, params, jnp.asarray([p], jnp.int32),
                        max_new=m, seq_len=len(p) + m)
        assert res_p[a] == np.asarray(want)[0, len(p):].tolist(), \
            f"{arch}: paged engine diverged from generate()"
    assert eng_p.trace_counts["decode"] == 1
    assert eng_p.trace_counts["prefill"] == 1


def test_prefix_hit_skips_prefill_and_stays_bit_identical():
    """Warm requests reuse cached pages: fewer prefill tokens computed,
    same greedy tokens, and the diverging-tail request COWs instead of
    mutating the shared pages (the repeated request still hits after)."""
    cfg, model, params = _setup("llama3.2-1b")
    rng = np.random.RandomState(3)
    head = rng.randint(0, cfg.vocab_size, size=16).tolist()
    tail = rng.randint(0, cfg.vocab_size, size=5).tolist()
    eng = Engine(model, params, max_slots=2, max_seq=64, prefill_chunk=8,
                 page_size=8)
    oracle = {}
    for p, m in [(head, 6), (head + tail, 6), (head, 6)]:
        want = generate(model, params, jnp.asarray([p], jnp.int32),
                        max_new=m, seq_len=len(p) + m)
        oracle[tuple(p)] = np.asarray(want)[0, len(p):].tolist()

    r0 = eng.submit(head, 6)
    eng.run()
    cold_prefill = eng.stats.prefill_tokens
    assert eng.allocator.hit_tokens == 0
    res = eng.run() or eng.sched.results()
    assert res[r0] == oracle[tuple(head)]

    # warm: same head + diverging tail -> 2 pages hit, tail computed
    r1 = eng.submit(head + tail, 6)
    eng.run()
    assert eng.allocator.hit_tokens == 16
    res = eng.sched.results()
    assert res[r1] == oracle[tuple(head + tail)]

    # the full-hit repeat: only the last prompt token re-runs (for its
    # logits), through a COW copy — cached pages were never mutated by r1
    r2 = eng.submit(head, 6)
    eng.run()
    assert eng.allocator.hit_tokens == 32
    assert eng.allocator.cow_copies >= 1
    res = eng.sched.results()
    assert res[r2] == oracle[tuple(head)]
    warm_prefill = eng.stats.prefill_tokens - cold_prefill
    assert warm_prefill == len(tail) + 1      # tail chunk-rounded? no: 5+1
    assert eng.trace_counts["decode"] == 1


def test_cow_isolation_under_concurrent_divergence():
    """Two live requests sharing a cached head and diverging mid-page must
    not see each other's tails (COW splits the shared page)."""
    cfg, model, params = _setup("llama3.2-1b")
    rng = np.random.RandomState(11)
    head = rng.randint(0, cfg.vocab_size, size=8).tolist()   # 1 full page
    t1 = rng.randint(0, cfg.vocab_size, size=3).tolist()
    t2 = rng.randint(0, cfg.vocab_size, size=3).tolist()
    eng = Engine(model, params, max_slots=2, max_seq=64, prefill_chunk=8,
                 page_size=8)
    # publish the head
    eng.submit(head, 2)
    eng.run()
    # both tails decode concurrently from the shared head pages
    ra = eng.submit(head + t1, 8)
    rb = eng.submit(head + t2, 8)
    res = eng.run()
    for p, r in [(head + t1, ra), (head + t2, rb)]:
        want = generate(model, params, jnp.asarray([p], jnp.int32),
                        max_new=8, seq_len=len(p) + 8)
        assert res[r] == np.asarray(want)[0, len(p):].tolist()


def test_tiny_page_pool_head_of_line_completes():
    """A page pool far smaller than worst case still serves the whole
    queue: head-of-line admission waits for releases instead of
    deadlocking, and results stay bit-identical to the oracle."""
    cfg, model, params = _setup("llama3.2-1b")
    prompts, news = _mixed_workload(cfg)
    # worst case would want 3 slots * 64 rows = 24 pages; give 9 usable
    eng = Engine(model, params, max_slots=3, max_seq=64, prefill_chunk=16,
                 page_size=8, num_pages=10)
    rids = [eng.submit(p, m) for p, m in zip(prompts, news)]
    res = eng.run()
    for rid, p, m in zip(rids, prompts, news):
        want = generate(model, params, jnp.asarray([p], jnp.int32),
                        max_new=m, seq_len=len(p) + m)
        assert res[rid] == np.asarray(want)[0, len(p):].tolist()
    assert eng.trace_counts["decode"] == 1


def test_submit_rejects_request_larger_than_page_pool():
    cfg, model, params = _setup("llama3.2-1b")
    eng = Engine(model, params, max_slots=2, max_seq=64, prefill_chunk=16,
                 page_size=8, num_pages=4)
    with pytest.raises(ValueError, match="pages"):
        eng.submit(list(range(30)), 10)


def test_ssm_engine_falls_back_to_slot_granular():
    """Pure-SSM families have nothing to page: the engine runs the
    contiguous pool, parity with generate() holds, and a reused slot
    starts from clean conv/state lanes (the O(d_state) admission reset)."""
    cfg, model, params = _setup("mamba2-1.3b")
    eng = Engine(model, params, max_slots=1, max_seq=64, prefill_chunk=16,
                 page_size=16)
    assert not eng.paged and eng.allocator is None
    prompts, news = _mixed_workload(cfg, n_req=3)
    # serial through one slot: each request inherits the previous
    # occupant's lane and must still match the clean-pool oracle
    rids = [eng.submit(p, m) for p, m in zip(prompts, news)]
    res = eng.run()
    for rid, p, m in zip(rids, prompts, news):
        want = generate(model, params, jnp.asarray([p], jnp.int32),
                        max_new=m, seq_len=len(p) + m)
        assert res[rid] == np.asarray(want)[0, len(p):].tolist()


def test_hybrid_paged_attn_with_ssm_lanes():
    """Hybrid families page their attention leaves while SSM lanes stay
    slot-granular; the prefix cache is disabled (SSM state is not
    reconstructible from pages) and parity still holds under churn."""
    cfg, model, params = _setup("hymba-1.5b")
    eng = Engine(model, params, max_slots=2, max_seq=64, prefill_chunk=16,
                 page_size=8)
    if not eng.paged:
        pytest.skip("family has no attention leaves")
    assert not eng.allocator.prefix_cache
    prompts, news = _mixed_workload(cfg, n_req=3)
    rids = [eng.submit(p, m) for p, m in zip(prompts, news)]
    res = eng.run()
    for rid, p, m in zip(rids, prompts, news):
        want = generate(model, params, jnp.asarray([p], jnp.int32),
                        max_new=m, seq_len=len(p) + m)
        assert res[rid] == np.asarray(want)[0, len(p):].tolist()


# ---------------------------------------------------------------------------
# kernel: paged flash decode vs gathered-lane flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 7])
def test_flash_decode_paged_matches_contiguous(window):
    from repro.kernels.flash_attention import flash_decode, flash_decode_paged
    B, H, KV, Dk, Dv, ps, npg = 2, 4, 2, 16, 16, 8, 7
    key = jax.random.key(0)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, 1, H, Dk))
    k_pages = jax.random.normal(jax.random.fold_in(key, 2),
                                (npg, ps, KV, Dk))
    v_pages = jax.random.normal(jax.random.fold_in(key, 3),
                                (npg, ps, KV, Dv))
    tables = jnp.asarray([[1, 3, 5], [2, 4, 6]], jnp.int32)
    pos = jnp.asarray([13, 20], jnp.int32)
    got = flash_decode_paged(q, k_pages, v_pages, tables, pos,
                             page_size=ps, window=window, interpret=True)
    # oracle: gather each slot's lane contiguously, run the 1D kernel
    lanes_k = k_pages[tables].reshape(B, -1, KV, Dk)
    lanes_v = v_pages[tables].reshape(B, -1, KV, Dv)
    want = flash_decode(q, lanes_k, lanes_v, pos, window=window,
                        block_k=ps, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# placement + pool structure
# ---------------------------------------------------------------------------

def test_paged_pool_shardings_put_pages_on_data():
    """The page dim of a paged pool shards over the data axes exactly like
    the slot dim of a contiguous pool (pages are the unit of cache
    parallelism); structure check on a 1-device mesh."""
    import numpy as onp
    from jax.sharding import Mesh

    cfg, model, params = _setup("llama3.2-1b")
    pool = model.init_paged_cache(3, 8, 16)   # slots=3, ps=8, pages=16
    mesh = Mesh(onp.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = cache_mod.pool_shardings(mesh, pool, 3, num_pages=16)
    for (path, leaf), (_, s) in zip(
            jax.tree_util.tree_leaves_with_path(pool),
            jax.tree_util.tree_leaves_with_path(sh)):
        if cache_mod.is_paged_leaf(path):
            assert leaf.shape[1] == 16 and leaf.shape[2] == 8
            assert s.spec[1] == "data", f"page dim unsharded: {s.spec}"
        else:
            assert leaf.shape[1] == 3     # ssm lanes keep the slot dim


def test_reset_slot_ssm_zeroes_only_ssm_lanes():
    cfg, model, params = _setup("llama3.2-1b")
    pool = model.init_paged_cache(2, 8, 6)
    pool = jax.tree.map(lambda v: jnp.ones_like(v), pool)
    out = cache_mod.reset_slot_ssm(pool, jnp.int32(0))
    for path, leaf in jax.tree_util.tree_leaves_with_path(out):
        assert bool(jnp.all(leaf == 1.0))   # attn-only family: untouched


def test_copy_page_copies_all_layers_of_paged_leaves():
    cfg, model, params = _setup("llama3.2-1b")
    pool = model.init_paged_cache(2, 4, 6)
    pool = jax.tree_util.tree_map_with_path(
        lambda p, v: v.at[:, 3].set(7.0) if cache_mod.is_paged_leaf(p)
        else v, pool)
    out = cache_mod.copy_page(pool, jnp.int32(1), jnp.int32(3))
    for path, leaf in jax.tree_util.tree_leaves_with_path(out):
        if cache_mod.is_paged_leaf(path):
            assert bool(jnp.all(leaf[:, 1] == 7.0))
            assert bool(jnp.all(leaf[:, 2] == 0.0))
