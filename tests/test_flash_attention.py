"""Flash-attention parity suite: Pallas fwd/bwd kernels (interpret mode on
CPU) vs the einsum oracles across causal/sliding windows, GQA group sizes
(incl. group=1 MHA and ragged S), decode vs prefill vs train forward, the
MLA absorbed layout, end-to-end decoder_loss gradients, and the
no-(S,S)-materialization guarantees."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.configs.registry import ASSIGNED_ARCHS
from repro.configs.base import AttentionConfig
from repro.kernels.flash_attention import flash_attention, flash_decode
from repro.models import attention as attn_mod
from repro.models import build_model
from repro.models.attention import (causal_window_mask, gqa_attend,
                                    gqa_attend_blockwise, gqa_decode,
                                    gqa_forward, gqa_init_cache, gqa_prefill,
                                    init_gqa, init_mla, mla_forward,
                                    resolve_attn_impl)


def oracle(q, k, v, q_off, window, sm_scale):
    """Dense fp32 reference with explicit GQA grouping, Dk != Dv support,
    absolute q positions and windowing — the flash kernel contract."""
    B, Sq, H, Dk = q.shape
    KV, Sk = k.shape[2], k.shape[1]
    G = H // KV
    qg = q.astype(jnp.float32).reshape(B, Sq, KV, G, Dk)
    s = jnp.einsum("bskgd,btkd->bkgst", qg,
                   k.astype(jnp.float32)) * sm_scale
    qpos = q_off[:, None] + jnp.arange(Sq)[None]
    kpos = jnp.arange(Sk)
    keep = kpos[None, None] <= qpos[..., None]
    if window > 0:
        keep &= (qpos[..., None] - kpos[None, None]) < window
    s = jnp.where(keep[:, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1])


def _qkv(key, B, Sq, Sk, H, KV, Dk, Dv, dtype=jnp.float32):
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, H, Dk), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, Sk, KV, Dk), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, Sk, KV, Dv), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 7, 16])
@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("S", [32, 50])   # 50: ragged, not a tile multiple
def test_flash_fwd_matches_oracle(window, H, KV, S):
    q, k, v = _qkv(jax.random.key(window * 100 + H * 10 + S), 2, S, S, H,
                   KV, 16, 16)
    got = flash_attention(q, k, v, window=window, block_q=16, block_k=16,
                          interpret=True)
    want = oracle(q, k, v, jnp.zeros((2,), jnp.int32), window,
                  1.0 / np.sqrt(16))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_fwd_bf16_io_fp32_accumulators():
    q, k, v = _qkv(jax.random.key(0), 1, 48, 48, 4, 2, 32, 32)
    want = oracle(q, k, v, jnp.zeros((1,), jnp.int32), 0, 1 / np.sqrt(32))
    got = flash_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                          v.astype(jnp.bfloat16), block_q=16, block_k=16,
                          interpret=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=0.05, atol=0.05)


def test_flash_fwd_traced_window_matches_static():
    q, k, v = _qkv(jax.random.key(1), 1, 32, 32, 4, 2, 16, 16)
    f = jax.jit(lambda w: flash_attention(q, k, v, window=w, block_q=8,
                                          block_k=8, interpret=True))
    static = flash_attention(q, k, v, window=7, block_q=8, block_k=8,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(f(jnp.int32(7))),
                                  np.asarray(static))


def test_flash_lse_residual_is_logsumexp():
    B, S, H, KV, D = 1, 32, 4, 2, 16
    q, k, v = _qkv(jax.random.key(2), B, S, S, H, KV, D, D)
    _, lse = flash_attention(q, k, v, block_q=8, block_k=8, interpret=True,
                             return_lse=True)
    qg = q.reshape(B, S, KV, H // KV, D)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k) / np.sqrt(D)
    keep = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]
    s = jnp.where(keep[None, None, None], s, -1e30)
    want = jax.scipy.special.logsumexp(s, axis=-1).transpose(0, 3, 1, 2)
    np.testing.assert_allclose(np.asarray(lse),
                               np.asarray(want.reshape(B, S, H)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# backward (custom VJP)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 9])
@pytest.mark.parametrize("H,KV,S", [(4, 2, 24), (4, 4, 30), (4, 1, 24)])
def test_flash_bwd_matches_oracle_grads(window, H, KV, S):
    key = jax.random.key(window + H + S)
    q, k, v = _qkv(key, 1, S, S, H, KV, 16, 16)
    cot = jax.random.normal(jax.random.fold_in(key, 4), (1, S, H, 16))
    qo = jnp.zeros((1,), jnp.int32)

    def f_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, window=window, block_q=8,
                                       block_k=8, interpret=True) * cot)

    def f_ref(q, k, v):
        return jnp.sum(oracle(q, k, v, qo, window, 1 / np.sqrt(16)) * cot)

    got = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip(("dq", "dk", "dv"), got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5, err_msg=name)


def test_flash_bwd_bf16_io_matches_fp32_oracle():
    """The production train dtype: bf16 q/k/v/dout through the custom VJP
    must track the fp32 oracle gradients within bf16 tolerance (the
    kernels' fp32 accumulators and lse-based recompute do the work)."""
    key = jax.random.key(13)
    S, H, KV = 32, 4, 2
    q, k, v = _qkv(key, 1, S, S, H, KV, 16, 16)
    cot = jax.random.normal(jax.random.fold_in(key, 4), (1, S, H, 16))
    for window in (0, 9):
        def f_flash(q, k, v, _w=window):
            return jnp.sum(flash_attention(
                q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                v.astype(jnp.bfloat16), window=_w, block_q=8, block_k=8,
                interpret=True).astype(jnp.float32) * cot)

        def f_ref(q, k, v, _w=window):
            return jnp.sum(oracle(q, k, v, jnp.zeros((1,), jnp.int32), _w,
                                  1 / np.sqrt(16)) * cot)

        got = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
        want = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for name, a, b in zip(("dq", "dk", "dv"), got, want):
            assert a.dtype == jnp.float32      # cast-of-bf16-input grads
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0.1, atol=0.1, err_msg=name)


def test_flash_bwd_under_remat_and_scan():
    """The train path wraps attention in jax.checkpoint inside lax.scan."""
    q, k, v = _qkv(jax.random.key(5), 1, 16, 16, 4, 2, 16, 16)

    def layer(x, _):
        return x + flash_attention(x, k, v, block_q=8, block_k=8,
                                   interpret=True), None

    def loss(x):
        y, _ = jax.lax.scan(jax.checkpoint(layer), x, jnp.arange(2))
        return jnp.sum(y ** 2)

    g = jax.jit(jax.grad(loss))(q)
    assert bool(jnp.all(jnp.isfinite(g)))


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def test_flash_prefill_chunk_against_cache():
    """q-chunk x full-cache tiles: rows at q_off, garbage cache rows beyond
    the causal horizon must not leak into the output."""
    key = jax.random.key(6)
    B, C, S, H, KV, D = 2, 8, 40, 4, 2, 16
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, C, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, D)) * 5
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, S, KV, D)) * 5
    q_off = jnp.asarray([5, 11], jnp.int32)
    for window in (0, 6):
        got = flash_attention(q, k, v, q_off=q_off, window=window,
                              block_q=8, block_k=8, interpret=True)
        want = oracle(q, k, v, q_off, window, 1 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [0, 5])
@pytest.mark.parametrize("block_k", [16, 64])   # 64 > S: single split
def test_flash_decode_split_kv(window, block_k):
    key = jax.random.key(7)
    B, S, H, KV, D = 3, 40, 4, 2, 16
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, 1, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, S, KV, D))
    pos = jnp.asarray([0, 17, 39], jnp.int32)    # incl. the first token
    got = flash_decode(q, k, v, pos, window=window, block_k=block_k,
                       interpret=True)
    want = oracle(q, k, v, pos, window, 1 / np.sqrt(D))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# module-level dispatch parity (train / prefill / decode / MLA)
# ---------------------------------------------------------------------------

class _Cfg:
    d_model = 64


@pytest.mark.parametrize("window", [0, 5])
def test_gqa_paths_flash_vs_ref(window):
    key = jax.random.key(8)
    a = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16)
    p = init_gqa(key, _Cfg, a, jnp.float32)
    B, S = 2, 20
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, 64)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o_ref = gqa_forward(p, x, pos, a, window, impl="ref")
    o_fl = gqa_forward(p, x, pos, a, window, impl="flash")
    np.testing.assert_allclose(np.asarray(o_fl), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)

    caches = [gqa_init_cache(B, S, a, jnp.float32) for _ in range(2)]
    posm = jnp.broadcast_to(jnp.arange(8)[None], (B, 8))
    y_ref, c_ref = gqa_prefill(p, caches[0], x[:, :8], posm, 0, a, window,
                               impl="ref")
    y_fl, c_fl = gqa_prefill(p, caches[1], x[:, :8], posm, 0, a, window,
                             impl="flash")
    np.testing.assert_allclose(np.asarray(y_fl), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    pvec = jnp.asarray([3, 7], jnp.int32)        # per-slot positions
    d_ref, _ = gqa_decode(p, c_ref, x[:, 8:9], pvec, a, window, impl="ref")
    d_fl, _ = gqa_decode(p, c_fl, x[:, 8:9], pvec, a, window, impl="flash")
    np.testing.assert_allclose(np.asarray(d_fl), np.asarray(d_ref),
                               rtol=1e-5, atol=1e-5)


def _mla_cfg():
    return AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=32,
                           kv_lora_rank=64, qk_rope_dim=16, qk_nope_dim=16,
                           v_head_dim=32)


@pytest.mark.parametrize("window", [0, 7])
def test_mla_forward_flash_absorbed_vs_naive(window):
    key = jax.random.key(9)
    a = _mla_cfg()
    p = init_mla(key, _Cfg, a, jnp.float32)
    B, S = 2, 20
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, 64)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o_ref = mla_forward(p, x, pos, a, window, impl="ref")
    o_fl = mla_forward(p, x, pos, a, window, impl="flash")
    np.testing.assert_allclose(np.asarray(o_fl), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_mla_long_seq_routes_through_blockwise():
    """Satellite fix: with block_kv set the non-kernel MLA fallback must go
    through the shared blockwise scan (absorbed layout, Dv != Dk) instead
    of building the dense (B,H,S,S) matrix — and still match it."""
    key = jax.random.key(10)
    a = dataclasses.replace(_mla_cfg(), block_kv=8)
    p = init_mla(key, _Cfg, a, jnp.float32)
    B, S = 1, 24                                  # S > block_kv
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, 64)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    dense = mla_forward(p, x, pos, dataclasses.replace(a, block_kv=0),
                        0, impl="ref")
    routed = mla_forward(p, x, pos, a, 0, impl="ref")
    forced = mla_forward(p, x, pos, a, 0, impl="blockwise")
    np.testing.assert_allclose(np.asarray(routed), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(routed), np.asarray(forced))
    # and the routed jaxpr carries no (S, S) score tensor
    jpr = jax.make_jaxpr(
        lambda x: mla_forward(p, x, pos, a, 0, impl="ref"))(x)
    assert not _sxs_vars(jpr, S), "blockwise MLA still builds (S,S) scores"


def test_blockwise_generalized_dv_and_scale():
    """gqa_attend_blockwise with v dim != qk dim + explicit scale (the MLA
    absorbed layout) against the dense oracle."""
    q, k, v = _qkv(jax.random.key(11), 2, 30, 30, 4, 1, 24, 8)
    pos = jnp.arange(30)
    a = AttentionConfig(num_heads=4, num_kv_heads=1, head_dim=24)
    got = gqa_attend_blockwise(q, k, v, pos, pos, 0, a, block=8,
                               scale=jnp.float32(0.37))
    want = oracle(q, k, v, jnp.zeros((2,), jnp.int32), 0, 0.37)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end: decoder_loss gradients and the serve engine
# ---------------------------------------------------------------------------

def _with_impl(cfg, impl):
    from repro.configs.base import with_attn_impl
    return with_attn_impl(cfg, impl)


# every attention-bearing decoder config in the registry (GQA with/without
# bias + qk-norm, MLA, MoE routing over attention outputs, hybrid
# attn-parallel-SSM with sliding/global windows)
_ATTN_ARCHS = [a for a in ASSIGNED_ARCHS
               if get_config(a).family == "decoder"
               and get_config(a).attention is not None]


@pytest.mark.parametrize("arch", _ATTN_ARCHS)
def test_decoder_loss_grads_flash_vs_ref(arch):
    cfg0 = get_smoke_config(arch).with_overrides(remat=False,
                                                 dtype="float32")
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                cfg0.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    out = {}
    for impl in ("ref", "flash"):
        model = build_model(_with_impl(cfg0, impl))
        params = model.init(jax.random.key(0))
        loss = float(model.loss_fn(params, batch)[0])
        grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
        out[impl] = (loss, grads)
    assert abs(out["ref"][0] - out["flash"][0]) < 1e-4
    for a, b in zip(jax.tree.leaves(out["ref"][1]),
                    jax.tree.leaves(out["flash"][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_encdec_decoder_self_attn_flash_vs_ref():
    """The enc-dec decoder's causal self-attention also routes through
    gqa_forward — its loss/grads must match across implementations too
    (the registry sweep above covers only the decoder family)."""
    cfg0 = get_smoke_config("seamless-m4t-large-v2").with_overrides(
        remat=False, dtype="float32")
    key = jax.random.key(3)
    tokens = jax.random.randint(key, (1, 10), 0, cfg0.vocab_size)
    frames = jax.random.normal(jax.random.fold_in(key, 1),
                               (1, cfg0.encoder_seq_len, cfg0.d_model))
    batch = {"tokens": tokens, "labels": tokens, "frames": frames}
    out = {}
    for impl in ("ref", "flash"):
        model = build_model(_with_impl(cfg0, impl))
        params = model.init(jax.random.key(0))
        out[impl] = (float(model.loss_fn(params, batch)[0]),
                     jax.grad(lambda p: model.loss_fn(p, batch)[0])(params))
    assert abs(out["ref"][0] - out["flash"][0]) < 1e-4
    for a, b in zip(jax.tree.leaves(out["ref"][1]),
                    jax.tree.leaves(out["flash"][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_flash_lse_is_non_differentiable_by_contract():
    """lse is a residual: its gradient is zero *by stop_gradient* (the
    VJP discards the lse cotangent, so without the stop the zeros would
    be an undocumented accident), while the out gradient stays intact."""
    q, k, v = _qkv(jax.random.key(14), 1, 16, 16, 4, 2, 16, 16)

    def both(q):
        out, lse = flash_attention(q, k, v, block_q=8, block_k=8,
                                   interpret=True, return_lse=True)
        return out, lse

    g_lse = jax.grad(lambda q: both(q)[1].sum())(q)
    np.testing.assert_array_equal(np.asarray(g_lse), 0.0)
    g_out = jax.grad(lambda q: both(q)[0].sum())(q)
    assert float(jnp.max(jnp.abs(g_out))) > 0.0


def test_serve_engine_greedy_unchanged_under_flash():
    """Engine greedy outputs are impl-independent and the compile-once
    guard holds with the flash decode/prefill kernels in the jit."""
    from repro.serve import Engine, SamplingParams
    cfg = get_smoke_config("llama3.2-1b").with_overrides(remat=False,
                                                         dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (5, 9, 3)]

    def run(attn_impl):
        eng = Engine(model, params, max_slots=2, max_seq=32,
                     prefill_chunk=8, attn_impl=attn_impl)
        rids = [eng.submit(p, 5, SamplingParams()) for p in prompts]
        res = eng.run()
        return [res[r] for r in rids], eng.trace_counts

    ref, _ = run("ref")
    fl, tc = run("flash")
    assert ref == fl
    assert tc["decode"] == 1 and tc["prefill"] == 1


# ---------------------------------------------------------------------------
# memory guarantees
# ---------------------------------------------------------------------------

def _sxs_vars(jaxpr, S, dtype=None):
    """f32 (or ``dtype``) variables shaped (..., S, S) anywhere in a jaxpr."""
    hits = []

    def walk(jpr):
        for eqn in jpr.eqns:
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                if aval is None or len(aval.shape) < 2:
                    continue
                if tuple(aval.shape[-2:]) == (S, S) and (
                        dtype is None and aval.dtype == jnp.float32
                        or aval.dtype == dtype):
                    hits.append(aval)
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    walk(sub.jaxpr)
                elif isinstance(sub, (list, tuple)):
                    for s in sub:
                        if hasattr(s, "jaxpr"):
                            walk(s.jaxpr)
    walk(jaxpr.jaxpr)
    return hits


def test_dense_softmax_no_fp32_score_chain():
    """Peak-memory regression (satellite fix): the dense ref path must not
    run the softmax chain over an fp32 copy of the (S, S) scores. At most
    one fp32 (S,S) value may appear — the convert feeding the fp32
    row-sum reduction, which fuses into the reduce and never allocates."""
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    a = AttentionConfig(num_heads=H, num_kv_heads=KV, head_dim=hd)
    keep = causal_window_mask(jnp.arange(S), jnp.arange(S), 0)
    q = jnp.zeros((B, S, H, hd), jnp.bfloat16)
    k = jnp.zeros((B, S, KV, hd), jnp.bfloat16)
    jpr = jax.make_jaxpr(lambda q, k, v: gqa_attend(q, k, v, keep, a))(
        q, k, k)
    assert len(_sxs_vars(jpr, S)) <= 1, (
        f"dense path materializes fp32 (S,S) chain: {_sxs_vars(jpr, S)}")

    # the old upcast-everything softmax trips the same counter (the test
    # would have caught the regression it pins)
    def old_attend(q, k, v):
        G = H // KV
        qg = q.reshape(B, S, KV, G, hd)
        s = jnp.einsum("bskgh,btkh->bkgst", qg, k)
        s = jnp.where(keep[None, None, None], s, -1e30)
        w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(v.dtype)
        return jnp.einsum("bkgst,btkh->bskgh", w, v)

    jpr_old = jax.make_jaxpr(old_attend)(q, k, k)
    assert len(_sxs_vars(jpr_old, S)) >= 3


def test_flash_train_step_has_no_sxs_allocation():
    """Acceptance: fwd+bwd through the flash kernel compiles with no
    (S, S)-shaped tensor of any dtype in the optimized HLO."""
    import re
    B, S, H, KV, hd = 1, 128, 4, 2, 16
    q, k, v = _qkv(jax.random.key(12), B, S, S, H, KV, hd, hd,
                   jnp.bfloat16)

    def step(q, k, v):
        # grad wrt all three so the dq AND dkv kernels are in the HLO
        return jax.grad(lambda q, k, v: flash_attention(
            q, k, v, block_q=32, block_k=32,
            interpret=True).astype(jnp.float32).sum(),
            argnums=(0, 1, 2))(q, k, v)

    txt = jax.jit(step).lower(q, k, v).compile().as_text()
    assert not re.findall(rf"\[(?:\d+,)*{S},{S}\]", txt)

    def dense(q, k, v):
        keep = causal_window_mask(jnp.arange(S), jnp.arange(S), 0)
        a = AttentionConfig(num_heads=H, num_kv_heads=KV, head_dim=hd)
        return jax.grad(lambda q: gqa_attend(q, k, v, keep, a).astype(
            jnp.float32).sum())(q)

    txt_dense = jax.jit(dense).lower(q, k, v).compile().as_text()
    assert re.findall(rf"\[(?:\d+,)*{S},{S}\]", txt_dense)  # test bites


# ---------------------------------------------------------------------------
# dispatch knob + roofline model
# ---------------------------------------------------------------------------

def test_resolve_attn_impl_env_and_config(monkeypatch):
    a = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16)
    monkeypatch.delenv("REPRO_ATTN_IMPL", raising=False)
    # backend default on this CPU container: interpret mode -> ref
    assert resolve_attn_impl(a) == "ref"
    assert resolve_attn_impl(None) == "ref"
    # config knob
    assert resolve_attn_impl(
        dataclasses.replace(a, attn_impl="flash")) == "flash"
    # env wins over config
    monkeypatch.setenv("REPRO_ATTN_IMPL", "blockwise")
    assert resolve_attn_impl(
        dataclasses.replace(a, attn_impl="flash")) == "blockwise"
    monkeypatch.setenv("REPRO_ATTN_IMPL", "nope")
    with pytest.raises(ValueError):
        resolve_attn_impl(a)


def test_attention_roofline_windowed_flops():
    from repro.roofline.analysis import attention_flops_bytes
    full = attention_flops_bytes(batch=1, q_len=1024, kv_len=1024, heads=4,
                                 kv_heads=2, head_dim_k=64)
    assert full["pairs"] == 1024 * 1025 // 2          # causal triangle
    win = attention_flops_bytes(batch=1, q_len=1024, kv_len=1024, heads=4,
                                kv_heads=2, head_dim_k=64, window=128)
    # windowed compute is linear in S: 128*1024 - 128*127/2
    assert win["pairs"] == 128 * 1024 - 128 * 127 // 2
    assert win["flops"] < full["flops"] / 3
    chunk = attention_flops_bytes(batch=1, q_len=32, kv_len=256, heads=4,
                                  kv_heads=2, head_dim_k=64, q_start=224)
    assert chunk["pairs"] == sum(min(225 + i, 256) for i in range(32))
    fb = attention_flops_bytes(batch=1, q_len=256, kv_len=256, heads=4,
                               kv_heads=2, head_dim_k=64, kind="fwd+bwd")
    fwd = attention_flops_bytes(batch=1, q_len=256, kv_len=256, heads=4,
                                kv_heads=2, head_dim_k=64)
    assert fb["flops"] > 2 * fwd["flops"] and fb["hbm_bytes"] > \
        fwd["hbm_bytes"]
