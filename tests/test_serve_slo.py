"""Serve-side SLO guardrails: typed admission, deadlines + cancellation,
bounded queue + shedding policies, brownout, the stuck-step watchdog,
graceful drain/restore, chaos replay determinism, and the page-accounting
invariants every one of those paths must preserve.

The non-negotiables pinned here: rejections mutate nothing; cancel
releases pages exactly as finish does (refcounts partition the pool under
any interleaving); jitted decode/prefill are byte-identical with
guardrails on or off and compile exactly once; drain->restore and chaos
replay are bit-identical."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.fault.inject import SERVE_KINDS, FaultPlan
from repro.models import build_model
from repro.serve import (ACCEPTED, AdmissionResult, Engine,
                         REJECTED_QUEUE_FULL, Request, SamplingParams,
                         SlotScheduler)
from repro.serve.chaos import (VirtualClock, make_cost_model, run_chaos,
                               verify_drain_restore, verify_replay)


@functools.lru_cache(maxsize=None)
def _setup(arch="llama3.2-1b"):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _engine(**over):
    cfg, model, params = _setup()
    kw = dict(max_slots=3, max_seq=64, prefill_chunk=8, page_size=8)
    kw.update(over)
    return Engine(model, params, **kw)


# ---------------------------------------------------------------------------
# AdmissionResult: typed, back-compatible, zero-mutation rejections
# ---------------------------------------------------------------------------

def test_admission_result_coerces_to_rid():
    """Accepted results behave like the int rid they used to be: dict
    key, equality, int()."""
    sched = SlotScheduler(2, 32)
    r = sched.submit(Request(tokens=[1, 2], max_new=2))
    assert r.accepted and bool(r) and r.status == ACCEPTED
    assert int(r) == 0 and r == 0 and hash(r) == hash(0)
    assert {r: "x"}[0] == "x" and {0: "y"}[r] == "y"


def test_queue_full_rejection_is_typed_not_raised():
    sched = SlotScheduler(1, 32, max_queue=2)
    assert sched.submit(Request(tokens=[1], max_new=1))
    assert sched.submit(Request(tokens=[2], max_new=1))
    r = sched.submit(Request(tokens=[3], max_new=1))
    assert not r and r.status == REJECTED_QUEUE_FULL and int(r) == -1
    # malformed requests are caller bugs, still exceptions:
    with pytest.raises(ValueError, match="empty"):
        sched.submit(Request(tokens=[], max_new=1))
    with pytest.raises(ValueError, match="max_new"):
        sched.submit(Request(tokens=[1], max_new=0))


def test_rejection_paths_mutate_nothing():
    """Every refusal — queue-full at submit, draining, page-gated
    try_admit — leaves allocator + queue state byte-identical."""
    eng = _engine(max_queue=2, num_pages=10)
    assert eng.submit([1, 2, 3], 4)
    assert eng.submit([4, 5, 6], 4)
    before = eng.allocator.state_digest()
    pend = list(eng.sched.pending)
    r = eng.submit([7, 8, 9], 4)           # queue full
    assert not r and r.status == REJECTED_QUEUE_FULL
    assert eng.allocator.state_digest() == before
    assert list(eng.sched.pending) == pend
    eng.draining = True
    r2 = eng.submit([7, 8], 2)             # draining
    assert not r2 and eng.allocator.state_digest() == before
    eng.draining = False
    assert eng.stats.rejected_queue_full == 2
    # page-gated head-of-line block: 10-page pool (9 usable), two requests
    # each needing 2 pages admit, a third stays queued without any
    # allocator mutation while blocked
    eng.run()
    big = _engine(max_slots=2, num_pages=5)        # 4 usable pages
    big.submit([1] * 8, 8)                         # 2 pages
    big.submit([2] * 8, 8)                         # 2 pages
    big.step()
    digest = big.allocator.state_digest()
    r3 = big.submit([3] * 8, 8)                    # queued, cannot admit
    assert r3.accepted                             # queue is unbounded
    big.step()                                     # try_admit refuses
    assert big.sched.queue_depth == 1
    tbl, refs, free, held, resv, pfx = big.allocator.state_digest()
    assert (refs, free, held, pfx) == (digest[1], digest[2], digest[3],
                                       digest[5])


def test_never_fits_requests_still_raise():
    eng = _engine()
    with pytest.raises(ValueError, match="cache rows"):
        eng.submit(list(range(60)), 30)
    small = _engine(num_pages=4)                   # 3 usable pages
    with pytest.raises(ValueError, match="pages"):
        small.submit(list(range(30)), 10)          # 40 rows = 5 pages


# ---------------------------------------------------------------------------
# deadlines: queued shed, in-flight cancel, estimates
# ---------------------------------------------------------------------------

def test_expired_queued_request_is_shed_not_run():
    clock = VirtualClock()
    eng = _engine(clock=clock, cost_model=make_cost_model()[0],
                  max_slots=1)
    a = eng.submit([1, 2, 3], 4)                   # occupies the only slot
    b = eng.submit([4, 5, 6], 4, deadline_ms=5.0)  # cannot start in time
    clock.advance(0.02)                            # 20ms >> 5ms budget
    eng.step()
    reasons = eng.sched.finish_reasons()
    assert reasons[int(b)] == "shed"
    assert eng.sched.results()[int(b)] == []       # never decoded
    eng.run()
    assert eng.sched.finish_reasons()[int(a)] == "stop"
    assert eng.stats.shed == 1 and eng.stats.deadline_misses == 1


def test_queue_budget_max_queue_ms_sheds():
    clock = VirtualClock()
    eng = _engine(clock=clock, cost_model=make_cost_model()[0],
                  max_slots=1)
    eng.submit([1, 2, 3], 8)
    b = eng.submit([4, 5], 4, max_queue_ms=1.0)
    clock.advance(0.01)
    eng.step()
    assert eng.sched.finish_reasons()[int(b)] == "shed"


def test_inflight_past_deadline_cancelled_at_step_boundary():
    """A running request whose deadline lapses is evicted mid-flight with
    reason 'deadline'; its partial output is kept and its pages return to
    the free list (same release path as finish)."""
    clock = VirtualClock()
    eng = _engine(clock=clock, cost_model=make_cost_model()[0])
    r = eng.submit([1, 2, 3, 4], 32, deadline_ms=30.0)
    for _ in range(3):
        eng.step()
    got = len(eng.sched.slots[0].generated) if eng.sched.slots[0] else 0
    clock.advance(10.0)                            # blow way past deadline
    eng.step()
    assert eng.sched.finish_reasons()[int(r)] == "deadline"
    assert 0 < len(eng.sched.results()[int(r)]) < 32
    assert eng.sched.num_active == 0
    eng.allocator.check_consistency()
    assert eng.stats.deadline_misses == 1
    # the freed slot is immediately reusable
    r2 = eng.submit([5, 6], 2)
    eng.run()
    assert eng.sched.finish_reasons()[int(r2)] == "stop"


def test_cold_engine_never_sheds_on_blind_estimate():
    """With no measured rates (fresh engine), the admission estimate is 0:
    a tight-but-not-yet-expired deadline must not shed at submit time."""
    eng = _engine()
    r = eng.submit([1, 2], 2, deadline_ms=60_000.0)
    eng.step()
    assert int(r) not in eng.sched.finish_reasons() \
        or eng.sched.finish_reasons()[int(r)] == "stop"


def test_cancel_api_queued_and_inflight():
    eng = _engine(max_slots=1)
    a = eng.submit([1, 2, 3], 16)
    b = eng.submit([4, 5, 6], 4)
    eng.step()                                     # a running, b queued
    assert eng.cancel(int(b)) is True              # queued -> shed path
    assert eng.cancel(int(a)) is True              # in-flight -> evicted
    assert eng.cancel(999) is False
    assert eng.cancel(int(a)) is False             # already terminal
    reasons = eng.sched.finish_reasons()
    assert reasons[int(a)] == "cancel" and reasons[int(b)] == "cancel"
    eng.allocator.check_consistency()
    assert eng.stats.cancelled == 2


# ---------------------------------------------------------------------------
# bounded queue + shedding policy
# ---------------------------------------------------------------------------

def test_shed_policy_reject_no_deadline_displaces_youngest():
    sched = SlotScheduler(1, 64, max_queue=3,
                          shed_policy="reject-no-deadline")
    a = sched.submit(Request(tokens=[1], max_new=1, deadline_ms=50.0))
    b = sched.submit(Request(tokens=[2], max_new=1))          # no deadline
    c = sched.submit(Request(tokens=[3], max_new=1))          # no deadline
    d = sched.submit(Request(tokens=[4], max_new=1, deadline_ms=9.0))
    assert d.accepted
    # c (youngest without a deadline) was displaced, b survives
    assert [r.rid for r in sched.pending] == [int(a), int(b), int(d)]
    assert sched.finish_reasons()[int(c)] == "shed"
    e = sched.submit(Request(tokens=[5], max_new=1, deadline_ms=7.0))
    assert e.accepted and sched.finish_reasons()[int(b)] == "shed"
    # every queued request now carries a deadline: fall back to
    # reject-newest — the arrival is refused, the queue untouched
    f = sched.submit(Request(tokens=[6], max_new=1, deadline_ms=5.0))
    assert not f and f.status == REJECTED_QUEUE_FULL
    assert [r.rid for r in sched.pending] == [int(a), int(d), int(e)]


def test_shed_policy_validated():
    with pytest.raises(ValueError, match="shed_policy"):
        SlotScheduler(1, 32, shed_policy="lifo")


# ---------------------------------------------------------------------------
# brownout ladder
# ---------------------------------------------------------------------------

def test_brownout_ladder_hysteresis_and_clamp():
    eng = _engine()
    # sustained level-1 pressure: registration off after patience steps
    for _ in range(3):
        eng._update_brownout(0.90)
    assert eng._brownout_level == 1
    # a single cool step does not leave brownout (hysteresis)
    eng._update_brownout(0.40)
    assert eng._brownout_level == 1
    for _ in range(2):
        eng._update_brownout(0.40)
    assert eng._brownout_level == 0
    # level 2 clamps queued admissions' max_new
    eng.submit([1, 2, 3], 40)
    for _ in range(3):
        eng._update_brownout(0.97)
    assert eng._brownout_level == 2
    assert eng.sched.pending[0].max_new == eng.brownout_max_new
    assert eng.stats.brownout_clamped == 1
    assert eng.stats.brownout_level == 2


def test_brownout_level1_disables_prefix_registration():
    eng = _engine()
    eng._brownout_level = 1
    eng.submit([7] * 16, 2)
    eng.run()
    assert len(eng.allocator._entries) == 0        # nothing published
    eng._brownout_level = 0
    eng.submit([7] * 16, 2)
    eng.run()
    assert len(eng.allocator._entries) > 0


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_flags_stalled_step():
    clock = VirtualClock()
    cost, state = make_cost_model()
    eng = _engine(clock=clock, cost_model=cost, watchdog_k=4.0)
    eng.submit([1, 2, 3], 24)
    for _ in range(6):                             # warm the EWMA
        eng.step()
    assert eng.stats.watchdog_stalls == 0
    state["stall_factor"] = 50.0                   # one wedged dispatch
    eng.step()
    assert eng.stats.watchdog_stalls == 1
    state["stall_factor"] = 1.0
    eng.run()


# ---------------------------------------------------------------------------
# bounded finished map + pop_finished hand-off
# ---------------------------------------------------------------------------

def test_finished_retention_bounded_and_accounting_survives():
    sched = SlotScheduler(1, 64, finished_keep=4)
    for i in range(10):
        r = sched.submit(Request(tokens=[1, 2], max_new=1))
        sched.admit()
        sched.record_first_token(0, 5)             # max_new=1: finishes
    assert len(sched.finished) == 4                # newest kept
    assert sched.finished_total == 10 and sched.finished_dropped == 6
    popped = sched.pop_finished()
    assert len(popped) == 4 and len(sched.finished) == 0
    # monotonic accounting is unaffected by the hand-off
    assert sched.finished_total == 10
    sched.submit(Request(tokens=[3], max_new=1))
    sched.admit()
    sched.record_first_token(0, 5)
    assert sched.finished_total == 11


def test_engine_eviction_accounting_survives_pop(tmp_path):
    """The old len(finished) watermark broke the eviction counter the
    moment results were handed off; the finish-log stream does not."""
    eng = _engine()
    eng.submit([1, 2], 2)
    eng.run()
    assert eng.stats.evictions == 1
    eng.sched.pop_finished()
    eng.submit([3, 4], 2)
    eng.run()
    assert eng.stats.evictions == 2


# ---------------------------------------------------------------------------
# page-accounting invariants under adversarial interleavings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_refcounts_partition_pool_under_interleaving(seed):
    """Property test: any interleaving of submit / step / cancel / finish
    / COW (shared-prefix hits force copy-on-write) keeps the allocator
    partition exact — free + held + live == num_pages, refs recomputable
    from tables + prefix entries, null page pinned."""
    cfg, model, params = _setup()
    rng = np.random.RandomState(seed)
    eng = _engine(max_slots=3, num_pages=20)
    shared = rng.randint(0, cfg.vocab_size, 16).tolist()   # 2 full pages
    live_rids = []
    for op in range(60):
        choice = rng.rand()
        if choice < 0.35:
            # shared head -> prefix hits -> COW on the first write
            tail = rng.randint(0, cfg.vocab_size,
                               rng.randint(1, 6)).tolist()
            prompt = shared + tail if rng.rand() < 0.6 else tail
            r = eng.submit(prompt, int(rng.randint(1, 8)))
            if r:
                live_rids.append(int(r))
        elif choice < 0.5 and live_rids:
            eng.cancel(live_rids.pop(rng.randint(len(live_rids))))
        elif choice < 0.6 and eng.allocator.free:
            eng.allocator.hold_pages(int(rng.randint(1, 3)))
        elif choice < 0.7:
            eng.allocator.release_held()
        else:
            eng.step()
        eng.allocator.check_consistency()
    eng.allocator.release_held()
    eng.run()
    eng.allocator.check_consistency()
    assert eng.trace_counts["decode"] == 1


def test_cancel_releases_pages_exactly_like_finish():
    """Two identical requests, one cancelled mid-flight and one run to
    completion, leave identical allocator free/ref state."""
    def run(kill: bool):
        eng = _engine(max_slots=1, prefix_cache=False)
        r = eng.submit([1, 2, 3, 4, 5], 8)
        for _ in range(3):
            eng.step()
        if kill:
            eng.cancel(int(r))
        else:
            eng.run()
        eng.allocator.check_consistency()
        return (sorted(eng.allocator.free),
                eng.allocator.refs.tolist())
    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# drain -> restore
# ---------------------------------------------------------------------------

def test_drain_restore_bit_identical(tmp_path):
    path = str(tmp_path / "serve.snap")

    def make_engine(**over):
        return _engine(**over)
    out = verify_drain_restore(make_engine, seed=3, n=5, drain_after=2,
                               vocab=_setup()[0].vocab_size, path=path)
    assert out["requeued"]                         # something was pending


def test_drain_rejects_new_submissions_and_snapshot_crc(tmp_path):
    eng = _engine()
    eng.submit([1, 2, 3], 4)
    eng.submit([4, 5], 3)
    path = str(tmp_path / "s.snap")
    snap = eng.drain(path)
    assert not eng.submit([9, 9], 2)               # draining: refused
    # nothing was in flight: the queued work is snapshotted, not run
    assert len(snap["queued"]) == 2 and snap["inflight"] == []
    assert snap["finished"] == []
    # corrupt one byte: restore must fail loudly
    raw = bytearray(open(path, "rb").read())
    raw[len(raw) // 2] ^= 0x40
    bad = str(tmp_path / "bad.snap")
    open(bad, "wb").write(bytes(raw))
    fresh = _engine()
    with pytest.raises((ValueError, KeyError)):
        fresh.load_snapshot(bad)
    ok = _engine()
    ok.load_snapshot(path)
    assert ok.sched.results() == eng.sched.results()
    assert ok.sched._next_rid == eng.sched._next_rid


def test_restore_preserves_rids_for_queued_work(tmp_path):
    eng = _engine(max_slots=1)
    a = eng.submit([1, 2, 3], 4)
    b = eng.submit([4, 5, 6], 4)
    eng.step()                                     # a in flight, b queued
    snap = eng.drain(max_steps=0)                  # snapshot immediately
    eng2 = _engine(max_slots=1)
    requeued = eng2.load_snapshot(snap)
    assert requeued == [int(a), int(b)]            # in-flight first
    eng2.run()
    reasons = eng2.sched.finish_reasons()
    assert reasons[int(a)] == "stop" and reasons[int(b)] == "stop"


# ---------------------------------------------------------------------------
# chaos: serve fault kinds + bit-identical replay
# ---------------------------------------------------------------------------

def test_fault_plan_serve_kinds_round_trip():
    spec = "qflood:6@3,stall:8@6x4,cancel:1@9,pagepress:12@10x8"
    plan = FaultPlan.from_spec(spec, seed=5)
    assert plan.to_spec() == spec
    assert all(e.kind in SERVE_KINDS for e in plan.events)
    # training-side kinds are refused by the serve loop
    bad = FaultPlan.from_spec("kill:0@1")
    with pytest.raises(ValueError, match="training-side"):
        run_chaos(lambda **kw: _engine(**kw), bad)


def test_chaos_replay_bit_identical():
    plan = FaultPlan.from_spec(
        "qflood:4@2,stall:6@4x3,cancel:0@6,pagepress:8@5x4", seed=11)

    def make_engine(**over):
        return _engine(max_queue=8, shed_policy="reject-no-deadline",
                       **over)
    a, b = verify_replay(make_engine, plan, n_base=5, max_steps=120,
                         vocab=_setup()[0].vocab_size, max_seq=64)
    assert a["digest"] == b["digest"]
    assert a["decode_compiles"] == 1
    assert a["stats"]["finished_total"] == a["stats"]["submitted"] \
        - a["stats"]["rejected_at_submit"]


def test_chaos_virtual_clock_is_deterministic():
    clock = VirtualClock()
    assert clock() == 0.0
    clock.advance(0.5)
    assert clock() == 0.5
    with pytest.raises(ValueError):
        clock.advance(-1.0)


# ---------------------------------------------------------------------------
# the compile contract: guardrails change nothing inside jit
# ---------------------------------------------------------------------------

def _decode_hlo(eng):
    tokens = jnp.zeros((eng.max_slots, 1), jnp.int32)
    pos = jnp.zeros((eng.max_slots,), jnp.int32)
    return eng._decode.jitted.lower(
        eng.params, eng.pool, tokens, pos, jnp.asarray(eng._temps),
        jnp.asarray(eng._top_ks), jnp.asarray(eng._top_ps), eng._keys,
        eng._tables()).as_text()


def _prefill_hlo(eng):
    toks = jnp.zeros((1, eng.prefill_chunk), jnp.int32)
    return eng._prefill.jitted.lower(
        eng.params, eng.pool, toks, jnp.int32(0), jnp.int32(0),
        jnp.int32(eng.prefill_chunk), eng._tables()).as_text()


def test_jitted_programs_byte_identical_guardrails_on_off():
    on = _engine(max_queue=4, watchdog_k=2.0, guardrails=True)
    off = _engine(guardrails=False)
    assert _decode_hlo(on) == _decode_hlo(off)
    assert _prefill_hlo(on) == _prefill_hlo(off)


def test_decode_compiles_once_under_guardrail_churn():
    clock = VirtualClock()
    eng = _engine(max_queue=4, clock=clock, cost_model=make_cost_model()[0])
    rids = [eng.submit([i + 1, i + 2], 4,
                       deadline_ms=(5.0 if i % 2 else None))
            for i in range(6)]
    eng.step()
    clock.advance(1.0)                             # expire the deadlines
    eng.run()
    eng.cancel(next(int(r) for r in rids if r))
    eng.submit([9, 8, 7], 3)
    eng.run()
    assert eng.trace_counts["decode"] == 1
    assert eng.trace_counts["sample"] <= 2         # greedy paths only


def test_guardrails_off_records_budgets_without_enforcing():
    clock = VirtualClock()
    eng = _engine(guardrails=False, clock=clock,
                  cost_model=make_cost_model()[0])
    r = eng.submit([1, 2, 3], 6, deadline_ms=1.0)
    clock.advance(1.0)                             # way past budget
    eng.run()
    assert eng.sched.finish_reasons()[int(r)] == "stop"   # ran anyway
    assert eng.stats.deadline_misses == 1          # ...and was measured
    assert eng.stats.goodput_tokens == 0
