"""Property-style checks for the repro.dist sharding subsystem:

- ``sanitize_spec`` output is always divisible-or-empty, never duplicates a
  mesh axis, and handles axes absent from the mesh;
- ``param_spec`` returns a rank-compatible spec for every leaf of every
  smoke config in the registry, sanitizable against every production mesh;
- ``act.constrain`` is the identity outside ``activation_spec`` and a shape-
  preserving constraint inside;
- the ``*_shardings`` builders produce valid NamedShardings end-to-end.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_smoke_config
from repro.configs.base import InputShape
from repro.dist import act
from repro.dist.sharding import (MODEL_AXIS, batch_shardings, cache_shardings,
                                 dp_axes_of, dp_size_of, param_shardings,
                                 param_spec, sanitize_spec,
                                 set_replicate_attn, state_shardings)
from repro.launch.specs import (abstract_cache, abstract_state,
                                train_batch_specs)
from repro.models import build_model
from repro.optim import sgd_momentum
from repro.testing import FakeMesh

SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})
TINY = FakeMesh({"data": 4, "model": 2})
MESHES = [SINGLE, MULTI, TINY]
_IDS = ["16x16", "2x16x16", "4x2"]


def _extent(mesh, e):
    if isinstance(e, (tuple, list)):
        k = 1
        for a in e:
            k *= mesh.shape[a]
        return k
    return mesh.shape[e]


def _assert_valid(spec, shape, mesh):
    assert len(spec) <= len(shape)
    used = []
    for i, e in enumerate(spec):
        if e is None:
            continue
        assert shape[i] % _extent(mesh, e) == 0, (spec, shape)
        used += list(e) if isinstance(e, (tuple, list)) else [e]
    assert len(used) == len(set(used)), f"duplicated axis in {spec}"


# ---------------------------------------------------------------------------
# sanitize_spec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh", MESHES, ids=_IDS)
def test_sanitize_always_divisible_or_dropped(mesh):
    shapes = [(20,), (16,), (1,), (7, 13), (16, 16), (40, 2560, 20, 128),
              (3, 5, 7, 11), (48, 64, 64), (2, 8, 4, 2, 64)]
    for shape in shapes:
        for pos in range(len(shape)):
            entries = [None] * len(shape)
            entries[pos] = MODEL_AXIS
            _assert_valid(sanitize_spec(P(*entries), shape, mesh),
                          shape, mesh)


def test_sanitize_relocation_prefers_right():
    # the pinned dryrun case: 20 heads on model=16 move right to head_dim
    spec = sanitize_spec(P(None, None, "model", None),
                         (40, 2560, 20, 128), SINGLE)
    assert tuple(spec) == (None, None, None, "model")
    # nothing divisible on the right: falls back to the nearest left dim
    spec = sanitize_spec(P(None, "model", None), (32, 20, 7), SINGLE)
    assert tuple(spec) == ("model",)


def test_sanitize_drops_when_nothing_divides():
    assert tuple(sanitize_spec(P("model"), (20,), SINGLE)) == ()
    assert tuple(sanitize_spec(P("model", "data"), (6, 10), SINGLE)) == ()


def test_sanitize_tuple_and_missing_axes():
    # tuple (pod,data) entry: extent is the product
    spec = sanitize_spec(P(("pod", "data"), None), (64, 3), MULTI)
    assert tuple(spec) == (("pod", "data"),)
    assert tuple(sanitize_spec(P(("pod", "data")), (4,), MULTI)) == ()
    # axes absent from the mesh are dropped, present ones kept
    pure_dp = FakeMesh({"data": 4})
    assert tuple(sanitize_spec(P(None, "model"), (4, 32), pure_dp)) == ()
    spec = sanitize_spec(P(("pod", "data"), "model"), (8, 32), pure_dp)
    assert tuple(spec) == ("data",)


def test_sanitize_never_widens_rank():
    spec = sanitize_spec(P("model", None, None, None), (32,), SINGLE)
    assert len(spec) <= 1


# ---------------------------------------------------------------------------
# dp axes
# ---------------------------------------------------------------------------

def test_dp_axes_and_size():
    assert dp_axes_of(SINGLE) == ("data",)
    assert dp_size_of(SINGLE) == 16
    assert dp_axes_of(MULTI) == ("pod", "data")
    assert dp_size_of(MULTI) == 32
    assert dp_axes_of(FakeMesh({"model": 8})) == ()
    assert dp_size_of(FakeMesh({"model": 8})) == 1


# ---------------------------------------------------------------------------
# param_spec over every smoke config in the registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
def test_param_spec_rank_compatible_every_leaf(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    n_sharded = 0

    def check(path, leaf):
        nonlocal n_sharded
        spec = param_spec(path, leaf)
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        for mesh in MESHES:
            _assert_valid(sanitize_spec(spec, leaf.shape, mesh),
                          leaf.shape, mesh)
        if any(e is not None for e in spec):
            n_sharded += 1

    jax.tree_util.tree_map_with_path(check, params)
    # the rule engine must actually shard things, not replicate everything
    assert n_sharded >= 3, f"{arch}: only {n_sharded} sharded leaves"


def test_replicate_attn_toggle():
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    wq = [(p, l) for p, l in leaves
          if jax.tree_util.keystr(p).endswith("['wq']")]
    assert wq
    path, leaf = wq[0]
    assert MODEL_AXIS in tuple(param_spec(path, leaf))
    try:
        set_replicate_attn(True)
        assert tuple(param_spec(path, leaf)) == ()
        # FFN TP is unaffected by the toggle
        wi = [(p, l) for p, l in leaves
              if "mlp" in jax.tree_util.keystr(p)
              and jax.tree_util.keystr(p).endswith("['wi']")][0]
        assert MODEL_AXIS in tuple(param_spec(*wi))
    finally:
        set_replicate_attn(False)
    assert MODEL_AXIS in tuple(param_spec(path, leaf))


# ---------------------------------------------------------------------------
# act.constrain
# ---------------------------------------------------------------------------

def test_act_constrain_identity_outside_context():
    x = jnp.ones((2, 8, 16))
    assert act.constrain(x) is x
    with act.activation_spec(None):   # explicit None is also a no-op
        assert act.constrain(x) is x
    assert act.current_spec() is None


def test_act_constrain_inside_context_preserves_shape_and_values():
    mesh = jax.make_mesh((1,), ("model",))
    jax.set_mesh(mesh)
    x = jnp.arange(2 * 8 * 16, dtype=jnp.float32).reshape(2, 8, 16)
    with act.activation_spec(P(None, None, "model")):
        assert act.current_spec() == P(None, None, "model")
        y = jax.jit(act.constrain)(x)
    assert y.shape == x.shape
    assert bool(jnp.all(y == x))
    assert act.current_spec() is None


def test_act_constrain_rank_pads():
    mesh = jax.make_mesh((1,), ("model",))
    jax.set_mesh(mesh)
    with act.activation_spec(P(None, None, "model")):
        y2 = jax.jit(act.constrain)(jnp.ones((4, 16)))      # rank < spec
        y4 = jax.jit(act.constrain)(jnp.ones((2, 2, 4, 16)))  # rank > spec
    assert y2.shape == (4, 16) and y4.shape == (2, 2, 4, 16)


def test_act_contexts_nest():
    a, b = P("model"), P(None, "model")
    with act.activation_spec(a):
        with act.activation_spec(b):
            assert act.current_spec() is b
        assert act.current_spec() is a
    assert act.current_spec() is None


# ---------------------------------------------------------------------------
# builders end-to-end on a real (1-device) mesh
# ---------------------------------------------------------------------------

def _real_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_and_state_shardings_build():
    mesh = _real_mesh()
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    state = abstract_state(model, sgd_momentum(weight_decay=0.0))
    psh = param_shardings(mesh, state["params"])
    for leaf, sh in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(psh)):
        assert isinstance(sh, NamedSharding)
        assert len(sh.spec) <= leaf.ndim
    ssh = state_shardings(mesh, state)
    assert set(ssh) == {"params", "opt", "step"}
    # BSP state is replicated over the whole mesh (paper-faithful DP)
    for sh in jax.tree.leaves(ssh,
                              is_leaf=lambda x: isinstance(x, NamedSharding)):
        assert tuple(sh.spec) == ()


def test_batch_and_cache_shardings_build():
    mesh = _real_mesh()
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    model = build_model(cfg)
    shape = InputShape("tiny_train", 32, 8, "train")
    bsh = batch_shardings(mesh, train_batch_specs(cfg, shape))
    for sh in jax.tree.leaves(bsh):
        assert isinstance(sh, NamedSharding)
    cache = abstract_cache(model, cfg, InputShape("tiny_dec", 32, 8, "decode"))
    csh = cache_shardings(mesh, cache, 8)
    for leaf, sh in zip(jax.tree.leaves(cache), jax.tree.leaves(csh)):
        assert isinstance(sh, NamedSharding)
        assert len(sh.spec) <= leaf.ndim


def test_cache_shardings_shard_heads_on_fake_mesh():
    """On the production mesh shape the KV cache is model-sharded on a
    head-like dim and data-sharded on batch (validated via specs only)."""
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    cache = abstract_cache(model, cfg, InputShape("d", 64, 16, "decode"))
    leaves = jax.tree_util.tree_flatten_with_path(cache)[0]
    k_leaves = [(p, l) for p, l in leaves
                if jax.tree_util.keystr(p).endswith("['k']")]
    assert k_leaves
    for path, leaf in k_leaves:
        entries = [None] * leaf.ndim
        bi = next(i for i, s in enumerate(leaf.shape) if s == 16)
        entries[bi] = "data"
        entries[leaf.ndim - 2] = MODEL_AXIS
        _assert_valid(sanitize_spec(P(*entries), leaf.shape, TINY),
                      leaf.shape, TINY)
