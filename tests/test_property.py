"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # no hypothesis in this env: deterministic fallback
    from repro.testing.hypofallback import given, settings, st

from repro.models.common import softmax_xent
from repro.models.ssm import _segsum, ssd_chunked, ssd_naive
from repro.optim import poly_decay, step_decay, warmup_cosine


@settings(max_examples=25, deadline=None)
@given(t=st.integers(2, 12))
def test_segsum_definition(t):
    x = jax.random.normal(jax.random.key(t), (t,))
    out = np.asarray(_segsum(x))
    xs = np.asarray(x)
    for i in range(t):
        for j in range(t):
            if j > i:
                assert out[i, j] == -np.inf
            else:
                np.testing.assert_allclose(out[i, j], xs[j + 1:i + 1].sum(),
                                           rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(3, 40), chunk=st.sampled_from([4, 8, 16]),
       h=st.sampled_from([1, 2, 4]))
def test_ssd_chunked_equals_naive_property(s, chunk, h):
    key = jax.random.key(s * 131 + chunk)
    b, p, n = 1, 4, 8
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, s, h)) - 1)
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.2)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, s, 1, n))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, s, 1, n))
    y1, _ = ssd_chunked(x, dt, A, B, C, chunk)
    y2, _ = ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 50), v=st.integers(2, 30))
def test_softmax_xent_matches_numpy(n, v):
    key = jax.random.key(n * 57 + v)
    logits = jax.random.normal(key, (n, v)) * 3
    labels = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, v)
    got = float(softmax_xent(logits, labels))
    lg = np.asarray(logits, np.float64)
    p = lg - np.log(np.exp(lg - lg.max(-1, keepdims=True)).sum(-1,
                    keepdims=True)) - lg.max(-1, keepdims=True)
    want = -p[np.arange(n), np.asarray(labels)].mean()
    np.testing.assert_allclose(got, want, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(lr0=st.floats(1e-4, 1.0), steps=st.integers(10, 1000))
def test_schedules_bounded_and_monotone(lr0, steps):
    pd = poly_decay(lr0, steps)
    sd = step_decay(lr0, max(steps // 5, 1))
    vals_p = [float(pd(jnp.int32(s))) for s in range(0, steps, max(steps // 10, 1))]
    vals_s = [float(sd(jnp.int32(s))) for s in range(0, steps, max(steps // 10, 1))]
    assert all(0 <= v <= lr0 * (1 + 1e-6) for v in vals_p + vals_s)
    assert all(a >= b - 1e-9 for a, b in zip(vals_p, vals_p[1:]))
    assert all(a >= b - 1e-9 for a, b in zip(vals_s, vals_s[1:]))


@settings(max_examples=15, deadline=None)
@given(k=st.integers(1, 8), n=st.integers(1, 500))
def test_pad_chunk_roundtrip(k, n):
    """The exchangers' pad->chunk->unpad plumbing is lossless."""
    from repro.core.exchanger import _pad_to
    g = jax.random.normal(jax.random.key(k * 7 + n), (n, 3))
    gp, n0 = _pad_to(g, k)
    assert gp.shape[0] % k == 0 and n0 == n
    chunks = gp.reshape(k, -1, 3)
    back = chunks.reshape(-1, 3)[:n]
    np.testing.assert_array_equal(np.asarray(back), np.asarray(g))


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 16))
def test_rope_preserves_norm(b, s):
    from repro.models.common import apply_rope
    x = jax.random.normal(jax.random.key(b * 31 + s), (b, s, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    y = apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)
