"""Per-program attribution, anomaly detection, and the bench-regression
gate: ProgramProfile cost capture + gauge math, StreamDetector /
FleetDetector firing rules, detection-driven straggler marking through
``elastic_train`` (multi-device subprocess), the ``benchmarks/history``
comparator tolerance bands, ``run.py --check`` wiring, and the
adversarial-input contracts of the validators (diagnostics, never
tracebacks)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.telemetry import anomaly, metrics, profile, trace
from repro.telemetry.schema import (SCHEMA_VERSION, validate_bench_obj,
                                    validate_metrics_jsonl, validate_record,
                                    validate_trace)

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.abspath(_ROOT))     # for `import benchmarks.*`


@pytest.fixture(autouse=True)
def _fresh_telemetry():
    was = telemetry.enabled()
    telemetry.reset()
    trace.reset()
    telemetry.set_enabled(True)
    yield
    telemetry.set_enabled(was)
    telemetry.reset()
    trace.reset()


# ---------------------------------------------------------------------------
# ProgramProfile: capture -> observe -> gauges
# ---------------------------------------------------------------------------

def test_capture_records_cost_and_join_emits_gauges(monkeypatch):
    @jax.jit
    def f(x):
        return x @ x

    x = jnp.ones((64, 64), jnp.float32)
    prof = profile.capture("test/prog", f, x, coll_bytes=1e6)
    assert prof is not None and prof.captured
    # 64^3 * 2 flops for a square matmul
    assert prof.flops == pytest.approx(2 * 64 ** 3, rel=0.25)
    assert prof.hbm_bytes > 0
    assert prof.coll_bytes == 1e6

    profile.observe("test/prog", 0.010)
    profile.observe("test/prog", 0.020)
    assert prof.calls == 2
    assert prof.mean_time_s == pytest.approx(0.015)
    assert prof.achieved_flops_s == pytest.approx(prof.flops / 0.015)

    # MFU divides by the env-overridable peak model
    monkeypatch.setenv("REPRO_PEAK_FLOPS", "1e9")
    rl = prof.roofline()
    assert rl["mfu"] == pytest.approx(prof.achieved_flops_s / 1e9)
    assert rl["t_roofline_s"] > 0 and rl["bound"] in ("compute", "memory",
                                                      "collective")

    profile.emit()
    reg = telemetry.default_registry()
    for q in ("flops", "hbm_bytes", "coll_bytes", "calls", "mean_time_s",
              "achieved_flops_s", "mfu", "achieved_coll_bw"):
        assert reg[f"profile/test_prog/{q}"].value is not None
    assert reg["profile/test_prog/flops"].value == prof.flops


def test_capture_failure_is_a_counter_not_an_exception():
    class Broken:
        def lower(self, *a, **k):
            raise RuntimeError("no lowering for you")

    assert profile.capture("test/broken", Broken()) is None
    reg = telemetry.default_registry()
    assert reg["profile/capture_errors"].value == 1
    assert "capture_error" in profile.get("test/broken").meta


def test_instrument_first_call_records_compile_time_and_passthrough():
    calls = []

    @jax.jit
    def f(x):
        calls.append(1)
        return x * 2

    w = profile.instrument("test/instr", f)
    x = jnp.arange(8.0)
    y1, y2 = w(x), w(x)
    assert np.array_equal(np.asarray(y1), np.asarray(y2))
    prof = profile.get("test/instr")
    assert prof.captured and prof.compile_time_s > 0
    assert len(calls) == 1          # lower() shared the jit trace cache
    profile.emit()
    assert telemetry.default_registry()["compile/test_instr_s"].value > 0


def test_profile_disabled_by_config_knob():
    telemetry.configure(profile=False)
    try:
        assert not profile.enabled()

        @jax.jit
        def f(x):
            return x + 1

        assert profile.capture("test/off", f, jnp.ones(4)) is None
        profile.observe("test/off", 1.0)
        assert profile.get("test/off") is None
    finally:
        telemetry.configure(profile=True)


def test_instrument_leaves_jitted_program_bytes_identical():
    """The attribution wrapper must never alter the program: lowered text
    of the wrapped jit is identical with profiling on and off."""
    def g(x):
        return jnp.sin(x) * x

    x = jax.ShapeDtypeStruct((16,), jnp.float32)
    telemetry.configure(profile=True)
    on = jax.jit(g).lower(x).as_text()
    telemetry.configure(profile=False)
    off = jax.jit(g).lower(x).as_text()
    telemetry.configure(profile=True)
    assert on == off


# ---------------------------------------------------------------------------
# StreamDetector: spikes + regressions
# ---------------------------------------------------------------------------

def test_stream_detector_flags_spike_not_steady_state():
    det = anomaly.StreamDetector("test/stream", min_n=8, spike_z=8.0)
    rng = np.random.default_rng(0)
    for _ in range(32):
        r = det.observe(0.1 + rng.uniform(-0.001, 0.001))
        assert not r["spike"]
    r = det.observe(1.0)            # 10x step time
    assert r["spike"] and r["z"] > 8.0
    assert det.spikes == 1
    reg = telemetry.default_registry()
    assert reg["anomaly/test_stream/spikes"].value == 1
    assert any(e[1] == "anomaly/spike" for e in trace.events())


def test_stream_detector_regression_fires_once_then_reanchors():
    det = anomaly.StreamDetector("test/reg", min_n=4, patience=3,
                                 regress_tol=0.5, spike_z=1e9)
    for _ in range(16):
        det.observe(0.1)
    fired = [det.observe(0.2)["regression"] for _ in range(30)]
    assert sum(fired) == 1          # re-anchor: sustained shift reports once
    assert det.regressions == 1


def test_stream_detector_silent_when_disabled():
    det = anomaly.StreamDetector("test/off")
    telemetry.set_enabled(False)
    for _ in range(64):
        r = det.observe(0.1)
    r = det.observe(100.0)
    assert not r["spike"] and det.spikes == 0


# ---------------------------------------------------------------------------
# FleetDetector: cross-sectional stragglers
# ---------------------------------------------------------------------------

def test_fleet_detector_flags_relative_outlier_with_tied_fleet():
    det = anomaly.FleetDetector()
    # MAD = 0 (everyone ties): the relative arm must still catch 8x
    assert det.observe({0: 0.1, 1: 0.1, 2: 0.1, 3: 0.8}) == [3]
    assert det.observe({0: 0.1, 1: 0.1, 2: 0.1, 3: 0.1}) == []
    # 2x is inside rel_thresh=3 — not a straggler
    assert det.observe({0: 0.1, 1: 0.1, 2: 0.1, 3: 0.2}) == []


def test_fleet_detector_respects_min_workers_and_patience():
    det = anomaly.FleetDetector(patience=2)
    assert det.observe({0: 0.1, 1: 0.9}) == []          # < min_workers
    d3 = {0: 0.1, 1: 0.1, 2: 0.1, 3: 0.9}
    assert det.observe(d3) == []                         # streak 1 < 2
    assert det.observe(d3) == [3]                        # streak 2
    ok = {0: 0.1, 1: 0.1, 2: 0.1, 3: 0.1}
    det.observe(ok)                                      # streak resets
    assert det.observe(d3) == []


def test_mark_straggling_counts_observed_separately():
    from repro.fault.membership import MembershipController, WorkerState
    c = MembershipController([0, 1, 2, 3], alpha=0.5)
    assert c.mark_straggling(3, 2)
    assert c.state_of(3) == WorkerState.STRAGGLING
    assert c.observed_straggles == 1
    assert 3 not in c.reporting()
    assert not c.mark_straggling(9)      # unknown worker: no count
    assert c.observed_straggles == 1


# ---------------------------------------------------------------------------
# "slow" fault kind + detection through elastic_train (subprocess, 8 dev)
# ---------------------------------------------------------------------------

def test_slow_fault_event_spec_roundtrip_and_validation():
    from repro.fault.inject import FaultEvent, FaultPlan
    plan = FaultPlan.from_spec("slow:2@4x3,kill:1@9")
    ev = plan.events_at(4)[0]
    assert ev.kind == "slow" and ev.rounds == 3 and ev.factor == 8.0
    assert plan.to_spec() == "slow:2@4x3,kill:1@9"
    with pytest.raises(ValueError):
        FaultEvent("slow", 0, 1, factor=0.5)


_SLOW_SCRIPT = r"""
import json, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np

from repro.configs import get_smoke_config
from repro.data.synthetic import LMTokenSource
from repro.models import build_model
from repro.optim import constant, sgd_momentum
from repro.train.engine import TrainPlan
from repro.fault.elastic import elastic_train
from repro.fault.membership import WorkerState

cfg = get_smoke_config("llama3.2-1b").with_overrides(
    vocab_size=64, d_ff=128, num_layers=2, dtype="float32")
model = build_model(cfg)
src = LMTokenSource(cfg.vocab_size, 16, seed=0)
batch_fn = lambda step, k: src.batch(4 * k, step)
plan = TrainPlan(algo="easgd", tau=2, alpha=0.5, exchanger="ar", quorum=2)

def run():
    return elastic_train(model, sgd_momentum(weight_decay=0.0),
                         constant(0.05), batch_fn, plan=plan,
                         num_workers=4, num_steps=16, seed=0,
                         fault_plan="slow:2@4x3", print_fn=None)

_, r1 = run()
_, r2 = run()
from repro.telemetry import trace
flag_steps = sorted(e[5]["step"] for e in trace.events()
                    if e[1] == "anomaly/straggler")
out = dict(slows=r1.slows, detected=r1.stragglers_detected,
           detected_replay=r2.stragglers_detected,
           straggles_injected=r1.straggles,
           flag_steps=flag_steps[:4],
           rounds_synced=r1.rounds_synced,
           final_workers=list(r1.final_workers))
print("RESULTS_JSON:" + json.dumps(out))
"""


def test_elastic_detects_injected_slowdown_within_three_rounds():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SLOW_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON:"):
            out = json.loads(line[len("RESULTS_JSON:"):])
    assert out is not None, proc.stdout[-2000:]
    assert out["slows"] == 1, out
    # the controller was never told ("straggle" was not injected) — the
    # detector discovered the slow worker from observed timing alone
    assert out["straggles_injected"] == 0, out
    assert out["detected"] >= 1, out
    # ...at the very first slowed step (well inside 3 tau rounds: the
    # slow window starts at step 4; 3 rounds of tau=2 end at step 9)
    assert out["flag_steps"] and out["flag_steps"][0] <= 9, out
    # deterministic: the replay flags identically and the fleet survives
    assert out["detected_replay"] == out["detected"], out
    assert out["final_workers"] == [0, 1, 2, 3], out


# ---------------------------------------------------------------------------
# train/serve integration: gauges for train step, decode step, exchange half
# ---------------------------------------------------------------------------

def test_train_loop_emits_program_and_compile_gauges():
    from repro.optim import constant, sgd_momentum
    from repro.train.loop import train
    from tests.test_engine import _batches, _mesh1, _tiny_lm

    cfg, model = _tiny_lm()
    mesh = _mesh1()
    n = 4
    train(model, sgd_momentum(), constant(0.01), mesh, _batches(cfg, n),
          num_steps=n, log_every=2, print_fn=lambda *a: None)
    profile.emit()
    reg = telemetry.default_registry()
    # train step: cost captured, steady-state durations joined, MFU out
    assert reg["profile/train_step/flops"].value > 0
    assert reg["profile/train_step/hbm_bytes"].value > 0
    assert reg["profile/train_step/calls"].value == n - 1
    assert reg["profile/train_step/mean_time_s"].value > 0
    assert reg["profile/train_step/mfu"].value > 0
    assert reg["compile/train_step_s"].value > 0
    # exchange halves: standalone jitted programs captured + micro-timed
    assert profile.get("exchange/rs") is not None
    assert profile.get("exchange/rs").captured
    assert reg["profile/exchange_rs/hbm_bytes"].value > 0
    assert reg["profile/exchange_rs/mfu"].value >= 0
    assert reg["compile/exchange_rs_s"].value > 0


def test_serve_engine_emits_decode_attribution():
    from tests.test_telemetry import _serve_run

    _, engine = _serve_run()
    profile.emit()
    reg = telemetry.default_registry()
    assert profile.get("serve/decode_step").captured
    assert reg["profile/serve_decode_step/flops"].value > 0
    assert reg["profile/serve_decode_step/mfu"].value > 0
    assert reg["compile/serve_decode_step_s"].value > 0
    assert profile.get("serve/prefill_chunk").captured
    assert reg["compile/serve_prefill_chunk_s"].value > 0
    # compile-once survives the lower() capture (shared trace cache)
    assert engine.trace_counts["decode"] == 1
    assert engine.trace_counts["prefill"] == 1


# ---------------------------------------------------------------------------
# history comparator + run.py --check
# ---------------------------------------------------------------------------

def _bench_obj(rows, quick=True):
    return {"schema_version": SCHEMA_VERSION,
            "run": {"host": "h", "backend": "cpu"},
            "quick": quick, "rows": rows}


def test_history_direction_heuristics():
    from benchmarks.history import direction
    assert direction("tok_s") == 1
    assert direction("decode_tok_s") == 1
    assert direction("speedup") == 1
    assert direction("continuous_over_static") == 1
    assert direction("achieved_bw") == 1
    assert direction("us_per_call") == -1
    assert direction("p50_ms") == -1
    assert direction("bwd_ms") == -1          # "bw" token must NOT match
    assert direction("compiles") == -1
    assert direction("workspace_bytes") == -1
    assert direction("exposed_ms") == -1
    assert direction("weird_quantity") == 0


def test_history_twenty_percent_tok_s_regression_fails():
    from benchmarks.history import compare, REGRESSED
    base = _bench_obj([{"name": "serve/engine", "us_per_call": 100.0,
                        "derived": "tok_s=100.0;p50_ms=1.0"}])
    bad = _bench_obj([{"name": "serve/engine", "us_per_call": 100.0,
                       "derived": "tok_s=80.0;p50_ms=1.0"}])
    verdicts = compare(base, bad, default_rtol=0.15)
    reg = {v.metric: v for v in verdicts if v.status == REGRESSED}
    assert "serve/engine.tok_s" in reg
    # the baseline against itself passes clean
    assert all(v.status != REGRESSED
               for v in compare(base, base, default_rtol=0.15))


def test_history_lower_better_and_tolerance_resolution():
    from benchmarks.history import compare, REGRESSED, OK
    base = _bench_obj([{"name": "x", "us_per_call": 100.0,
                        "derived": "compiles=1"}])
    slow = _bench_obj([{"name": "x", "us_per_call": 200.0,
                        "derived": "compiles=2"}])
    v = {x.metric: x for x in compare(base, slow, default_rtol=0.15)}
    assert v["x.us_per_call"].status == REGRESSED
    assert v["x.compiles"].status == REGRESSED
    # bare-key tolerance entry loosens one metric, not the other
    v = {x.metric: x for x in compare(
        base, slow, default_rtol=0.15,
        per_metric={"us_per_call": 2.0})}
    assert v["x.us_per_call"].status == OK
    assert v["x.compiles"].status == REGRESSED


def test_history_missing_and_new_metrics_do_not_gate():
    from benchmarks.history import compare, MISSING, NEW, REGRESSED
    base = _bench_obj([{"name": "a", "us_per_call": 1.0, "derived": ""}])
    new = _bench_obj([{"name": "b", "us_per_call": 1.0, "derived": ""}])
    verdicts = compare(base, new)
    statuses = {v.metric: v.status for v in verdicts}
    assert statuses["a.us_per_call"] == MISSING
    assert statuses["b.us_per_call"] == NEW
    assert not any(v.status == REGRESSED for v in verdicts)


def test_history_error_rows_dropped_and_cli_gate(tmp_path):
    from benchmarks.history import main, metrics_of
    base = _bench_obj([{"name": "a", "us_per_call": 10.0,
                        "derived": "tok_s=50"},
                       {"name": "comm/ERROR", "us_per_call": 0,
                        "derived": "RuntimeError:boom"}])
    assert "comm/ERROR.us_per_call" not in metrics_of(base)
    bad = _bench_obj([{"name": "a", "us_per_call": 10.0,
                       "derived": "tok_s=10"}])
    bdir = tmp_path / "baselines"
    bdir.mkdir()
    (bdir / "BENCH_quick_cpu.json").write_text(json.dumps(base))
    new_p = tmp_path / "new.json"
    new_p.write_text(json.dumps(bad))
    assert main([str(new_p), "--baselines", str(bdir)]) == 1
    ok_p = tmp_path / "same.json"
    ok_p.write_text(json.dumps(base))
    assert main([str(ok_p), "--baselines", str(bdir)]) == 0
    # --rtol override loosens the gate (the CI loose-CPU-tolerances mode)
    assert main([str(new_p), "--baselines", str(bdir), "--rtol", "10"]) == 0


def test_run_check_against_dir_no_baseline_passes(tmp_path):
    from benchmarks.history import check_against_dir
    ok, verdicts, path = check_against_dir(_bench_obj([]), str(tmp_path))
    assert ok and verdicts == [] and "BENCH_quick_cpu" in path


def test_committed_baseline_within_own_tolerances():
    """The committed baseline must pass --check against itself with the
    committed tolerance file (what CI's bench-regression job relies on)."""
    from benchmarks.history import check_against_dir
    bdir = os.path.join(_ROOT, "benchmarks", "baselines")
    base_p = os.path.join(bdir, "BENCH_quick_cpu.json")
    assert os.path.exists(base_p), "committed quick baseline missing"
    with open(base_p) as f:
        obj = json.load(f)
    assert not validate_bench_obj(obj), validate_bench_obj(obj)
    ok, verdicts, _ = check_against_dir(obj, bdir)
    assert ok, [v.line() for v in verdicts if v.status == "regressed"]
    assert verdicts, "baseline compared against nothing"


# ---------------------------------------------------------------------------
# adversarial validator inputs: diagnostics, never tracebacks
# ---------------------------------------------------------------------------

def test_validate_jsonl_truncated_line_is_a_diagnostic(tmp_path):
    p = tmp_path / "m.jsonl"
    good = json.dumps({"schema_version": SCHEMA_VERSION, "kind": "run",
                       "ts": 1.0, "run": {"host": "h", "backend": "cpu"}})
    line = json.dumps({"schema_version": SCHEMA_VERSION, "kind": "counter",
                       "ts": 1.0, "name": "a/b", "value": 3})
    p.write_text(good + "\n" + line[: len(line) // 2] + "\n")
    errs = validate_metrics_jsonl(str(p))
    assert errs and any("bad json" in e for e in errs)


def test_validate_unknown_schema_version_is_a_diagnostic():
    errs = validate_record({"schema_version": 999, "kind": "counter",
                            "ts": 1.0, "name": "x", "value": 1})
    assert any("schema_version" in e for e in errs)


def test_validate_histogram_nonnumeric_bounds_no_traceback():
    rec = {"schema_version": SCHEMA_VERSION, "kind": "histogram", "ts": 1.0,
           "name": "h", "bounds": ["a", None], "counts": [0, 0, 0],
           "count": 0, "sum": 0.0, "min": 0.0, "max": 0.0}
    errs = validate_record(rec)
    assert any("non-numeric histogram bounds" in e for e in errs)
    rec2 = dict(rec, bounds=[1.0, 2.0], counts=[0, "x", 0])
    assert any("non-integer histogram counts" in e
               for e in validate_record(rec2))


def test_validate_trace_async_end_before_begin(tmp_path):
    p = tmp_path / "t.json"
    ev = {"name": "s", "ph": "e", "pid": 1, "tid": 1, "ts": 1.0, "id": 7}
    p.write_text(json.dumps({
        "traceEvents": [ev],
        "otherData": {"schema_version": SCHEMA_VERSION,
                      "run": {"backend": "cpu"}}}))
    errs = validate_trace(str(p))
    assert any("async end before begin" in e for e in errs)
    # balanced begin/end is clean
    b = dict(ev, ph="b")
    p.write_text(json.dumps({
        "traceEvents": [b, ev],
        "otherData": {"schema_version": SCHEMA_VERSION,
                      "run": {"backend": "cpu"}}}))
    assert validate_trace(str(p)) == []


def test_validate_trace_events_not_a_list(tmp_path):
    p = tmp_path / "t.json"
    p.write_text(json.dumps({"traceEvents": {"oops": 1}}))
    errs = validate_trace(str(p))
    assert errs and "not a list" in errs[0]


def test_validate_bench_obj_rejects_malformed_rows():
    obj = _bench_obj([{"name": "a", "us_per_call": "fast"}])
    assert any("us_per_call" in e for e in validate_bench_obj(obj))
    assert validate_bench_obj("nope")           # not even a dict
    assert not validate_bench_obj(
        _bench_obj([{"name": "a", "us_per_call": 1.0, "derived": ""}]))


# ---------------------------------------------------------------------------
# report CLI renders from real artifacts
# ---------------------------------------------------------------------------

def test_report_renders_programs_anomalies_and_percentiles(tmp_path):
    from repro.telemetry import report as report_mod

    reg = telemetry.default_registry()
    reg.counter("train/steps").inc(10)
    h = reg.histogram("train/step_time_s")
    for v in (0.01, 0.011, 0.012, 0.5):
        h.observe(v)
    reg.counter("anomaly/train_step_time/spikes").inc()
    metrics.info("train/plan", algo="bsp")

    @jax.jit
    def f(x):
        return x @ x

    profile.capture("train/step", f, jnp.ones((32, 32)))
    profile.observe("train/step", 0.01)

    mpath = tmp_path / "m.jsonl"
    telemetry.dump_metrics(str(mpath))
    assert validate_metrics_jsonl(str(mpath)) == []

    with trace.span("train/step"):
        pass
    tpath = tmp_path / "t.json"
    trace.export(str(tpath))

    bpath = tmp_path / "b.json"
    bpath.write_text(json.dumps(_bench_obj(
        [{"name": "x", "us_per_call": 5.0, "derived": "tok_s=9"}])))

    out = tmp_path / "HEALTH.md"
    rc = report_mod.main([str(mpath), "--trace", str(tpath),
                          "--bench", str(bpath), "--out", str(out)])
    assert rc == 0
    md = out.read_text()
    assert "# Run health report" in md
    assert "## Programs" in md and "train/step" in md
    assert "## anomaly" in md
    assert "## train" in md and "p50=" in md and "p99=" in md
    assert "## Top spans" in md
    assert "## Bench rows" in md and "tok_s=9" in md


def test_report_percentile_matches_live_histogram():
    from repro.telemetry.report import _hist_percentile
    from repro.telemetry.registry import Histogram

    h = Histogram("x")
    rng = np.random.default_rng(3)
    for v in rng.lognormal(-4, 1, size=500):
        h.observe(float(v))
    rec = h.snapshot()
    for q in (50, 90, 99):
        assert _hist_percentile(rec, q) == pytest.approx(h.percentile(q))
