"""Serving benchmark: continuous batching, paged-cache density, and
prefix-cache TTFT vs the static/contiguous references.

A mixed workload (Poisson prompt lengths, strongly bimodal output lengths,
and a shared-prefix request class — the shape real traffic has: most
requests carry a common system-prompt head) is served several ways with
the *same* compiled decode step:

- ``static``     : requests grouped FIFO into batches of ``max_slots``;
                   each group runs until its longest member finishes
                   (finished lanes idle — classic static batching)
- ``continuous`` : all requests queued at once; finished lanes are evicted
                   mid-flight and refilled from the queue
- ``paged``      : the page-pool engine at a *fixed cache HBM budget* —
                   the rows that provision N contiguous worst-case slots
                   re-cut into pages host 2N+ concurrent slots, because
                   admission reserves what a request actually needs, not
                   ``max_seq``
- ``prefix``     : shared-prefix requests served twice — cold (pages
                   computed) then warm (pages reused by refcount) — the
                   hashed-prefix-cache TTFT win

Useful-token throughput (only requested tokens count) and per-token
latency percentiles come from the engine's step clock. The decode step
must compile exactly once across all the churn — the ``compiles`` field
in the derived column is the recompile regression guard; the density and
prefix rows gate ``slots_speedup`` / ``hit_frac`` / ``ttft_speedup``
through the same tolerance machinery.

Rows:
- serve/continuous    : steady-state tok/s + p50/p99 per-token latency
- serve/static        : same for the static-batch reference
- serve/speedup       : continuous over static (the >= 1.5x acceptance bar)
- serve/prefill       : chunked prefill throughput (tok/s)
- serve/ttft          : submit -> first-token percentiles + queue waits
- serve/paged_density : concurrent slots at fixed cache rows, paged over
                        contiguous (the >= 2x acceptance bar) + useful
                        tok/s at that density
- serve/prefix_ttft   : warm-over-cold TTFT speedup + prompt fraction
                        served from cache on the warm pass
- serve/slo_goodput   : adversarial flood (hog requests with hopeless
                        deadlines burying short feasible ones) served with
                        guardrails on vs off; goodput = tokens delivered
                        within deadline per second. The >= 1.3x
                        goodput_speedup is the SLO acceptance bar — the
                        guarded engine sheds/cancels the hogs at step
                        boundaries instead of burning slots on work nobody
                        can use, and p99 token latency stays bounded.
"""
import numpy as np

_PREFIX_LEN = 16   # shared head (page-aligned at page_size 8/16)


def _workload(n_req: int, vocab: int, seed: int = 0):
    """Mixed traffic: Poisson prompts, bimodal outputs, and every fourth
    request carrying the same ``_PREFIX_LEN``-token head (system-prompt
    class) over a unique tail."""
    rng = np.random.RandomState(seed)
    lens = np.maximum(1, rng.poisson(8, n_req))
    news = np.where(np.arange(n_req) % 2 == 0, 4, 32)   # bimodal outputs
    shared = rng.randint(0, vocab, size=_PREFIX_LEN).tolist()
    prompts = []
    for i, n in enumerate(lens):
        body = rng.randint(0, vocab, size=int(n)).tolist()
        prompts.append(shared + body if i % 4 == 0 else body)
    need = int(max(len(p) + int(m) for p, m in zip(prompts, news)))
    return prompts, news, need


def _serve(eng, prompts, news, *, continuous: bool, slots: int):
    import time
    from repro.serve import SamplingParams
    t0 = time.perf_counter()
    if continuous:
        rids = [eng.submit(p, int(m), SamplingParams())
                for p, m in zip(prompts, news)]
        eng.run()
    else:
        rids = []
        for g in range(0, len(prompts), slots):
            rids += [eng.submit(p, int(m), SamplingParams())
                     for p, m in zip(prompts[g:g + slots],
                                     news[g:g + slots])]
            eng.run()          # drain the group before admitting the next
    return time.perf_counter() - t0, rids


def _run_peak(eng, prompts, news):
    """Drive to completion tracking peak concurrent active slots."""
    import time
    from repro.serve import SamplingParams
    for p, m in zip(prompts, news):
        eng.submit(p, int(m), SamplingParams())
    peak = 0
    t0 = time.perf_counter()
    while eng.sched.has_work():
        eng.step()
        peak = max(peak, eng.sched.num_active)
    return time.perf_counter() - t0, peak


def run(quick: bool = False):
    import jax
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serve import Engine, SamplingParams

    arch = "llama3.2-1b"
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_req = 8 if quick else 16
    slots = 4
    prompts, news, need = _workload(n_req, cfg.vocab_size)
    chunk = 16
    kw = dict(max_slots=slots, max_seq=need, prefill_chunk=chunk)

    def make_engine(**over):
        # jit caches are per-instance: warm each engine (compile prefill/
        # decode/sample at the measurement shapes), then zero its clock
        eng = Engine(model, params, **{**kw, **over})
        eng.submit(prompts[0], 2, SamplingParams())
        eng.run()
        eng.reset_stats()
        return eng

    useful = int(np.sum(news))
    rows = []

    eng_c = make_engine()
    dt_c, _ = _serve(eng_c, prompts, news, continuous=True, slots=slots)
    lat = eng_c.stats.token_latency_percentiles()
    tok_s_c = useful / dt_c
    rows.append((f"serve/continuous/{arch}", dt_c / useful * 1e6,
                 f"tok_s={tok_s_c:.1f};p50_ms={lat[50] * 1e3:.2f};"
                 f"p99_ms={lat[99] * 1e3:.2f};"
                 f"compiles={eng_c.trace_counts['decode']}"))

    eng_s = make_engine()
    dt_s, _ = _serve(eng_s, prompts, news, continuous=False, slots=slots)
    lat_s = eng_s.stats.token_latency_percentiles()
    tok_s_s = useful / dt_s
    rows.append((f"serve/static/{arch}", dt_s / useful * 1e6,
                 f"tok_s={tok_s_s:.1f};p50_ms={lat_s[50] * 1e3:.2f};"
                 f"p99_ms={lat_s[99] * 1e3:.2f}"))

    rows.append((f"serve/speedup/{arch}", 0.0,
                 f"continuous_over_static={tok_s_c / tok_s_s:.2f}"))

    st = eng_c.stats
    rows.append((f"serve/prefill/{arch}", st.prefill_time
                 / max(st.prefill_tokens, 1) * 1e6,
                 f"tok_s={st.prefill_tok_s():.1f};chunk={chunk}"))

    # request-level latency: submit -> first token (continuous mode queues
    # everything at once, so TTFT here is dominated by queue wait — the
    # depth-of-queue picture a static batcher can't see per request)
    ttft = st.ttft_percentiles()
    qw = st.queue_wait_percentiles()
    rows.append((f"serve/ttft/{arch}", ttft[50] * 1e6,
                 f"p50_ms={ttft[50] * 1e3:.2f};p99_ms={ttft[99] * 1e3:.2f};"
                 f"queue_p50_ms={qw[50] * 1e3:.2f};"
                 f"queue_p99_ms={qw[99] * 1e3:.2f};"
                 f"admitted={st.admissions};evicted={st.evictions}"))

    # --- paged density at fixed cache HBM -------------------------------
    # Provision the contiguous pool worst-case (max_seq=256 per slot, the
    # way a static server must) and count its cache rows; give the paged
    # engine exactly those rows as pages and twice the slots. Every
    # request in this workload uses far less than 256 rows, so admission
    # reservations let all 2N lanes fill — the density win the page pool
    # exists for.
    provision = 256
    page = 16
    eng_base = make_engine(max_seq=provision, page_size=0)
    cache_rows = slots * eng_base.max_seq
    dt_b, peak_b = _run_peak(eng_base, prompts, news)
    eng_p = make_engine(max_seq=provision, page_size=page,
                        max_slots=2 * slots,
                        num_pages=cache_rows // page + 1)   # +1: null page
    dt_p, peak_p = _run_peak(eng_p, prompts, news)
    rows.append((f"serve/paged_density/{arch}", dt_p / useful * 1e6,
                 f"slots_speedup={peak_p / max(peak_b, 1):.2f};"
                 f"peak_active={peak_p};cache_rows={cache_rows};"
                 f"tok_s={useful / dt_p:.1f};"
                 f"page_occupancy={eng_p.allocator.occupancy():.3f};"
                 f"compiles={eng_p.trace_counts['decode']}"))

    # --- prefix-cache TTFT: cold pages vs refcounted reuse --------------
    # One long shared prompt served cold (pages computed + published),
    # then the same prompt class served warm: admission installs the hit
    # pages and prefill runs only the unseen tail (a full hit re-runs one
    # token for its logits). TTFT drops by roughly the prompt/chunk count.
    rng = np.random.RandomState(7)
    head = rng.randint(0, cfg.vocab_size, size=48).tolist()   # 3 chunks
    tails = [rng.randint(0, cfg.vocab_size, size=8).tolist()
             for _ in range(4)]
    eng_x = make_engine(max_seq=128, page_size=page)
    for t in [[]] + tails[:1]:        # cold: head and head+tail once each
        eng_x.submit(head + t, 4, SamplingParams())
        eng_x.run()
    cold = eng_x.stats.ttft_percentiles()[50]
    hit0 = eng_x.allocator.hit_tokens
    eng_x.reset_stats()
    warm_prompts = [head] + [head + t for t in tails]
    warm_tok = sum(len(p) for p in warm_prompts)
    for p in warm_prompts:            # warm: every head page is cached
        eng_x.submit(p, 4, SamplingParams())
        eng_x.run()
    warm = eng_x.stats.ttft_percentiles()[50]
    hit_tok = eng_x.allocator.hit_tokens - hit0
    rows.append((f"serve/prefix_ttft/{arch}", warm * 1e6,
                 f"ttft_speedup={cold / max(warm, 1e-9):.2f};"
                 f"hit_frac={hit_tok / warm_tok:.3f};"
                 f"cow_copies={eng_x.allocator.cow_copies};"
                 f"compiles={eng_x.trace_counts['decode']}"))

    # --- SLO goodput under adversarial flood ----------------------------
    # Hogs ask for long outputs under a deadline they can never meet; the
    # shorts behind them are entirely feasible. Without guardrails every
    # hog burns its full decode budget for tokens that miss the deadline;
    # with guardrails hogs are shed from the queue / cancelled at the
    # first step boundary past deadline, so the engine's time goes to
    # deliverable tokens. Both engines share the compiled decode step.
    rng = np.random.RandomState(3)
    n_hog, n_short = (4, 4) if quick else (6, 6)
    flood = []
    for i in range(n_hog + n_short):
        if i % 2 == 0 and i // 2 < n_hog:           # interleave arrivals
            flood.append((rng.randint(0, cfg.vocab_size, 6).tolist(),
                          48, 1.0))                 # hog: hopeless budget
        else:
            flood.append((rng.randint(0, cfg.vocab_size, 6).tolist(),
                          8, 10_000.0))             # short: generous
    import time as _time

    def _flood(guard: bool):
        eng = make_engine(max_seq=64, guardrails=guard)
        for p, m, dl in flood:
            eng.submit(p, m, SamplingParams(), deadline_ms=dl)
        t0 = _time.perf_counter()
        eng.run()
        return _time.perf_counter() - t0, eng

    dt_g, eng_g = _flood(True)
    dt_n, eng_n = _flood(False)
    gp_g = eng_g.stats.goodput_tokens / dt_g
    gp_n = eng_n.stats.goodput_tokens / max(dt_n, 1e-9)
    lat_g = eng_g.stats.token_latency_percentiles()
    rows.append((f"serve/slo_goodput/{arch}", dt_g * 1e6,
                 f"goodput_speedup={gp_g / max(gp_n, 1e-9):.2f};"
                 f"goodput_tok_s={gp_g:.1f};"
                 f"p99_ms={lat_g[99] * 1e3:.2f};"
                 f"shed={eng_g.stats.shed};"
                 f"cancelled={eng_g.stats.cancelled};"
                 f"compiles={eng_g.trace_counts['decode']}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run(quick=True):
        print(f"{name},{us:.1f},{derived}")
