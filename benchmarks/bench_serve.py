"""Serving benchmark: continuous batching vs the static-batch reference.

A mixed workload (Poisson prompt lengths, strongly bimodal output lengths
— the shape real traffic has) is served two ways with the *same* compiled
decode step:

- ``static``     : requests grouped FIFO into batches of ``max_slots``;
                   each group runs until its longest member finishes
                   (finished lanes idle — classic static batching)
- ``continuous`` : all requests queued at once; finished lanes are evicted
                   mid-flight and refilled from the queue

Useful-token throughput (only requested tokens count) and per-token
latency percentiles come from the engine's step clock. The decode step
must compile exactly once across all the churn — the ``compiles`` field
in the derived column is the recompile regression guard.

Rows:
- serve/continuous : steady-state tok/s + p50/p99 per-token latency
- serve/static     : same for the static-batch reference
- serve/speedup    : continuous over static (the >= 1.5x acceptance bar)
- serve/prefill    : chunked prefill throughput (tok/s)
"""
import numpy as np


def _workload(n_req: int, vocab: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    lens = np.maximum(1, rng.poisson(8, n_req))
    news = np.where(np.arange(n_req) % 2 == 0, 4, 32)   # bimodal outputs
    prompts = [rng.randint(0, vocab, size=int(n)).tolist() for n in lens]
    return prompts, news, int((lens + news).max())


def _serve(eng, prompts, news, *, continuous: bool, slots: int):
    import time
    from repro.serve import SamplingParams
    t0 = time.perf_counter()
    if continuous:
        rids = [eng.submit(p, int(m), SamplingParams())
                for p, m in zip(prompts, news)]
        eng.run()
    else:
        rids = []
        for g in range(0, len(prompts), slots):
            rids += [eng.submit(p, int(m), SamplingParams())
                     for p, m in zip(prompts[g:g + slots],
                                     news[g:g + slots])]
            eng.run()          # drain the group before admitting the next
    return time.perf_counter() - t0, rids


def run(quick: bool = False):
    import jax
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serve import Engine, SamplingParams

    arch = "llama3.2-1b"
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_req = 8 if quick else 16
    slots = 4
    prompts, news, need = _workload(n_req, cfg.vocab_size)
    chunk = 16
    kw = dict(max_slots=slots, max_seq=need, prefill_chunk=chunk)

    def make_engine():
        # jit caches are per-instance: warm each engine (compile prefill/
        # decode/sample at the measurement shapes), then zero its clock
        eng = Engine(model, params, **kw)
        eng.submit(prompts[0], 2, SamplingParams())
        eng.run()
        eng.reset_stats()
        return eng

    useful = int(np.sum(news))
    rows = []

    eng_c = make_engine()
    dt_c, _ = _serve(eng_c, prompts, news, continuous=True, slots=slots)
    lat = eng_c.stats.token_latency_percentiles()
    tok_s_c = useful / dt_c
    rows.append((f"serve/continuous/{arch}", dt_c / useful * 1e6,
                 f"tok_s={tok_s_c:.1f};p50_ms={lat[50] * 1e3:.2f};"
                 f"p99_ms={lat[99] * 1e3:.2f};"
                 f"compiles={eng_c.trace_counts['decode']}"))

    eng_s = make_engine()
    dt_s, _ = _serve(eng_s, prompts, news, continuous=False, slots=slots)
    lat_s = eng_s.stats.token_latency_percentiles()
    tok_s_s = useful / dt_s
    rows.append((f"serve/static/{arch}", dt_s / useful * 1e6,
                 f"tok_s={tok_s_s:.1f};p50_ms={lat_s[50] * 1e3:.2f};"
                 f"p99_ms={lat_s[99] * 1e3:.2f}"))

    rows.append((f"serve/speedup/{arch}", 0.0,
                 f"continuous_over_static={tok_s_c / tok_s_s:.2f}"))

    st = eng_c.stats
    rows.append((f"serve/prefill/{arch}", st.prefill_time
                 / max(st.prefill_tokens, 1) * 1e6,
                 f"tok_s={st.prefill_tok_s():.1f};chunk={chunk}"))

    # request-level latency: submit -> first token (continuous mode queues
    # everything at once, so TTFT here is dominated by queue wait — the
    # depth-of-queue picture a static batcher can't see per request)
    ttft = st.ttft_percentiles()
    qw = st.queue_wait_percentiles()
    rows.append((f"serve/ttft/{arch}", ttft[50] * 1e6,
                 f"p50_ms={ttft[50] * 1e3:.2f};p99_ms={ttft[99] * 1e3:.2f};"
                 f"queue_p50_ms={qw[50] * 1e3:.2f};"
                 f"queue_p99_ms={qw[99] * 1e3:.2f};"
                 f"admitted={st.admissions};evicted={st.evictions}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run(quick=True):
        print(f"{name},{us:.1f},{derived}")
