"""Pallas kernel micro-bench (interpret mode on CPU — correctness-path
timing only; compiled TPU timing requires hardware). Derived: relative cost
vs the pure-jnp oracle."""
import time


def _time(fn, *args, reps=3):
    import jax
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref

    key = jax.random.key(0)
    rows = []
    k, n = 8, 1 << 16
    chunks = jax.random.normal(key, (k, n)).astype(jnp.bfloat16)
    us_k = _time(lambda x: ops.chunk_sum(x), chunks)
    us_r = _time(jax.jit(ref.chunk_sum_ref), chunks)
    rows.append(("kernels/chunk_sum_8x64k", us_k,
                 f"ref_us={us_r:.1f};ratio={us_k / us_r:.1f}"))

    x = jax.random.normal(key, (n,))
    us_k = _time(lambda v: ops.quant_int8(v), x)
    us_r = _time(jax.jit(ref.quant_int8_ref), x)
    rows.append(("kernels/quant_int8_64k", us_k,
                 f"ref_us={us_r:.1f};ratio={us_k / us_r:.1f}"))

    p = jax.random.normal(key, (n,))
    m = jnp.zeros((n,))
    us_k = _time(lambda a, b, c: ops.fused_sgd(a, b, c, 0.1), p, x, m)
    us_r = _time(jax.jit(lambda a, b, c: ref.fused_sgd_ref(a, b, c, 0.1)),
                 p, x, m)
    rows.append(("kernels/fused_sgd_64k", us_k,
                 f"ref_us={us_r:.1f};ratio={us_k / us_r:.1f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
