"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
- bench_comm     -> Fig 3 / Table 3 (exchange strategies + fused
                    RS->update->AG step pipelines)
- bench_overlap  -> §3.2 overlap: exposed comm, overlapped vs serialized
- bench_scaling  -> Table 1 (speedup vs #workers)
- bench_easgd    -> §4 async (engine-driven EASGD/ASGD tau sweep, fp16-wire
                    center exchange through the shared exchanger layer)
- bench_loading  -> §3.3 Alg 1 (parallel loading)
- bench_kernels  -> kernel micro-bench
- bench_dist     -> sharding spec construction (repro.dist) on the largest
                    config; must stay off the compile hot path
- bench_serve    -> continuous-batching engine vs static-batch serving
                    (steady-state tok/s, p50/p99 token latency, recompile
                    guard)
- bench_attention-> flash (Pallas) vs XLA-einsum vs blockwise attention at
                    S in {512, 2048, 8192}: fwd / fwd+bwd tok/s, peak
                    workspace, achieved-vs-roofline, no-(S,S)-in-HLO guard
- bench_telemetry-> instrumentation overhead on a hot step loop: enabled
                    vs REPRO_TELEMETRY=0 no-op path (asserts the <1%
                    step-time contract), per-op costs

``--quick`` runs the CI smoke subset (bench_comm + bench_overlap +
bench_easgd + bench_serve + bench_attention at reduced scale); ``--json
PATH`` additionally writes the
rows as JSON so the perf trajectory accumulates as artifacts
(``BENCH_*.json`` — async throughput rows land alongside comm/overlap/
serve/attention). ``--check`` turns the run into a regression gate: rows
are diffed against ``benchmarks/baselines/`` through
``benchmarks/history.py`` tolerance bands and a regression exits nonzero.
"""
import argparse
import inspect
import json
import os
import sys
import traceback

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; the repo root is needed for `from benchmarks import ...` and
# src/ for the in-process benches (`repro` may not be pip-installed)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke subset: bench_comm + bench_overlap + "
                         "bench_easgd + bench_serve + bench_attention at "
                         "reduced scale")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (perf-trajectory "
                         "artifact)")
    ap.add_argument("--check", action="store_true",
                    help="compare this run against the committed baseline "
                         "(benchmarks/baselines) and exit nonzero on any "
                         "regression outside the tolerance bands")
    ap.add_argument("--baselines", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "baselines"),
        metavar="DIR", help="baseline directory for --check")
    ap.add_argument("--rtol", type=float, default=None,
                    help="override every --check tolerance band (CI uses "
                         "a loose value; committed tolerances are the "
                         "intent)")
    ap.add_argument("--metrics-out", default=None, metavar="JSONL",
                    help="dump telemetry metrics recorded during the "
                         "benches (incl. the serve engines' registries) "
                         "as schema'd JSONL")
    ap.add_argument("--trace-out", default=None, metavar="JSON",
                    help="export host-side spans from the benches as "
                         "Chrome-trace/Perfetto JSON")
    args = ap.parse_args()

    from benchmarks import (bench_attention, bench_comm, bench_dist,
                            bench_easgd, bench_fault, bench_kernels,
                            bench_loading, bench_overlap, bench_scaling,
                            bench_serve, bench_telemetry)
    if args.quick:
        modules = [("comm", bench_comm), ("overlap", bench_overlap),
                   ("easgd", bench_easgd), ("serve", bench_serve),
                   ("attention", bench_attention),
                   ("telemetry", bench_telemetry)]
    else:
        modules = [("comm", bench_comm), ("overlap", bench_overlap),
                   ("scaling", bench_scaling), ("easgd", bench_easgd),
                   ("loading", bench_loading), ("kernels", bench_kernels),
                   ("dist", bench_dist), ("serve", bench_serve),
                   ("attention", bench_attention),
                   ("telemetry", bench_telemetry),
                   ("fault", bench_fault)]
    print("name,us_per_call,derived")
    failed, rows = [], []
    for name, mod in modules:
        try:
            kw = ({"quick": True} if args.quick and
                  "quick" in inspect.signature(mod.run).parameters else {})
            for row_name, us, derived in mod.run(**kw):
                rows.append({"name": row_name, "us_per_call": us,
                             "derived": derived})
                print(f"{row_name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            rows.append({"name": f"{name}/ERROR", "us_per_call": 0,
                         "derived": f"{type(e).__name__}:{e}"})
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    obj = None
    if args.json or args.check:
        # same schema + run context as live-run telemetry (--metrics-out):
        # every BENCH_*.json is attributable to a host/device/backend and
        # comparable across PRs (validated by repro.telemetry.validate)
        from repro.telemetry.schema import SCHEMA_VERSION, run_context
        obj = {"schema_version": SCHEMA_VERSION, "run": run_context(),
               "quick": args.quick, "rows": rows}
    if args.json:
        # validate BEFORE writing: a malformed artifact must never land on
        # disk where the next PR's --check would trust it
        from repro.telemetry.schema import validate_bench_obj
        errs = validate_bench_obj(obj, args.json)
        if errs:
            for e in errs:
                print(f"bench schema: {e}", file=sys.stderr)
            sys.exit(1)
        with open(args.json, "w") as f:
            json.dump(obj, f, indent=1)
    regressed = False
    if args.check:
        from benchmarks.history import check_against_dir, render
        ok, verdicts, base_path = check_against_dir(obj, args.baselines,
                                                    rtol=args.rtol)
        if verdicts:
            print(f"== regression check vs {base_path} ==")
            print(render(verdicts, only_notable=True))
        else:
            print(f"regression check: no baseline at {base_path} — "
                  f"nothing to gate")
        regressed = not ok
    if args.metrics_out:
        from repro import telemetry
        telemetry.dump_metrics(args.metrics_out)
    if args.trace_out:
        from repro import telemetry
        telemetry.trace.export(args.trace_out)
    if failed or regressed:
        sys.exit(1)


if __name__ == "__main__":
    main()
