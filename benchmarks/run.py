"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
- bench_comm     -> Fig 3 / Table 3 (exchange strategies)
- bench_scaling  -> Table 1 (speedup vs #workers)
- bench_easgd    -> §4 async (EASGD overhead / tau)
- bench_loading  -> §3.3 Alg 1 (parallel loading)
- bench_kernels  -> kernel micro-bench
- bench_dist     -> sharding spec construction (repro.dist) on the largest
                    config; must stay off the compile hot path
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_comm, bench_dist, bench_easgd,
                            bench_kernels, bench_loading, bench_scaling)
    modules = [("comm", bench_comm), ("scaling", bench_scaling),
               ("easgd", bench_easgd), ("loading", bench_loading),
               ("kernels", bench_kernels), ("dist", bench_dist)]
    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules:
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
