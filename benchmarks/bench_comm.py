"""Fig 3 / Table 3: communication overhead of exchange strategies.

Exchanges gradient pytrees with the exact parameter counts of the paper's
models (AlexNet 61M / GoogLeNet 13.4M / VGG 138M) across 8 workers,
measuring (a) wall-clock per exchange on 8 host devices and (b) modeled
wire bytes parsed from the compiled HLO. One subprocess per model so the
8x-stacked gradients are freed between models (single-host memory).

Derived column: modeled-bytes speedup vs the AR baseline (the paper's
Table 3 reports 3x for ASA, ~6x for ASA16 vs Allreduce).
"""
import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.exchanger import get_exchanger
from repro.roofline.analysis import parse_collectives

MODELS = {
    # name -> parameter tensor shapes approximating the paper's models
    "alexnet": [(11*11*3, 96), (5*5*48, 256), (3*3*256, 384), (3*3*192, 384),
                (3*3*192, 256), (9216, 4096), (4096, 4096), (4096, 1000)],
    "googlenet": [(1024, 1000)] + [(480, 512)] * 24,
    "vggnet": [(3*3*64, 64), (3*3*128, 128), (3*3*256, 256), (3*3*512, 512),
               (25088, 4096), (4096, 4096), (4096, 1000)],
}

mname = sys.argv[1]
shapes = MODELS[mname]
mesh = jax.make_mesh((8,), ("data",))
jax.set_mesh(mesh)
key = jax.random.key(0)
rows = []
# split big tensors into <=8M-element pieces (DDP-style bucketing): XLA's
# CPU all-reduce materializes O(k^2) copies of each buffer, so >100MB
# leaves OOM the single-host 8-device simulation. Wire bytes unchanged.
MAX_ELEMS = 2 << 20
grads = {}
for i, s in enumerate(shapes):
    n = int(np.prod(s))
    pieces = max(1, -(-n // MAX_ELEMS))
    rows_per = s[0] // pieces if s[0] >= pieces else s[0]
    start = 0
    j = 0
    while start < s[0]:
        r = min(rows_per, s[0] - start)
        grads[f"p{i}_{j}"] = jax.random.normal(
            jax.random.fold_in(key, i * 100 + j),
            (8, r, *s[1:])).astype(jnp.float32)
        start += r
        j += 1
nparams = sum(int(np.prod(s)) for s in shapes)
base_bytes = None
strategies = ["ar", "asa", "asa16", "asa8"]
if nparams < 20e6:
    strategies.append("ring")   # unrolled 2(k-1) ppermute steps: too many
                                # live fp32 buffers for the 61M/138M models
                                # on a single-host 8-device CPU sim
for strat in strategies:
    ex = get_exchanger(strat)
    def f(gs):
        per = {n: v[0] for n, v in gs.items()}
        out = ex.exchange(per, "data")
        return {n: v[None] for n, v in out.items()}
    fn = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("data"),
                               out_specs=P("data"),
                               axis_names=frozenset({"data"}),
                               check_vma=False))
    compiled = fn.lower(grads).compile()
    st = parse_collectives(compiled.as_text())
    wire = st.total_bytes
    out = fn(grads); jax.block_until_ready(out)  # warm
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        out = fn(grads)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / reps * 1e6
    del out, fn, compiled
    if strat == "ar":
        base_bytes = wire or 1
    rows.append({"model": mname, "strategy": strat, "params": nparams,
                 "us_per_call": us, "wire_bytes": wire,
                 "modeled_speedup_vs_ar": base_bytes / max(wire, 1)})
print("RESULTS_JSON:" + json.dumps(rows))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = []
    for mname in ["alexnet", "googlenet", "vggnet"]:
        proc = subprocess.run([sys.executable, "-c", _SCRIPT, mname],
                              env=env, capture_output=True, text=True,
                              timeout=1800)
        if proc.returncode != 0:
            out.append((f"comm/{mname}/FAILED", 0.0,
                        f"rc={proc.returncode}"))
            continue
        rows = None
        for line in proc.stdout.splitlines():
            if line.startswith("RESULTS_JSON:"):
                rows = json.loads(line[len("RESULTS_JSON:"):])
        for r in rows:
            out.append((f"comm/{r['model']}/{r['strategy']}",
                        r["us_per_call"],
                        f"wire_bytes={r['wire_bytes']};"
                        f"modeled_speedup_vs_ar="
                        f"{r['modeled_speedup_vs_ar']:.2f}"))
    return out


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
