"""Fig 3 / Table 3: communication overhead of exchange strategies.

Exchanges gradient pytrees with the exact parameter counts of the paper's
models (AlexNet 61M / GoogLeNet 13.4M / VGG 138M) across 8 workers,
measuring (a) wall-clock per exchange on 8 host devices and (b) modeled
wire bytes parsed from the compiled HLO. One subprocess per model so the
8x-stacked gradients are freed between models (single-host memory).

Derived column: modeled-bytes speedup vs the AR baseline (the paper's
Table 3 reports 3x for ASA, ~6x for ASA16 vs Allreduce).

The ``asa16+{exchupd,updexch,rsupd}`` rows compare full *step* pipelines
(exchange + parameter update) for the sharded fused-update work:

- ``exchupd``: exchange gradients, update replicated (subgd);
- ``updexch``: update locally, exchange weights AND momentum (awagd /
  Krizhevsky — what Synkhronos fuses away);
- ``rsupd``  : RS -> shard update -> AG of updated params (fused path).

``rsupd`` matches ``exchupd``'s wire bytes (its win is the eliminated
full-gradient materialization, the 1/k update compute/state, and overlap
eligibility) and halves ``updexch``'s — momentum never touches the wire.
"""
import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.exchanger import get_exchanger
from repro.roofline.analysis import parse_collectives

MODELS = {
    # name -> parameter tensor shapes approximating the paper's models
    "alexnet": [(11*11*3, 96), (5*5*48, 256), (3*3*256, 384), (3*3*192, 384),
                (3*3*192, 256), (9216, 4096), (4096, 4096), (4096, 1000)],
    "googlenet": [(1024, 1000)] + [(480, 512)] * 24,
    "vggnet": [(3*3*64, 64), (3*3*128, 128), (3*3*256, 256), (3*3*512, 512),
               (25088, 4096), (4096, 4096), (4096, 1000)],
}

mname = sys.argv[1]
shapes = MODELS[mname]
mesh = jax.make_mesh((8,), ("data",))
jax.set_mesh(mesh)
key = jax.random.key(0)
rows = []
# split big tensors into <=8M-element pieces (DDP-style bucketing): XLA's
# CPU all-reduce materializes O(k^2) copies of each buffer, so >100MB
# leaves OOM the single-host 8-device simulation. Wire bytes unchanged.
MAX_ELEMS = 2 << 20
grads = {}
for i, s in enumerate(shapes):
    n = int(np.prod(s))
    pieces = max(1, -(-n // MAX_ELEMS))
    rows_per = s[0] // pieces if s[0] >= pieces else s[0]
    start = 0
    j = 0
    while start < s[0]:
        r = min(rows_per, s[0] - start)
        grads[f"p{i}_{j}"] = jax.random.normal(
            jax.random.fold_in(key, i * 100 + j),
            (8, r, *s[1:])).astype(jnp.float32)
        start += r
        j += 1
nparams = sum(int(np.prod(s)) for s in shapes)
base_bytes = None
strategies = ["ar", "asa", "asa16", "asa8"]
if nparams < 20e6:
    strategies.append("ring")   # unrolled 2(k-1) ppermute steps: too many
                                # live fp32 buffers for the 61M/138M models
                                # on a single-host 8-device CPU sim
for strat in strategies:
    ex = get_exchanger(strat)
    def f(gs):
        per = {n: v[0] for n, v in gs.items()}
        out = ex.exchange(per, "data")
        return {n: v[None] for n, v in out.items()}
    fn = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("data"),
                               out_specs=P("data"),
                               axis_names=frozenset({"data"}),
                               check_vma=False))
    compiled = fn.lower(grads).compile()
    st = parse_collectives(compiled.as_text())
    wire = st.total_bytes
    out = fn(grads); jax.block_until_ready(out)  # warm
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        out = fn(grads)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / reps * 1e6
    del out, fn, compiled
    if strat == "ar":
        base_bytes = wire or 1
    rows.append({"model": mname, "strategy": strat, "params": nparams,
                 "us_per_call": us, "wire_bytes": wire,
                 "modeled_speedup_vs_ar": base_bytes / max(wire, 1)})

# --- full-step pipelines: exchange-then-update vs update-then-exchange
# (awagd) vs the fused RS->update->AG path, for asa16 -----------------------
from repro.core.exchanger import Exchanger as _Ex, param_wire_dtype

ex = get_exchanger("asa16")
LR = jnp.float32(0.01)

def _upd(p, g):
    return p - LR * g            # momentum-SGD first step (m0 = 0 -> m1 = g)

def f_exchupd(gs):
    per = {n: v[0] for n, v in gs.items()}
    red = ex.exchange(per, "data")
    return {n: _upd(jnp.zeros_like(v), red[n])[None] for n, v in per.items()}

def f_updexch(gs):
    per = {n: v[0] for n, v in gs.items()}
    newp = ex.exchange({n: _upd(jnp.zeros_like(v), v)
                        for n, v in per.items()}, "data")
    newm = ex.exchange(per, "data")          # momentum after step 1 == grads
    return ({n: v[None] for n, v in newp.items()},
            {n: v[None] for n, v in newm.items()})

def f_rsupd(gs):
    per = {n: v[0] for n, v in gs.items()}
    plan = ex.plan_for(per, "data")
    res, _ = ex.reduce_scatter(per, "data", plan=plan)
    idx = jax.lax.axis_index("data")
    p_flats, p_smalls, _ = _Ex.pack(
        {n: jnp.zeros_like(v) for n, v in per.items()}, plan)
    new_flats = []
    for bi, b in enumerate(plan.buckets):
        p_sh = jax.lax.dynamic_slice(p_flats[bi], (idx * b.shard_len,),
                                     (b.shard_len,))
        new_flats.append(ex.all_gather(
            [_upd(p_sh, res["shards"][bi])], plan, "data",
            wire_dtype=param_wire_dtype(ex))[0])
    smalls = [_upd(s.astype(jnp.float32), g)
              for s, g in zip(p_smalls, res["full"])]
    out = _Ex.unpack(new_flats, smalls, plan)
    return {n: v[None] for n, v in out.items()}

vbytes = {}
for vname, vf, ospec in [("exchupd", f_exchupd, P("data")),
                         ("updexch", f_updexch, (P("data"), P("data"))),
                         ("rsupd", f_rsupd, P("data"))]:
    fn = jax.jit(jax.shard_map(vf, mesh=mesh, in_specs=P("data"),
                               out_specs=ospec,
                               axis_names=frozenset({"data"}),
                               check_vma=False))
    compiled = fn.lower(grads).compile()
    wire = parse_collectives(compiled.as_text()).total_bytes
    out = fn(grads); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(3):
        out = fn(grads)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / 3 * 1e6
    del out, fn, compiled
    vbytes[vname] = wire
    extra = ""
    if vname == "rsupd":
        extra = (f";rsupd_over_exchupd="
                 f"{wire / max(vbytes['exchupd'], 1):.2f}"
                 f";rsupd_over_updexch="
                 f"{wire / max(vbytes['updexch'], 1):.2f}")
    rows.append({"model": mname, "strategy": f"asa16+{vname}",
                 "params": nparams, "us_per_call": us, "wire_bytes": wire,
                 "modeled_speedup_vs_ar": base_bytes / max(wire, 1),
                 "extra": extra})
print("RESULTS_JSON:" + json.dumps(rows))
"""


def run(quick: bool = False):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = []
    models = ["alexnet"] if quick else ["alexnet", "googlenet", "vggnet"]
    for mname in models:
        proc = subprocess.run([sys.executable, "-c", _SCRIPT, mname],
                              env=env, capture_output=True, text=True,
                              timeout=1800)
        if proc.returncode != 0:
            out.append((f"comm/{mname}/FAILED", 0.0,
                        f"rc={proc.returncode}"))
            continue
        rows = None
        for line in proc.stdout.splitlines():
            if line.startswith("RESULTS_JSON:"):
                rows = json.loads(line[len("RESULTS_JSON:"):])
        for r in rows:
            out.append((f"comm/{r['model']}/{r['strategy']}",
                        r["us_per_call"],
                        f"wire_bytes={r['wire_bytes']};"
                        f"modeled_speedup_vs_ar="
                        f"{r['modeled_speedup_vs_ar']:.2f}"
                        + r.get("extra", "")))
    return out


if __name__ == "__main__":
    for name, us, derived in run(quick="--quick" in sys.argv):
        print(f"{name},{us:.1f},{derived}")
