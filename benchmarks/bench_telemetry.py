"""Telemetry overhead benchmark — pins the <1% step-time contract.

The per-step instrumentation pattern the train loop uses (1 span, 2
histogram observes, 3 counter incs) is timed precisely over many
thousands of calls, in enabled mode and in the ``REPRO_TELEMETRY=0``
no-op mode, and divided by a measured real step time (a jitted device
dispatch + sync). That ratio is the honest per-step delta:

- telemetry/overhead_on  : instr_cost / step_time, **asserted < 1%** —
  the acceptance contract for default-on telemetry
- telemetry/overhead_off : same for the no-op fast path, asserted < 1%
- telemetry/per_op       : ns per counter-inc / histogram-observe / span
  in enabled mode (the raw instrument costs, for budgeting new sites)
- telemetry/loop_delta   : the end-to-end cross-check — instrumented vs
  bare step loops, min-of-reps. Informational: at few-ms CPU step times
  the run-to-run noise floor exceeds the ~6us instrumentation signal,
  so this row reports the measured delta rather than asserting on it.

The step is a real device dispatch + sync so the ratio is against
genuine step time, not an empty loop; min-of-reps suppresses scheduler
noise.
"""
import time


def _time_calls(fn, arg, m):
    t0 = time.perf_counter()
    for _ in range(m):
        fn(arg)
    return (time.perf_counter() - t0) / m


def _loop(step_fn, x, n, instrument):
    """Time n dispatch+sync steps, calling ``instrument(dt)`` per step."""
    import jax
    t0 = time.perf_counter()
    for _ in range(n):
        t_i = time.perf_counter()
        x = step_fn(x)
        jax.block_until_ready(x)
        instrument(time.perf_counter() - t_i)
    return time.perf_counter() - t0, x


def run(quick: bool = False):
    import jax
    import jax.numpy as jnp

    from repro import telemetry
    from repro.telemetry import metrics, trace

    n = 60 if quick else 200
    reps = 3 if quick else 5
    m = 10_000 if quick else 50_000
    d = 512

    @jax.jit
    def step(x):
        return jnp.tanh(x @ x) * 0.5 + x * 0.5

    x0 = jnp.ones((d, d), jnp.float32) / d
    jax.block_until_ready(step(x0))      # compile outside the clock

    def bare_instr(dt):
        pass

    def full_instr(dt):
        # the per-step pattern train/loop.py uses
        with trace.span("bench/step"):
            pass
        metrics.histogram("bench/step_time_s").observe(dt)
        metrics.histogram("bench/data_time_s").observe(dt)
        metrics.counter("bench/steps").inc()
        metrics.counter("bench/examples").inc(16)
        metrics.counter("bench/bytes").inc(1 << 20)

    was_enabled = telemetry.enabled()
    try:
        # -- the contract: measured instr cost vs measured step time -------
        step_s = min(_loop(step, x0, n, bare_instr)[0] / n
                     for _ in range(reps))
        telemetry.set_enabled(True)
        instr_on_s = min(_time_calls(full_instr, 0.003, m)
                         for _ in range(reps))
        telemetry.set_enabled(False)
        instr_off_s = min(_time_calls(full_instr, 0.003, m)
                          for _ in range(reps))
        on_pct = instr_on_s / step_s * 100.0
        off_pct = instr_off_s / step_s * 100.0

        # -- end-to-end cross-check: instrumented vs bare loops ------------
        telemetry.set_enabled(True)
        loop_on = min(_loop(step, x0, n, full_instr)[0] for _ in range(reps))
        loop_bare = min(_loop(step, x0, n, bare_instr)[0]
                        for _ in range(reps))
        loop_delta_pct = (loop_on - loop_bare) / loop_bare * 100.0

        # -- raw per-op costs ----------------------------------------------
        reg = telemetry.Registry()
        c = reg.counter("bench/per_op")
        h = reg.histogram("bench/per_op_h")
        t0 = time.perf_counter()
        for _ in range(m):
            c.inc()
        inc_ns = (time.perf_counter() - t0) / m * 1e9
        t0 = time.perf_counter()
        for _ in range(m):
            h.observe(0.001)
        obs_ns = (time.perf_counter() - t0) / m * 1e9
        t0 = time.perf_counter()
        for _ in range(m // 10):
            with trace.span("bench/op"):
                pass
        span_ns = (time.perf_counter() - t0) / (m // 10) * 1e9
        trace.reset()
    finally:
        telemetry.set_enabled(was_enabled)

    rows = [
        ("telemetry/overhead_on", instr_on_s * 1e6,
         f"overhead_pct={on_pct:.3f};step_us={step_s * 1e6:.1f};"
         f"instr_us={instr_on_s * 1e6:.2f}"),
        ("telemetry/overhead_off", instr_off_s * 1e6,
         f"overhead_pct={off_pct:.3f};instr_us={instr_off_s * 1e6:.2f}"),
        ("telemetry/loop_delta", loop_on / n * 1e6,
         f"delta_pct={loop_delta_pct:.2f};n={n};informational=1"),
        ("telemetry/per_op", 0.0,
         f"counter_inc_ns={inc_ns:.0f};hist_observe_ns={obs_ns:.0f};"
         f"span_ns={span_ns:.0f}"),
    ]
    # the acceptance contract: default-on telemetry costs < 1% of step
    # time, and the no-op path is free (noise floor)
    assert on_pct < 1.0, \
        f"enabled telemetry overhead {on_pct:.2f}% >= 1% of step time"
    assert off_pct < 1.0, \
        f"no-op telemetry overhead {off_pct:.2f}% >= 1% of step time"
    return rows


if __name__ == "__main__":
    import sys
    for name, us, derived in run(quick="--quick" in sys.argv):
        print(f"{name},{us:.1f},{derived}")
