"""Benchmark history: compare a fresh ``BENCH_*.json`` against a committed
baseline, with per-metric tolerance bands — the regression gate behind
``benchmarks/run.py --check``.

Metric space: every bench row contributes ``<row>.us_per_call`` plus one
metric per ``key=value`` pair of its ``derived`` string (the
``tok_s=141.1;p50_ms=1.2;compiles=1`` convention every bench module
already emits). Direction is inferred from the key name:

- **higher is better**: throughputs and ratios — ``tok_s``, ``speedup``,
  ``examples_per_s``, ``continuous_over_static``, ``*_tok_s``,
  ``*_frac``/``mfu`` attribution ratios;
- **lower is better**: times and footprints — ``us_per_call``, ``*_ms`` /
  ``*_us`` / ``*_s``, ``*bytes`` / ``workspace``, ``compiles``;
- anything else is informational (tracked, never gates).

Tolerance: a metric regresses when it moves against its direction by more
than ``rtol`` (relative). ``rtol`` resolves per metric: exact
``"<row>.<key>"`` entry in the tolerances file, then bare ``"<key>"``
entry, then ``default_rtol``. The committed default (0.15) is strict
enough that a 20% throughput drop fails; the CI job loosens it with
``--rtol`` because shared-runner CPU timings are noisy — the committed
band is the *intent*, the CI override is the *reality of the runner*.

Baselines live in ``benchmarks/baselines/`` (``BENCH_quick_cpu.json`` for
``--quick`` runs, ``BENCH_full_cpu.json`` otherwise) next to
``tolerances.json``. ``python benchmarks/history.py NEW.json`` is the
standalone CLI; ``run.py --check`` calls :func:`check_against_dir`.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import dataclass

# higher-better: multi-token patterns matched as substrings, single
# tokens matched against the "_"-split token set ("bw" must match
# achieved_bw but never bwd_ms)
HIGHER_SUBSTR = ("tok_s", "examples_per_s", "continuous_over_static",
                 "speedup", "tflops")
HIGHER_TOKENS = frozenset({"mfu", "frac", "bw", "speedup", "gbps"})
LOWER_SUFFIX = ("us_per_call", "_ms", "_us", "_s", "bytes", "workspace",
                "compiles", "overhead", "exposed")

OK, REGRESSED, IMPROVED, INFO, MISSING, NEW = (
    "ok", "regressed", "improved", "info", "missing", "new")


def direction(key: str) -> int:
    """+1 higher-better, -1 lower-better, 0 informational. Higher-better
    checked first so ``tok_s`` wins over the ``_s`` suffix."""
    if any(pat in key for pat in HIGHER_SUBSTR):
        return 1
    if HIGHER_TOKENS & set(key.split("_")):
        return 1
    for pat in LOWER_SUFFIX:
        if key.endswith(pat) or key == pat.lstrip("_"):
            return -1
    return 0


def parse_derived(derived: str) -> dict:
    """``"tok_s=141.1;p50_ms=1.2"`` -> numeric dict (non-floats skipped)."""
    out = {}
    for part in filter(None, (p.strip() for p in str(derived).split(";"))):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k.strip()] = float(v)
        except ValueError:
            continue
    return out


def metrics_of(obj: dict) -> dict:
    """Flatten a BENCH object into ``{"<row>.<key>": value}``. Error rows
    (``*/ERROR``) are dropped — a crashed bench is run.py's exit-1, not a
    number to diff."""
    out = {}
    for row in obj.get("rows", []):
        name = row.get("name", "")
        if not name or name.endswith("/ERROR"):
            continue
        us = row.get("us_per_call")
        if isinstance(us, (int, float)):
            out[f"{name}.us_per_call"] = float(us)
        for k, v in parse_derived(row.get("derived", "")).items():
            out[f"{name}.{k}"] = v
    return out


@dataclass
class Verdict:
    metric: str
    status: str          # ok | regressed | improved | info | missing | new
    base: float | None = None
    new: float | None = None
    rel: float = 0.0     # signed relative change vs baseline
    rtol: float = 0.0

    def line(self) -> str:
        mark = {REGRESSED: "FAIL", IMPROVED: "  up", OK: "  ok",
                INFO: "info", MISSING: "miss", NEW: " new"}[self.status]
        b = "-" if self.base is None else f"{self.base:.4g}"
        n = "-" if self.new is None else f"{self.new:.4g}"
        return (f"{mark}  {self.metric:<52s} {b:>12s} -> {n:>12s}  "
                f"{self.rel * 100:+7.1f}%  (rtol {self.rtol:.2f})")


def _rtol_for(metric: str, default_rtol: float, per_metric: dict) -> float:
    if metric in per_metric:
        return float(per_metric[metric])
    key = metric.rsplit(".", 1)[-1]
    if key in per_metric:
        return float(per_metric[key])
    return float(default_rtol)


def compare(baseline: dict, new: dict, *, default_rtol: float = 0.15,
            per_metric: dict | None = None) -> list:
    """Verdict per metric of the union; gate on ``status == 'regressed'``."""
    per_metric = per_metric or {}
    base_m, new_m = metrics_of(baseline), metrics_of(new)
    verdicts = []
    for metric in sorted(base_m):
        b = base_m[metric]
        rtol = _rtol_for(metric, default_rtol, per_metric)
        if metric not in new_m:
            verdicts.append(Verdict(metric, MISSING, base=b, rtol=rtol))
            continue
        n = new_m[metric]
        rel = (n - b) / b if b else 0.0
        d = direction(metric.rsplit(".", 1)[-1])
        if d == 0 or b == 0:
            status = INFO
        elif rel * d < -rtol:        # moved against the good direction
            status = REGRESSED
        elif rel * d > rtol:
            status = IMPROVED
        else:
            status = OK
        verdicts.append(Verdict(metric, status, base=b, new=n, rel=rel,
                                rtol=rtol))
    for metric in sorted(set(new_m) - set(base_m)):
        verdicts.append(Verdict(metric, NEW, new=new_m[metric]))
    return verdicts


def load_tolerances(baselines_dir: str) -> tuple:
    path = os.path.join(baselines_dir, "tolerances.json")
    if not os.path.exists(path):
        return 0.15, {}
    with open(path) as f:
        tol = json.load(f)
    return float(tol.get("default_rtol", 0.15)), dict(
        tol.get("per_metric", {}))


def baseline_path_for(obj: dict, baselines_dir: str) -> str:
    name = ("BENCH_quick_cpu.json" if obj.get("quick")
            else "BENCH_full_cpu.json")
    return os.path.join(baselines_dir, name)


def check_against_dir(obj: dict, baselines_dir: str, *,
                      rtol: float | None = None) -> tuple:
    """``(ok, verdicts, baseline_path)`` — ``ok`` is True when nothing
    regressed (or no baseline exists yet for this mode, which is reported
    but does not gate: the first run *creates* history)."""
    path = baseline_path_for(obj, baselines_dir)
    if not os.path.exists(path):
        return True, [], path
    with open(path) as f:
        baseline = json.load(f)
    default_rtol, per_metric = load_tolerances(baselines_dir)
    if rtol is not None:
        default_rtol, per_metric = float(rtol), {}
    verdicts = compare(baseline, obj, default_rtol=default_rtol,
                       per_metric=per_metric)
    ok = not any(v.status == REGRESSED for v in verdicts)
    return ok, verdicts, path


def render(verdicts: list, *, only_notable: bool = False) -> str:
    lines = []
    for v in verdicts:
        if only_notable and v.status in (OK, INFO, NEW):
            continue
        lines.append(v.line())
    n_reg = sum(1 for v in verdicts if v.status == REGRESSED)
    n_imp = sum(1 for v in verdicts if v.status == IMPROVED)
    lines.append(f"{len(verdicts)} metrics: {n_reg} regressed, "
                 f"{n_imp} improved")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("new", help="fresh BENCH_*.json to check")
    ap.add_argument("--baseline", default=None,
                    help="explicit baseline BENCH json")
    ap.add_argument("--baselines", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "baselines"),
        help="baselines directory (default: benchmarks/baselines)")
    ap.add_argument("--rtol", type=float, default=None,
                    help="override every tolerance band")
    ap.add_argument("--all", action="store_true",
                    help="print every verdict, not just notable ones")
    args = ap.parse_args(argv)
    with open(args.new) as f:
        obj = json.load(f)
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        default_rtol, per_metric = load_tolerances(args.baselines)
        if args.rtol is not None:
            default_rtol, per_metric = args.rtol, {}
        verdicts = compare(baseline, obj, default_rtol=default_rtol,
                           per_metric=per_metric)
        ok = not any(v.status == REGRESSED for v in verdicts)
        base_path = args.baseline
    else:
        ok, verdicts, base_path = check_against_dir(
            obj, args.baselines, rtol=args.rtol)
    if not verdicts:
        print(f"no baseline at {base_path} — nothing to compare")
        return 0
    print(f"comparing {args.new} vs {base_path}")
    print(render(verdicts, only_notable=not args.all))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
