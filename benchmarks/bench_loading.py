"""Paper §3.3 / Alg 1: parallel loading overlap.

Materializes image batch files on disk and compares steps/s of training with
the background ParallelLoader vs the synchronous in-loop loader. Derived:
overlap efficiency (parallel/sync throughput; >1 means the loader hid IO).
"""
import tempfile
import time


def run():
    import jax
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.core import get_exchanger, init_train_state, make_bsp_step
    from repro.data.prefetch import ParallelLoader, SyncLoader
    from repro.data.synthetic import ImageSource, materialize_batch_files
    from repro.models import build_model
    from repro.optim import constant, sgd_momentum

    cfg = get_smoke_config("alexnet")
    model = build_model(cfg)
    opt = sgd_momentum(weight_decay=0.0)
    mesh = jax.make_mesh((1,), ("data",))
    jax.set_mesh(mesh)
    step = jax.jit(make_bsp_step(model, opt, get_exchanger("ar"),
                                 constant(0.01), mesh))
    n_batches, bsz = 16, 16
    with tempfile.TemporaryDirectory() as td:
        src = ImageSource(cfg.image_size, cfg.num_classes)
        files = materialize_batch_files(src, td, n_batches, bsz)
        mean = np.zeros((cfg.image_size, cfg.image_size, 3), np.float32)
        rows = []
        # local disk (IO << compute) and simulated remote disk (IO ~ compute,
        # the paper's motivating case: "network bandwidth if reading from
        # remote disks")
        for name, loader_cls, kw in [
                ("sync_local", SyncLoader, {}),
                ("parallel_local", ParallelLoader, {"depth": 3}),
                ("sync_remote", SyncLoader, {"io_delay_ms": 400}),
                ("parallel_remote", ParallelLoader,
                 {"depth": 3, "io_delay_ms": 400})]:
            loader = loader_cls(files, image_mean=mean,
                                crop=cfg.image_size - 8, **kw)
            state = init_train_state(model, opt, jax.random.key(0))
            it = iter(loader)
            b = next(it)
            state, _ = step(state, b, jax.random.key(0))  # compile
            jax.block_until_ready(state)
            t0 = time.perf_counter()
            n = 0
            for b in it:
                state, _ = step(state, b, jax.random.key(n))
                n += 1
            jax.block_until_ready(state)
            dt = time.perf_counter() - t0
            rows.append((name, dt / max(n, 1) * 1e6, n / dt))
            if hasattr(loader, "stop"):
                loader.stop()
    base = {"local": rows[0][2], "remote": rows[2][2]}
    return [(f"loading/{name}", us, f"steps_per_s={sps:.2f};"
             f"speedup_vs_sync={sps / base[name.split('_')[1]]:.2f}")
            for name, us, sps in rows]


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
