"""Exposed-communication benchmark: overlapped vs serialized exchange.

The paper's §3.2 claim is that *when* gradients move matters as much as
how many bytes move. This benchmark trains an MLP at AlexNet/VGG
FC-parameter scale on 8 host devices with gradient accumulation
(microbatches) and measures, per strategy x bucket size:

- ``none``    : compute-only baseline (identity exchanger)
- ``serial``  : RS->update->AG issued once after the full accumulation
- ``overlap`` : ``overlap="buckets"`` — microbatch i-1's bucket
                reduce-scatters issued while microbatch i's backprop runs

Exposed (non-overlapped) comm time = mode wall time - compute baseline.
(The baseline updates *replicated* params while the sharded modes update
1/k per rank, so their exposed figure is understated by the update
savings and can go negative on CPU hosts; compare serial vs overlap rows
directly for the overlap effect. On CPU, XLA has no async collectives —
overlap wall time includes the m× wire volume un-hidden; the compiled-HLO
evidence is the schedule signal, the TPU scheduler does the hiding.)
The derived column also reports the compiled-HLO overlap evidence
(``roofline.analysis.overlap_evidence``): the loop body must contain a
collective that is independent of (hence issuable before) the backward
dots. One subprocess per scale so the large stacked buffers are freed
between runs (single-host memory).
"""
import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.core import (get_exchanger, init_sharded_train_state,
                        init_train_state, make_bsp_step)
from repro.models.registry import Model
from repro.optim import constant, sgd_momentum
from repro.roofline.analysis import overlap_evidence, parse_collectives

SCALES = {
    # FC stacks with the paper models' dominant parameter counts
    "mlp-quick":  [(256, 1024), (1024, 1024), (1024, 512)],       # ~1.8M
    "alexnet-fc": [(9216, 4096), (4096, 4096), (4096, 1000)],     # ~58M
    "vgg-fc":     [(25088, 4096), (4096, 4096), (4096, 1000)],    # ~123M
}

scale = sys.argv[1]
strategies = sys.argv[2].split(",")
bucket_list = [int(b) for b in sys.argv[3].split(",")]
widths = SCALES[scale]
MICRO = 4
BATCH = 32                       # global; 4 rows/rank, 1 per microbatch


def build_model():
    def init(key):
        return {f"w{i}": jax.random.normal(jax.random.fold_in(key, i), s)
                * 0.02 for i, s in enumerate(widths)}

    def loss_fn(params, batch, rng=None, unroll=False):
        h = batch["x"]
        for i in range(len(widths)):
            h = jnp.tanh(h @ params[f"w{i}"])
        loss = 0.5 * jnp.mean(jnp.square(h))
        return loss, {"loss": loss, "aux": jnp.zeros(())}

    return Model(cfg=None, init=init, loss_fn=loss_fn, forward=None)


model = build_model()
mesh = jax.make_mesh((8,), ("data",))
jax.set_mesh(mesh)
opt = sgd_momentum(weight_decay=0.0)
batch = {"x": np.random.default_rng(0).normal(
    0, 1, (BATCH, widths[0][0])).astype(np.float32)}
rng = jax.random.key(1)
nparams = sum(int(np.prod(s)) for s in widths)


def timed(step_fn, state):
    s, _ = step_fn(state, batch, rng)
    jax.block_until_ready(s)        # warm (compile)
    reps = 2
    t0 = time.perf_counter()
    for _ in range(reps):
        s, _ = step_fn(s, batch, rng)
    jax.block_until_ready(s)
    return (time.perf_counter() - t0) / reps * 1e6


rows = []
base = jax.jit(make_bsp_step(model, opt, get_exchanger("none"), constant(0.01),
                             mesh, microbatches=MICRO))
t_none = timed(base, init_train_state(model, opt, jax.random.key(0)))
rows.append({"name": f"overlap/{scale}/none", "us": t_none,
             "derived": f"params={nparams}"})

for strat in strategies:
    ex = get_exchanger(strat)
    for bb in bucket_list:
        sstate = init_sharded_train_state(model, opt, jax.random.key(0),
                                          mesh, bucket_bytes=bb)
        serial = jax.jit(make_bsp_step(
            model, opt, ex, constant(0.01), mesh, microbatches=MICRO,
            bucket_bytes=bb, sharded_update=True))
        over = jax.jit(make_bsp_step(
            model, opt, ex, constant(0.01), mesh, microbatches=MICRO,
            bucket_bytes=bb, overlap="buckets"))
        t_serial = timed(serial, sstate)
        t_over = timed(over, sstate)
        txt = over.lower(sstate, batch, rng).compile().as_text()
        ev = overlap_evidence(txt)
        colls = parse_collectives(txt)
        tag = f"overlap/{scale}/{strat}/b{bb}"
        rows.append({"name": f"{tag}/serial", "us": t_serial,
                     "derived": f"exposed_us={t_serial - t_none:.1f}"})
        rows.append({
            "name": f"{tag}/overlap", "us": t_over,
            "derived": (f"exposed_us={t_over - t_none:.1f};"
                        f"rs_before_last_dot={ev['rs_before_last_dot']};"
                        f"comm_independent_of_dots="
                        f"{ev['comm_independent_of_dots']};"
                        f"loop_wire_bytes={colls.total_bytes}")})
print("RESULTS_JSON:" + json.dumps(rows))
"""


def run(quick: bool = False):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    configs = ([("mlp-quick", "asa16", "0,1048576")] if quick else
               [("alexnet-fc", "asa16,asa8", "0,33554432"),
                ("vgg-fc", "asa16", "0,33554432")])
    out = []
    for scale, strats, buckets in configs:
        proc = subprocess.run(
            [sys.executable, "-c", _SCRIPT, scale, strats, buckets],
            env=env, capture_output=True, text=True, timeout=3000)
        if proc.returncode != 0:
            out.append((f"overlap/{scale}/FAILED", 0.0,
                        f"rc={proc.returncode}"))
            sys.stderr.write(proc.stderr[-2000:])
            continue
        rows = None
        for line in proc.stdout.splitlines():
            if line.startswith("RESULTS_JSON:"):
                rows = json.loads(line[len("RESULTS_JSON:"):])
        for r in rows:
            out.append((r["name"], r["us"], r["derived"]))
    return out


if __name__ == "__main__":
    for name, us, derived in run(quick="--quick" in sys.argv):
        print(f"{name},{us:.1f},{derived}")
